"""End-to-end training driver example.

Default (CI-friendly, CPU): a reduced olmo-family model for 60 steps
with checkpointing — loss visibly drops.

The ~100M-parameter run the deliverable describes:
    PYTHONPATH=src python examples/train_lm.py --full
which drives the same launcher with d_model=768, 12 layers
(~103M params incl embeddings) for 300 steps. On CPU this takes hours;
on a real TPU slice it is minutes — the launcher is identical.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.train import train  # noqa: E402

if __name__ == "__main__":
    if "--full" in sys.argv:
        train(["--arch", "olmo-1b", "--smoke",
               "--d-model", "768", "--n-layers", "12",
               "--steps", "300", "--batch", "16", "--seq", "512",
               "--lr", "3e-4", "--ckpt-dir", "/tmp/repro_100m",
               "--ckpt-every", "50"])
    else:
        train(["--arch", "olmo-1b", "--smoke",
               "--steps", "60", "--batch", "8", "--seq", "64",
               "--lr", "5e-3", "--ckpt-dir", "/tmp/repro_quick",
               "--ckpt-every", "20"])
