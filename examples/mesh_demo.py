"""Mesh demo — one NetworkPlan spanning more than one device.

The paper's library adapts an IP to the resources ONE fabric offers;
``plan_network(mesh=...)`` extends the same resource-driven story
across a device mesh, narrated here in three moves:

1. SPLIT WINS — a conv that saturates one device (the budget pins the
   MXU, forcing the slow VPU member) is batch-split across 2 devices:
   the per-device footprint halves, the planner flips to the MXU
   member, and the collective bill (priced into ``comm_cycles``) still
   leaves the split cheaper.  Execution goes through ``shard_map``
   (distributed/shard_exec.py) and is bit-identical to the replicated
   walk.
2. REFUSAL — a tiny 1x1 conv whose collectives dwarf its compute
   plans at degree=1: the mesh is offered, and honestly declined.
3. SERVING — ``AdaptiveServer(mesh=...)`` grants tenants whole-device
   slices via the arbiter and serves sharded plans live.

Multi-device is real on a CPU host: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` (set before JAX
imports, done below).  See docs/adaptive_ips.md, "Sharding contract",
and benchmarks/run.py::table_mesh for the measured-wall-clock gate.

    XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
      PYTHONPATH=src python examples/mesh_demo.py
"""
import os
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=2")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.ip import SiteSpec  # noqa: E402
from repro.core.plan import plan_network  # noqa: E402
from repro.core.resources import MeshSpec, ResourceBudget  # noqa: E402
from repro.distributed.shard_exec import (apply_plan_replicated,  # noqa: E402
                                          apply_plan_sharded)


def describe(tag, plan):
    s = plan.sites[0]
    shard = (f"{s.shard_axis}x{s.shard_degree}" if s.sharded
             else "replicated")
    print(f"  {tag:<18} {s.ip.name.split('.')[-1]:<10} {shard:<10} "
          f"est={plan.total_cycles:.3e} cyc "
          f"(comm={s.footprint.comm_cycles:.3e})")


def main():
    print(f"host devices: {len(jax.devices())} "
          "(forced via XLA_FLAGS — same flag CI uses)")
    mesh = MeshSpec(devices=2)
    rng = np.random.default_rng(0)

    print("\n== 1. SPLIT WINS: one device saturates, two flip the "
          "member ==")
    budget = ResourceBudget(mxu_passes_budget=7)   # the MXU is rationed
    x = jnp.asarray(rng.normal(size=(8, 16, 16, 32)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, (9 * 32) ** -0.5,
                               (3, 3, 32, 128)).astype(np.float32))
    spec = SiteSpec.make("conv", "conv2d", (x.shape, w.shape),
                         "float32", dual=False)
    p1 = plan_network((spec,), budget)
    p2 = plan_network((spec,), budget, mesh=mesh)
    describe("1 device", p1)
    describe("2-device mesh", p2)
    assert p2.sites[0].sharded and p2.total_cycles < p1.total_cycles
    y_rep = apply_plan_replicated(p2, x, {"conv": w})
    y_shd = apply_plan_sharded(p2, x, {"conv": w})
    assert bool((y_rep == y_shd).all())
    print("  -> batch split halves the per-device footprint, the "
          "planner buys the\n     MXU member back, and the sharded "
          "result is bit-identical")

    print("\n== 2. REFUSAL: collectives would dwarf the compute ==")
    xr_shape, wr_shape = (4, 64, 64, 4), (1, 1, 4, 128)
    rspec = SiteSpec.make("conv", "conv2d", (xr_shape, wr_shape),
                          "float32", dual=False)
    pr = plan_network((rspec,), ResourceBudget(), mesh=mesh)
    describe("2-device mesh", pr)
    assert not pr.sites[0].sharded
    print("  -> the mesh was offered and declined: an all-reduce of "
          "the 8 MiB output\n     costs ~11x the whole site's compute")

    print("\n== 3. SERVING: tenants hold whole-device slices ==")
    from repro.models.frontends import init_cnn_frontend
    from repro.runtime.server import AdaptiveServer
    params = init_cnn_frontend(jax.random.PRNGKey(0), channels=(3, 8, 8),
                               d_model=16)
    srv = AdaptiveServer(ResourceBudget(), mesh=mesh, max_batch=4)
    srv.register("vision", params, (16, 16, 3))
    xb = jnp.asarray(rng.normal(size=(4, 16, 16, 3)).astype(np.float32))
    srv.submit("vision", xb)
    done = srv.drain()
    share = srv.shares()["vision"]
    print(f"  served {len(done)} requests; tenant holds "
          f"{share.devices}/{mesh.devices} devices "
          f"(sub-mesh planned + shard_map executed)")

    # the library's central promise, now across devices: the mesh
    # changes the implementation, never the result
    json_rt = type(p2).from_json(p2.to_json())
    assert json_rt.to_json() == p2.to_json()
    print("\nplan JSON round-trips the sharding fields bit-exactly")


if __name__ == "__main__":
    main()
