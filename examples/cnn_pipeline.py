"""Adaptive CNN pipeline — the paper's future-work scenario closed:
a full CNN layer stack (conv -> pool -> activation) planned as ONE
NetworkPlan — every op site competes for a slice of the same budget,
and the budget is partitioned across the whole graph up front.

    PYTHONPATH=src python examples/cnn_pipeline.py

Part 1 runs an int8 fixed-point CNN under three deployment budgets
(ample / MXU-starved / VPU-starved): the planned IPs differ per budget,
the outputs are bit-identical — adaptation changes the implementation,
never the math.  Plans are memoized (re-planning the same graph+budget
is a dict hit) and serialize to JSON for experiment artifacts.

Part 2 shows the precision axis the activation family adds: under an
8-bit-precision budget the selector swaps the exact transcendental for
the fixed-point LUT IP, trading a bounded approximation error for ~4x
fewer vector ops and 1-byte operand streaming.

Part 3 plans the precision ladder: a float32 block that does NOT fit a
tight VMEM envelope is re-planned with per-site ``ladder=(16, 8)`` —
the planner lowers exactly the sites that need it (the ``p=`` column of
``describe()``), execution quantizes accordingly, and the per-site
error report quantifies what the fit cost.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core.plan import NetworkPlan, plan_network, planner_stats
from repro.core.resources import ResourceBudget
from repro.core.selector import select_activation_ip
from repro.kernels.activation.ops import activation
from repro.kernels.conv2d.ops import conv2d
from repro.kernels.pool2d.ops import pool2d

LAYERS = [  # (cin, cout, kernel)
    (8, 16, 3),
    (16, 32, 3),
    (32, 32, 3),
]

BUDGETS = {
    "ample": ResourceBudget(),
    "mxu_starved": ResourceBudget(mxu_available=False),
    "vpu_starved": ResourceBudget(vpu_ops_budget=2_000_000),
}


def requantize(y):
    return jnp.clip(y // 8, -128, 127).astype(jnp.int8)


def stack_site_specs(img_shape):
    """The whole stack as declarative sites: conv (int8 operands) ->
    maxpool -> relu (both on the conv's int32 accumulator), requantized
    back to int8 between layers.  Per-layer sites come from the same
    oracle-derived helper the models use."""
    from repro.models.blocks import cnn_block_site_specs
    specs = []
    shape = img_shape
    for li, (cin, cout, k) in enumerate(LAYERS):
        layer, out = cnn_block_site_specs(
            shape, (k, k, cin, cout), x_dtype=jnp.int8, pool_mode="max",
            activation="relu", site=f"layer{li}")
        specs += layer
        shape = out.shape
    return specs


def run_stack(img, weights, budget):
    """conv -> maxpool -> relu -> requant per layer, from one plan.
    fuse=False: this part drives each op kernel by hand (with its own
    requantize between them), so it needs the per-op sites the fused
    default would collapse."""
    plan = plan_network(stack_site_specs(img.shape), budget, fuse=False)
    x = img
    for li, w in enumerate(weights):
        x = conv2d(x, w, ip=plan[f"layer{li}.conv"][0].name)
        x = pool2d(x, window=(2, 2), mode="max",
                   ip=plan[f"layer{li}.pool"][0].name)
        x = requantize(activation(x, kind="relu",
                                  ip=plan[f"layer{li}.act"][0].name))
    return x, plan


def main():
    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.integers(-128, 128, (2, 40, 40, 8), dtype=np.int8))
    weights = [jnp.asarray(rng.integers(-16, 16, (k, k, cin, cout),
                                        dtype=np.int8))
               for cin, cout, k in LAYERS]

    results = {}
    for bname, budget in BUDGETS.items():
        out, plan = run_stack(img, weights, budget)
        results[bname] = np.asarray(out)
        print(f"\n=== budget: {bname} ===")
        print(plan.describe())
        print(f"  output: {out.shape}, sum={int(np.asarray(out).sum())}")

    base = results["ample"]
    for bname, out in results.items():
        assert np.array_equal(out, base), bname
    print("\nall budgets produced IDENTICAL outputs — adaptation changed "
          "the implementation, not the math. ✓")

    # --- plan cache + JSON artifacts ------------------------------------
    evals_before = planner_stats().selector_evals
    replanned = plan_network(stack_site_specs(img.shape), BUDGETS["ample"],
                             fuse=False)
    assert planner_stats().selector_evals == evals_before
    assert replanned is plan_network(stack_site_specs(img.shape),
                                     BUDGETS["ample"], fuse=False)
    roundtrip = NetworkPlan.from_json(replanned.to_json())
    assert roundtrip == replanned
    print("plan cache hit (zero new selector evals) + JSON round-trip. ✓")

    # --- Part 2: the precision axis -------------------------------------
    feats = jnp.asarray(rng.normal(0, 2, (2, 10, 10, 32)).astype(np.float32))
    full = ResourceBudget(precision_bits=16)
    low = ResourceBudget(precision_bits=8)
    ip_full = select_activation_ip(feats.shape, kind="tanh", budget=full)
    ip_low = select_activation_ip(feats.shape, kind="tanh", budget=low)
    y_full = activation(feats, kind="tanh", ip=ip_full.name)
    y_low = activation(feats, kind="tanh", ip=ip_low.name)
    err = float(jnp.abs(y_full - y_low).max())
    print(f"\ntanh head: precision>=16b -> {ip_full.name}, "
          f"precision<=8b -> {ip_low.name}")
    print(f"max |exact - lut| = {err:.4f} (bounded by the 256-level grid)")
    assert ip_full.name == "activation.act_vpu"
    assert ip_low.name == "activation.act_lut"
    assert err < 0.05
    print("precision-driven swap verified. ✓")

    # --- Part 3: the precision ladder ------------------------------------
    import jax

    from repro.models.blocks import (apply_cnn_block, cnn_block_site_specs,
                                     init_cnn_block)
    from repro.quant.report import max_rel_error, summarize

    block = init_cnn_block(jax.random.PRNGKey(0), cin=8, cout=16, k=3)
    xs = jnp.asarray(rng.normal(size=(2, 16, 16, 8)).astype(np.float32))
    y_f32 = apply_cnn_block(block, xs, activation="relu")
    # 24 KiB: too tight for the f32 fused block (the planner fuses by
    # default), loose enough for its int16 rung.
    tight = ResourceBudget(vmem_bytes=24 * 1024)
    try:
        apply_cnn_block(block, xs, budget=tight, activation="relu")
        raise AssertionError("expected the f32-only block to be infeasible")
    except ValueError:
        print(f"\nf32-only block under {tight.vmem_bytes // 1024}KiB VMEM: "
              "infeasible (as expected)")
    report = {}
    y_lad = apply_cnn_block(block, xs, budget=tight, ladder=(16, 8),
                            activation="relu", quant_report=report)
    specs3, _ = cnn_block_site_specs(xs.shape, block["w"].shape,
                                     x_dtype=xs.dtype, activation="relu",
                                     ladder=(16, 8))
    plan3 = plan_network(specs3, tight)
    print("ladder-planned block (note the p= column):")
    print(plan3.describe())
    print("per-site quantization error report:")
    print(summarize(report))
    rel = float(jnp.linalg.norm(y_lad - y_f32) / jnp.linalg.norm(y_f32))
    assert max_rel_error(report) <= 5e-2 and rel <= 5e-2
    print(f"ladder made the block fit; end-to-end rel err {rel:.2e} ≤ 5e-2 ✓")


if __name__ == "__main__":
    main()
