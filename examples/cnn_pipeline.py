"""Adaptive CNN pipeline — the paper's future-work scenario closed:
a full CNN layer stack (conv -> pool -> activation) where EVERY op is
dispatched through the resource-driven selector under one budget.

    PYTHONPATH=src python examples/cnn_pipeline.py

Part 1 runs an int8 fixed-point CNN under three deployment budgets
(ample / MXU-starved / VPU-starved): the selected IPs differ per budget,
the outputs are bit-identical — adaptation changes the implementation,
never the math.

Part 2 shows the precision axis the activation family adds: under an
8-bit-precision budget the selector swaps the exact transcendental for
the fixed-point LUT IP, trading a bounded approximation error for ~4x
fewer vector ops and 1-byte operand streaming.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core.resources import ResourceBudget
from repro.core.selector import (describe_plan, select_activation_ip,
                                 select_conv_ip, select_pool_ip)
from repro.kernels.activation.ops import activation
from repro.kernels.conv2d.ops import conv2d
from repro.kernels.pool2d.ops import pool2d

LAYERS = [  # (cin, cout, kernel)
    (8, 16, 3),
    (16, 32, 3),
    (32, 32, 3),
]

BUDGETS = {
    "ample": ResourceBudget(),
    "mxu_starved": ResourceBudget(mxu_available=False),
    "vpu_starved": ResourceBudget(vpu_ops_budget=2_000_000),
}


def requantize(y):
    return jnp.clip(y // 8, -128, 127).astype(jnp.int8)


def run_stack(img, weights, budget):
    """conv -> maxpool -> relu -> requant per layer, all selector-driven."""
    plan = {}
    x = img
    for li, w in enumerate(weights):
        ip, fp = select_conv_ip(x.shape, w.shape, dual=False, dtype=jnp.int8,
                                budget=budget, with_footprint=True)
        plan[f"layer{li}.conv"] = (ip, fp)
        x = conv2d(x, w, ip=ip.name)
        ip, fp = select_pool_ip(x.shape, window=(2, 2), mode="max",
                                dtype=x.dtype, budget=budget,
                                with_footprint=True)
        plan[f"layer{li}.pool"] = (ip, fp)
        x = pool2d(x, window=(2, 2), mode="max", ip=ip.name)
        ip, fp = select_activation_ip(x.shape, kind="relu", dtype=x.dtype,
                                      budget=budget, with_footprint=True)
        plan[f"layer{li}.act"] = (ip, fp)
        x = requantize(activation(x, kind="relu", ip=ip.name))
    return x, plan


def main():
    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.integers(-128, 128, (2, 40, 40, 8), dtype=np.int8))
    weights = [jnp.asarray(rng.integers(-16, 16, (k, k, cin, cout),
                                        dtype=np.int8))
               for cin, cout, k in LAYERS]

    results = {}
    for bname, budget in BUDGETS.items():
        out, plan = run_stack(img, weights, budget)
        results[bname] = np.asarray(out)
        print(f"\n=== budget: {bname} ===")
        print(describe_plan(plan))
        print(f"  output: {out.shape}, sum={int(np.asarray(out).sum())}")

    base = results["ample"]
    for bname, out in results.items():
        assert np.array_equal(out, base), bname
    print("\nall budgets produced IDENTICAL outputs — adaptation changed "
          "the implementation, not the math. ✓")

    # --- Part 2: the precision axis -------------------------------------
    feats = jnp.asarray(rng.normal(0, 2, (2, 10, 10, 32)).astype(np.float32))
    full = ResourceBudget(precision_bits=16)
    low = ResourceBudget(precision_bits=8)
    ip_full = select_activation_ip(feats.shape, kind="tanh", budget=full)
    ip_low = select_activation_ip(feats.shape, kind="tanh", budget=low)
    y_full = activation(feats, kind="tanh", ip=ip_full.name)
    y_low = activation(feats, kind="tanh", ip=ip_low.name)
    err = float(jnp.abs(y_full - y_low).max())
    print(f"\ntanh head: precision>=16b -> {ip_full.name}, "
          f"precision<=8b -> {ip_low.name}")
    print(f"max |exact - lut| = {err:.4f} (bounded by the 256-level grid)")
    assert ip_full.name == "activation.act_vpu"
    assert ip_low.name == "activation.act_lut"
    assert err < 0.05
    print("precision-driven swap verified. ✓")


if __name__ == "__main__":
    main()
