"""Serving demo — the adaptive-IP runtime under multi-tenant load.

Two CNN frontends share one constrained device (a tight VPU-op
envelope).  A latency-critical "vision-heavy" tenant floods the server
while a best-effort "edge-light" tenant trickles requests; the budget
arbiter grants slices proportional to observed demand (floored at each
tenant's minimal feasible fraction), live re-plans on every shift, and
the squeezed tenant degrades its tanh activation down the precision
ladder to the 8-bit LUT member instead of failing — the paper's
resource-driven adaptation, made dynamic.

The trace replayed here is the canonical one CI's ``table_serving``
bench gates on (``benchmarks/run.py::_run_serving``) — the demo is a
narrated view of the same experiment, so the two can never diverge.

Part 2 walks the **SLO scheduler** (``runtime/scheduler.py``): the
round loop is replaced by event-driven continuous batching where the
light tenant holds a tight wall deadline and a higher priority — watch
it jump the heavy backlog (a preemption, with an immediate arbiter
grant transfer) and report both clocks: modeled est-cycles percentiles
next to measured wall-seconds and the deadline-miss rate.

    PYTHONPATH=src python examples/serving_demo.py
"""
import importlib.util
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))


def _load_bench():
    spec = importlib.util.spec_from_file_location(
        "bench_run", ROOT / "benchmarks" / "run.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def main():
    bench = _load_bench()
    print("replaying the same skewed trace (10 heavy : 2 light per wave) "
          "under both policies\n(latency = est-cycles, the planner's own "
          "cost model)\n")
    for policy in ("static", "demand"):
        p95, telemetry = bench._run_serving(policy, 10, 2)
        print(f"== policy={policy}: overall p95 = {p95:.3e} cycles")
        for name, snap in telemetry.items():
            mix = ", ".join(f"int{b}x{n}" if b < 32 else f"f32x{n}"
                            for b, n in snap["precision_mix"].items())
            print(f"   {name:<14s} grant={snap['granted_fraction']:.3f} "
                  f"(floor {snap['floor_fraction']:.3f})  "
                  f"p95={snap['p95_cycles']:.3e}  "
                  f"occupancy={snap['batch_occupancy']:.2f}  "
                  f"plan-cache hit rate="
                  f"{snap['plan_cache_hit_rate']:.2f}")
            print(f"   {'':<14s} precision mix: {mix}; "
                  f"max quant rel err = {snap['max_quant_rel_err']:.2e}")
        print()
    print("The arbiter buys the heavy tenant the fast VPU-hungry conv "
          "member (the static half-slice forces the slower MXU one) and "
          "squeezes the light tenant below its f32 footprint — which "
          "serves on at the 8-bit LUT rung instead of failing.")
    scheduler_walkthrough(bench)


def scheduler_walkthrough(bench):
    import numpy as np
    from repro.runtime import SLOScheduler, SLOSpec

    print("\n== part 2: the SLO scheduler on the same deployment ==")
    srv, heavy_p, light_p = bench._slo_deployment(slo_pressure=2.0)
    sched = SLOScheduler(srv)
    sched.register("vision-heavy", heavy_p, (32, 32, 8),
                   slo=SLOSpec(deadline_s=5.0, priority=0))
    sched.register("edge-light", light_p, (24, 24, 6),
                   activation="tanh", ladder=(16, 8),
                   slo=SLOSpec(deadline_s=1.0, priority=1))
    rng = np.random.default_rng(0)
    # a heavy burst queues FIRST, then the priority tenant walks in:
    # FIFO would drain the whole burst before the light request
    for _ in range(8):
        sched.submit("vision-heavy",
                     rng.normal(size=(32, 32, 8)).astype(np.float32))
    for _ in range(2):
        sched.submit("edge-light",
                     rng.normal(size=(24, 24, 6)).astype(np.float32))
    comps = sched.run()
    order = [c.tenant for c in comps[:4]]
    st = sched.stats()
    print(f"first launch served: {order[0]} (queued last, dispatched "
          f"first — {st['preemptions']} preemption(s) moved the grant)")
    print(f"launches={st['launches']} sheds={st['sheds']} "
          f"rejections={st['rejections']}")
    for name, t in srv.tenants.items():
        snap = t.telemetry.snapshot()
        print(f"   {name:<14s} p95={snap['p95_cycles']:.3e} cycles "
              f"(modeled) | wall p95={snap['wall_p95_s'] * 1e3:.2f} ms "
              f"(measured) | miss rate={snap['deadline_miss_rate']:.2f} "
              f"| preempted-for={snap['preemptions']}")
    print("Both clocks on one row is the dual-clock rule: est-cycles "
          "lanes stay policy-comparable, wall seconds judge the SLO.")


if __name__ == "__main__":
    main()
