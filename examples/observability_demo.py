"""Observability demo — watch every layer of the stack decide.

Four views onto one small CNN serving stack, narrated end to end:

1. AUDIT   — plan the network under an ample and then a constrained
   budget; ``NetworkPlan.explain()`` names the concrete clause that
   rejected every candidate the selector passed over (vmem overflow,
   VPU starvation, precision-ladder descent) plus plan-level events
   (fusion decisions, partition repairs, shard refusals).
2. TRACE   — enable the span tracer, run a multi-tenant serving cycle,
   and export Chrome trace-event JSON (open it at ui.perfetto.dev):
   plan/replan spans, kernel launches, arbiter splits, batch queue
   waits.  Disabled, the tracer costs the hot loop nothing.
3. METRICS — render the server's state as Prometheus-style text:
   per-tenant request counts, latency quantiles, shard degree,
   comm-cycles share, plan-cache size.
4. DRIFT   — fit a calibration table, then compare an honest and an
   8x mis-scaled copy against fresh measurements: the drift monitor
   stays quiet on the first, trips on the second, and
   ``recalibrate()`` refits it quiet again.

See docs/adaptive_ips.md, "Observability contract", and
benchmarks/run.py::table_obs for the asserted version of this loop.

    PYTHONPATH=src python examples/observability_demo.py
"""
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

import numpy as np  # noqa: E402

from repro.core.calibrate_cost import (collect_plan_samples,  # noqa: E402
                                       measure_planned_site, member_key)
from repro.core.plan import clear_plan_cache, plan_network  # noqa: E402
from repro.core.resources import ResourceBudget  # noqa: E402
from repro.models.blocks import cnn_block_site_specs  # noqa: E402
from repro.obs import (EVENTS, TRACER, DriftMonitor,  # noqa: E402
                       mis_scaled_table)

LAYERS = [(8, 16), (16, 32), (32, 32)]


def network_specs():
    specs, shape = [], (2, 32, 32, LAYERS[0][0])
    for li, (cin, cout) in enumerate(LAYERS):
        layer, out = cnn_block_site_specs(
            shape, (3, 3, cin, cout), x_dtype="float32", pool_mode="max",
            activation="relu", site=f"layer{li}", ladder=(16, 8))
        specs += layer
        shape = out.shape
    return tuple(specs)


def serving_cycle():
    """One small two-tenant serving trace; returns the server."""
    import jax

    from repro.models.frontends import init_cnn_frontend
    from repro.runtime import AdaptiveServer

    clear_plan_cache()
    device = ResourceBudget(vpu_ops_budget=60_000_000,
                            vmem_bytes=12 * 1024 * 1024)
    heavy = init_cnn_frontend(jax.random.PRNGKey(0), channels=(8, 16),
                              d_model=32)
    light = init_cnn_frontend(jax.random.PRNGKey(1), channels=(6, 12),
                              d_model=16)
    srv = AdaptiveServer(device, policy="demand", max_batch=4)
    srv.register("vision-heavy", heavy, (32, 32, 8))
    srv.register("edge-light", light, (24, 24, 6), activation="tanh",
                 ladder=(16, 8))
    rng = np.random.default_rng(0)
    # demand flips between waves so the arbiter actually re-balances
    # (and logs an ``arbiter.rebalance`` event) mid-trace
    for n_heavy, n_light in ((4, 1), (1, 4)):
        for _ in range(n_heavy):
            srv.submit("vision-heavy",
                       rng.normal(size=(32, 32, 8)).astype(np.float32))
        for _ in range(n_light):
            srv.submit("edge-light",
                       rng.normal(size=(24, 24, 6)).astype(np.float32))
        srv.step()
    return srv


def main():
    specs = network_specs()

    print("== 1. AUDIT: why did the plan choose what it chose? ==")
    clear_plan_cache()
    ample = plan_network(specs, ResourceBudget())
    tight = plan_network(specs, ResourceBudget(vpu_ops_budget=2_000_000))
    moved = [s.spec.name for s in tight.sites
             if (s.ip.name, s.precision_bits) != next(
                 ((a.ip.name, a.precision_bits) for a in ample.sites
                  if a.spec.name == s.spec.name), None)]
    print(f"  ample plan: {len(ample.sites)} sites; the VPU-starved "
          f"budget moved {len(moved)} site choices")
    print("  --- tight.explain() ---")
    print("\n".join("  " + line
                    for line in tight.explain().splitlines()))

    print("\n== 2. TRACE: a serving cycle under the span tracer ==")
    serving_cycle()                      # warm compile caches untraced
    EVENTS.clear()
    TRACER.clear()
    TRACER.enable()
    try:
        srv = serving_cycle()
        metrics_text = srv.metrics().render()
    finally:
        TRACER.disable()
    doc = json.loads(TRACER.export_chrome_trace())
    cats = sorted({e["cat"] for e in doc["traceEvents"]})
    out = ROOT / "experiments" / "obs"
    out.mkdir(parents=True, exist_ok=True)
    (out / "demo_trace.json").write_text(
        TRACER.export_chrome_trace(indent=None))
    print(f"  {len(doc['traceEvents'])} events over categories "
          f"{'|'.join(cats)}")
    print(f"  -> {out / 'demo_trace.json'} (load at ui.perfetto.dev)")
    print("  event log (always on, even with the tracer off):")
    for ev in EVENTS.recent(4):
        print(f"    {ev['kind']}: "
              + ", ".join(f"{k}={v}" for k, v in sorted(ev.items())
                          if k not in ("kind", "t")))

    print("\n== 3. METRICS: Prometheus-style exposition ==")
    wanted = ("repro_tenant_requests", "repro_tenant_shard_degree",
              "repro_plan_cache_size", "quantile=\"0.5\"")
    for line in metrics_text.splitlines():
        if any(w in line for w in wanted):
            print(f"  {line}")

    print("\n== 4. DRIFT: honest table quiet, mis-scaled table loud ==")
    clear_plan_cache()
    plan = plan_network(specs, ResourceBudget())
    for site in plan.sites:          # discard a warm pass per site so the
        measure_planned_site(site, repeat=1)  # fit sees the warm regime
    table = collect_plan_samples([plan], repeat=2).fit()
    honest = DriftMonitor(table, threshold=2.0, min_observations=3)
    lying = DriftMonitor(mis_scaled_table(table, 8.0), threshold=2.0,
                         min_observations=3)
    for site in plan.sites:
        member = member_key(site.ip.name, site.precision_bits,
                            site.spec.native_bits)
        us = measure_planned_site(site, repeat=2)
        honest.observe(member, site.footprint, us)
        lying.observe(member, site.footprint, us)
    print(f"  honest table:    drifted={honest.drifted} "
          f"(mean rel err {honest.mean_rel_error:.2f})")
    print(f"  8x mis-scaled:   drifted={lying.drifted} "
          f"(mean rel err {lying.mean_rel_error:.2f})")
    assert not honest.drifted and lying.drifted
    lying.recalibrate()
    for site in plan.sites:
        member = member_key(site.ip.name, site.precision_bits,
                            site.spec.native_bits)
        lying.observe(member, site.footprint,
                      measure_planned_site(site, repeat=2))
    print(f"  after recalibrate(): drifted={lying.drifted} "
          f"(mean rel err {lying.mean_rel_error:.2f})")
    assert not lying.drifted
    print("  -> the stale cost model was caught from serving-shaped "
          "samples\n     and refit without replanning by hand")


if __name__ == "__main__":
    main()
