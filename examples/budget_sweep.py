"""Budget sweep — paper Table III as an executable experiment, extended
to the LM hot path: for each resource budget, report which IP the
selector assigns for (a) the paper's 3x3 conv, (b) an LM FFN matmul,
(c) attention at train/prefill/decode shapes.

    PYTHONPATH=src python examples/budget_sweep.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp

from repro.configs import get_config
from repro.core.resources import ResourceBudget
from repro.core.selector import (select_attention_ip, select_conv_ip,
                                 select_matmul_ip)

BUDGETS = {
    "ample": ResourceBudget(),
    "no_mxu": ResourceBudget(mxu_available=False),
    "vmem_16MiB": ResourceBudget(vmem_bytes=16 * 2**20),
    "int8_parallel": ResourceBudget(precision_bits=8,
                                    prefer_parallel_streams=True),
    "int8_serial": ResourceBudget(precision_bits=8),
}


def main():
    cfg = get_config("llama3.2-1b")
    D, F = cfg.d_model, cfg.d_ff
    print(f"arch for LM sites: {cfg.name} (D={D}, F={F})\n")
    hdr = (f"{'budget':<14s} {'conv3x3':<18s} {'ffn matmul':<20s} "
           f"{'attn train4k':<22s} {'attn decode32k'}")
    print(hdr)
    print("-" * len(hdr))
    for name, b in BUDGETS.items():
        try:
            conv = select_conv_ip((8, 64, 64, 16), (3, 3, 16, 32),
                                  dual=b.prefer_parallel_streams,
                                  dtype=jnp.int8, budget=b).name
        except ValueError:
            conv = "infeasible"
        dtype = jnp.int8 if b.precision_bits <= 8 else jnp.bfloat16
        try:
            mm = select_matmul_ip((4096, D), (D, F),
                                  dual=b.prefer_parallel_streams,
                                  dtype=dtype, budget=b).name
        except ValueError:
            mm = "infeasible"
        try:
            at = select_attention_ip((8, 32, 4096, 64), (8, 8, 4096, 64),
                                     budget=b).name
        except ValueError:
            at = "infeasible"
        try:
            ad = select_attention_ip((128, 32, 1, 64), (128, 8, 32768, 64),
                                     budget=b).name
        except ValueError:
            ad = "infeasible"
        print(f"{name:<14s} {conv.split('.')[-1]:<18s} "
              f"{mm.split('.')[-1]:<20s} {at.split('.')[-1]:<22s} "
              f"{ad.split('.')[-1]}")
    print("\nNote: 'no_mxu' steers every site to the logic-only (Conv1-"
          "analogue) members; 'int8_parallel' unlocks the packed dual-"
          "stream (Conv3-analogue) members — paper Table I, automated.")


if __name__ == "__main__":
    main()
