"""Budget sweep — paper Table III as an executable experiment, extended
to the LM hot path and planned as a WHOLE NETWORK: for each resource
budget, the paper's 3x3 conv, an LM FFN matmul, and attention at
train/decode shapes are mapped by one ``plan_network`` call — the four
sites share the envelope (partitioned proportional-to-cost with greedy
repair) instead of each seeing the full budget.

The FFN site carries a precision *ladder* (it may drop to w8a8): each
cell prints ``member@bits``, and a trailing ``*`` marks sites the
planner lowered below their native width to make the network fit —
the ladder engaging is visible per budget.

    PYTHONPATH=src python examples/budget_sweep.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp

from repro.configs import get_config
from repro.core.ip import SiteSpec
from repro.core.plan import plan_network, select_ip
from repro.core.resources import ResourceBudget

BUDGETS = {
    "ample": ResourceBudget(),
    "no_mxu": ResourceBudget(mxu_available=False),
    "vmem_16MiB": ResourceBudget(vmem_bytes=16 * 2**20),
    "vmem_6MiB": ResourceBudget(vmem_bytes=6 * 2**20),
    "int8_parallel": ResourceBudget(precision_bits=8,
                                    prefer_parallel_streams=True),
    "int8_serial": ResourceBudget(precision_bits=8),
}


def lm_network_specs(cfg, budget):
    D, F = cfg.d_model, cfg.d_ff
    dual = budget.prefer_parallel_streams
    mm_dtype = jnp.int8 if budget.precision_bits <= 8 else jnp.bfloat16
    return [
        SiteSpec.make("conv3x3", "conv2d", ((8, 64, 64, 16), (3, 3, 16, 32)),
                      jnp.int8, dual=dual),
        # the FFN tolerates w8a8: the planner may descend to 8 bits
        SiteSpec.make("ffn", "matmul", ((4096, D), (D, F)), mm_dtype,
                      ladder=(8,), dual=dual),
        SiteSpec.make("attn_train4k", "attention",
                      ((8, 32, 4096, 64), (8, 8, 4096, 64)), jnp.bfloat16),
        SiteSpec.make("attn_decode32k", "attention",
                      ((128, 32, 1, 64), (128, 8, 32768, 64)), jnp.bfloat16),
    ]


def _cell(site):
    """member@bits, '*' when the precision ladder lowered the site."""
    return (f"{site.ip.name.split('.')[-1]}@{site.precision_bits}b"
            + ("*" if site.lowered else ""))


def main():
    cfg = get_config("llama3.2-1b")
    print(f"arch for LM sites: {cfg.name} (D={cfg.d_model}, F={cfg.d_ff})\n")
    hdr = (f"{'budget':<14s} {'conv3x3':<18s} {'ffn matmul':<20s} "
           f"{'attn train4k':<22s} {'attn decode32k'}")
    print(hdr)
    print("-" * len(hdr))
    for name, b in BUDGETS.items():
        specs = lm_network_specs(cfg, b)
        try:
            plan = plan_network(specs, b)
            cells = [_cell(plan.site(s.name)) for s in specs]
        except ValueError:
            # no joint plan: fall back to per-site full-budget selection
            # so the table shows WHICH sites cannot run
            cells = []
            for s in specs:
                try:
                    cells.append(
                        select_ip(s.family, s, budget=b).name.split(".")[-1]
                        + "!")
                except ValueError:
                    cells.append("infeasible")
        print(f"{name:<14s} {cells[0]:<18s} {cells[1]:<20s} "
              f"{cells[2]:<22s} {cells[3]}")
    print("\nNote: 'no_mxu' steers every site to the logic-only (Conv1-"
          "analogue) members; 'int8_parallel' unlocks the packed dual-"
          "stream (Conv3-analogue) members — paper Table I, automated. "
          "A '*' marks sites the precision ladder lowered below native "
          "width (e.g. the FFN dropping to w8a8 under 'vmem_6MiB'); a "
          "'!' marks per-site fallback choices when no joint "
          "whole-network plan exists under the budget.")


if __name__ == "__main__":
    main()
