"""Quickstart — the paper's own scenario: a small CNN whose convolution
layers are implemented by resource-adaptive IPs.

    PYTHONPATH=src python examples/quickstart.py

For three deployment budgets (ample / MXU-starved / 8-bit parallel) the
selector assigns a conv IP per layer, the network runs int8 inference
through the selected Pallas kernels (interpret mode on CPU), and all
three deployments are verified to produce identical logits — resource
adaptation changes the *implementation*, never the *result* (the
paper's central promise).
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core.resources import ResourceBudget
from repro.core.selector import select_conv_ip
from repro.kernels.conv2d.ops import conv2d

LAYERS = [  # (cin, cout, kernel) — an int8 feature stack big enough
    (16, 32, 3),   # that the MXU IP wins under an ample budget while
    (32, 64, 3),   # the VPU IP takes over when the MXU is spoken for
    (64, 64, 3),
]

BUDGETS = {
    "ample": ResourceBudget(),
    "mxu_starved": ResourceBudget(mxu_available=False),
    "vmem_tight": ResourceBudget(vmem_bytes=1 * 2**20),
}


def relu_pool(x):
    x = jnp.maximum(x, 0)
    n, h, w, c = x.shape
    x = x[:, : h // 2 * 2, : w // 2 * 2, :]
    x = x.reshape(n, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))
    return jnp.clip(x // 8, -128, 127).astype(jnp.int8)  # requantize


def main():
    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.integers(-128, 128, (2, 48, 48, 16),
                                   dtype=np.int8))
    weights = [jnp.asarray(rng.integers(-16, 16, (k, k, cin, cout),
                                        dtype=np.int8))
               for cin, cout, k in LAYERS]

    results = {}
    for bname, budget in BUDGETS.items():
        print(f"\n=== budget: {bname} ===")
        x = img
        for li, ((cin, cout, k), w) in enumerate(zip(LAYERS, weights)):
            ip = select_conv_ip(x.shape, w.shape, dual=False,
                                dtype=jnp.int8, budget=budget)
            fp = ip.footprint(*x.shape, k, k, cout, itemsize=1)
            print(f"  layer {li}: {x.shape} -> {ip.name:<22s} "
                  f"vmem={fp.vmem_bytes/1024:8.1f}KiB mxu={fp.mxu_passes:<4d} "
                  f"vpu={fp.vpu_ops:.2e}")
            y = conv2d(x, w, ip=ip.name)
            x = relu_pool(y)
        results[bname] = np.asarray(x)
        print(f"  output: {x.shape}, sum={int(np.asarray(x).sum())}")

    base = results["ample"]
    for bname, out in results.items():
        assert np.array_equal(out, base), bname
    print("\nall budgets produced IDENTICAL outputs — adaptation changed "
          "the implementation, not the math. ✓")


if __name__ == "__main__":
    main()
