"""Calibration demo — teach the planner what the stopwatch knows.

The planner's analytical cost model (est-cycles) can disagree with
measured wall-clock; the calibration loop closes that gap in three
moves, narrated here on a 3-layer CNN:

1. SAMPLE  — plan the network, run every distinct planned site
   standalone, and record (member, footprint, measured us) samples.
2. FIT     — per-member affine fits over the footprint's analytical
   axes (compute cycles, HBM bytes), global fallback under 3 samples.
3. RE-PLAN — the same ``plan_network`` call with ``calibration=`` now
   ranks members and fusion groups by measured cost; a synthetic
   "fused is slow on this machine" table demonstrably flips the
   fused/unfused decision while numerics stay identical.

The table round-trips through versioned JSON bit-exactly, so a fitted
table ships with a deployment.  See docs/adaptive_ips.md,
"Calibration contract", and benchmarks/run.py::table_calibration for
the asserted end-to-end loop.

    PYTHONPATH=src python examples/calibration_demo.py
"""
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

import numpy as np  # noqa: E402

from repro.core.calibrate_cost import (AffineFit, CalibrationTable,  # noqa: E402
                                       collect_plan_samples, member_key)
from repro.core.plan import clear_plan_cache, plan_network  # noqa: E402
from repro.core.resources import ResourceBudget  # noqa: E402
from repro.models.blocks import cnn_block_site_specs  # noqa: E402

LAYERS = [(8, 16), (16, 32), (32, 32)]


def network_specs(ladder=()):
    specs, shape = [], (2, 32, 32, LAYERS[0][0])
    for li, (cin, cout) in enumerate(LAYERS):
        layer, out = cnn_block_site_specs(
            shape, (3, 3, cin, cout), x_dtype="float32", pool_mode="max",
            activation="relu", site=f"layer{li}", ladder=ladder)
        specs += layer
        shape = out.shape
    return tuple(specs)


def describe(tag, plan, table=None):
    fams = [s.spec.family for s in plan.sites]
    fused = fams.count("cnn_fused")
    print(f"  {tag:<22} {len(plan.sites)} sites, {fused} fused; "
          f"est={plan.total_cycles:.3e} cyc, "
          f"calibrated={plan.calibrated_cycles(table):.3e} cyc")


def main():
    budget = ResourceBudget()
    specs = network_specs(ladder=(16, 8))
    clear_plan_cache()

    print("== 1. SAMPLE: measure every distinct site the analytical "
          "plans chose ==")
    plans = [plan_network(specs, budget, fuse=f) for f in (False, True)]
    table = collect_plan_samples(plans, repeat=3)
    print(f"  {table.sample_count()} samples over "
          f"{len({s.member for s in table.samples})} executed members")

    print("== 2. FIT: per-member affine models over (compute, hbm) ==")
    table.fit()
    for m, f in sorted(table.fits.items()):
        print(f"  {m:<28} us = {f.us_per_compute_cycle:.3g}*cyc "
              f"+ {f.us_per_hbm_byte:.3g}*B + {f.overhead_us:.3g}")
    text = table.to_json()
    assert CalibrationTable.from_json(text).to_json() == text
    print(f"  JSON round-trip bit-exact ({len(text)} bytes, "
          f"fingerprint {table.fingerprint()})")

    print("== 3. RE-PLAN: the same call, measured objective ==")
    describe("analytical fuse=True", plans[1], table)
    cal = plan_network(specs, budget, fuse=True, calibration=table)
    describe("calibrated fuse=True", cal, table)

    print("\n== counterfactual: a host where the fused member measures "
          "slow ==")
    slow = CalibrationTable(fits={
        member_key(s.ip.name, s.precision_bits, s.spec.native_bits):
            AffineFit(0.0, 0.0, 1e6, 3)
        for p in plans for s in p.sites if s.spec.family == "cnn_fused"})
    flipped = plan_network(specs, budget, fuse=True, calibration=slow)
    describe("calibrated fuse=True", flipped, slow)
    assert all(s.spec.family != "cnn_fused" for s in flipped.sites), \
        "a measured-slow fused member must unfuse the plan"
    print("  -> the planner unfused every block: it optimizes what was "
          "measured,\n     while feasibility (fits, floors) stayed "
          "analytical")

    # numerics never depend on the cost model that picked the plan
    from repro.models.blocks import apply_cnn_block
    rng = np.random.default_rng(0)
    x = rng.normal(size=(2, 32, 32, 8)).astype(np.float32)
    ws = [rng.normal(0, (9 * cin) ** -0.5, (3, 3, cin, cout))
          .astype(np.float32) for cin, cout in LAYERS]

    def run(network):
        y = np.asarray(x)
        import jax.numpy as jnp
        y = jnp.asarray(y)
        for li, w in enumerate(ws):
            y = apply_cnn_block({"w": w}, y, pool_mode="max",
                                activation="relu", site=f"layer{li}",
                                network=network, ladder=(16, 8))
        return np.asarray(y)

    np.testing.assert_array_equal(run(cal), run(flipped))
    print("  -> identical outputs under both cost models (budget/"
          "calibration\n     change the implementation, never the result)")


if __name__ == "__main__":
    main()
