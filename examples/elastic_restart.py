"""Fault-tolerance demo: crash mid-run, restart, resume exactly.

    PYTHONPATH=src python examples/elastic_restart.py

Phase 1 trains with an injected failure at step 25 (exit code 17).
Phase 2 relaunches the identical command: it restores the last committed
checkpoint, skips the data pipeline ahead, and finishes. The final
losses match an uninterrupted gold run (see tests/test_integration.py
for the assertion version).
"""
import shutil
import subprocess
import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def run(extra, check=True):
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "olmo-1b", "--smoke", "--steps", "40",
           "--batch", "4", "--seq", "32", "--ckpt-every", "10",
           "--ckpt-dir", CKPT] + extra
    print(f"$ {' '.join(cmd[2:])}")
    p = subprocess.run(cmd, env={"PYTHONPATH": str(REPO / "src")},
                       capture_output=True, text=True)
    print(p.stdout)
    if check and p.returncode != 0:
        print(p.stderr)
        raise SystemExit(p.returncode)
    return p


if __name__ == "__main__":
    CKPT = tempfile.mkdtemp(prefix="elastic_")
    try:
        print("=== phase 1: train with injected failure at step 25 ===")
        p = run(["--simulate-failure", "25"], check=False)
        assert p.returncode == 17, "expected the injected failure"
        print("=== phase 2: relaunch — restores and finishes ===")
        p = run([])
        assert "restored step" in p.stdout
        print("resume-after-failure ✓")
    finally:
        shutil.rmtree(CKPT, ignore_errors=True)
