"""Elastic-restart demo — plan-preserving serving recovery.

    PYTHONPATH=src python examples/elastic_restart.py

A two-tenant SLO deployment serves a few waves, snapshots its full
state (params, plan cache, arbiter grants, SLO specs), then the worker
"dies" (every in-memory planner memo is wiped — what a real process
death destroys).  Recovery rebuilds the server from the checkpoint and
serves the next wave; the demo's claim, asserted at the end, is that
the restarted deployment re-plans **zero** graphs cold: the plan-cache
import plus bit-identical grant restore means every post-crash batch
hits the imported cache instead of paying the restart storm.

The same scenario is CI-gated in ``benchmarks/run.py::table_slo``
(``recovery_cold_plans=0``) and unit-tested in
``tests/test_recovery.py`` — this is the narrated walk-through.
"""
import shutil
import sys
import tempfile
from pathlib import Path

import jax
import numpy as np

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.core.plan import STATS, plan_cache_stats           # noqa: E402
from repro.core.resources import ResourceBudget               # noqa: E402
from repro.models.frontends import init_cnn_frontend          # noqa: E402
from repro.runtime import (AdaptiveServer, SLOScheduler,      # noqa: E402
                           SLOSpec, recover_server,
                           simulate_worker_death, snapshot_server)


def deployment():
    srv = AdaptiveServer(ResourceBudget(vpu_ops_budget=15_000_000),
                         policy="demand", max_batch=4, slo_pressure=2.0)
    sched = SLOScheduler(srv)
    sched.register(
        "vision-heavy",
        init_cnn_frontend(jax.random.PRNGKey(0), channels=(8, 16),
                          d_model=32),
        (32, 32, 8), slo=SLOSpec(deadline_s=5.0, priority=0))
    sched.register(
        "edge-light",
        init_cnn_frontend(jax.random.PRNGKey(1), channels=(6, 12),
                          d_model=16),
        (24, 24, 6), activation="tanh", ladder=(16, 8),
        slo=SLOSpec(deadline_s=1.0, priority=1))
    return srv, sched


def wave(sched, rng):
    for _ in range(8):
        sched.submit("vision-heavy",
                     rng.normal(size=(32, 32, 8)).astype(np.float32))
    for _ in range(4):
        sched.submit("edge-light",
                     rng.normal(size=(24, 24, 6)).astype(np.float32))
    return sched.run()


def main():
    ckpt = tempfile.mkdtemp(prefix="elastic_restart_")
    try:
        print("=== phase 1: serve ===")
        srv, sched = deployment()
        rng = np.random.default_rng(0)
        # two identical waves settle the demand EWMA at the mix's fixed
        # point — the post-crash wave then re-arbitrates to the SAME
        # grants, keeping every slice-budget cache key identical
        for i in (1, 2):
            comps = wave(sched, rng)
            print(f"wave {i}: served {len(comps)} requests; grants: "
                  + ", ".join(f"{n}={t.granted:.3f}"
                              for n, t in srv.tenants.items()))
        cache = plan_cache_stats()
        print(f"plan cache: {cache['size']} plans, "
              f"hit rate {cache['hit_rate']:.2f}")

        print("\n=== phase 2: snapshot, then the worker dies ===")
        snapshot_server(srv, ckpt, step=1, scheduler=sched)
        print(f"snapshot committed to {ckpt}")
        simulate_worker_death()
        print(f"worker died: plan cache now holds "
              f"{plan_cache_stats()['size']} plans")

        print("\n=== phase 3: recover and serve on ===")
        misses_before = STATS.plan_misses
        srv2, sched2 = recover_server(ckpt)
        print("restored: tenants="
              + ", ".join(f"{n} (grant {t.granted:.3f})"
                          for n, t in srv2.tenants.items())
              + "; SLOs="
              + str({n: s.deadline_s for n, s in sched2.slos.items()}))
        comps = wave(sched2, np.random.default_rng(0))
        cold = STATS.plan_misses - misses_before
        print(f"post-crash wave: served {len(comps)} requests, "
              f"{cold} cold plans")
        assert cold == 0, "recovery must re-plan nothing cold"
        print("\nplan-preserving restart ✓ (zero cold plans)")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
