"""Batched serving example: continuous batching over 4 slots.

    PYTHONPATH=src python examples/serve_lm.py [--arch llama3.2-1b]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.serve import serve  # noqa: E402

if __name__ == "__main__":
    arch = "llama3.2-1b"
    if "--arch" in sys.argv:
        arch = sys.argv[sys.argv.index("--arch") + 1]
    serve(["--arch", arch, "--smoke", "--requests", "10", "--slots", "4",
           "--prompt-len", "12", "--max-new", "12", "--max-len", "48"])
