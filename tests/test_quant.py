"""Fixed-point precision subsystem: quantization core, per-family
quantized kernels vs the oracles, calibration, the precision ladder in
the planner, and mixed-precision plan execution."""
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ip import SiteSpec
from repro.core.plan import NetworkPlan, plan_network, plan_single
from repro.core.resources import ResourceBudget
from repro.quant import (Calibrator, MIN_SCALE, dequantize, fake_quant,
                         max_rel_error, quantization_error, quantize_acts,
                         quantize_weights, quantized_activation,
                         quantized_conv2d, quantized_matmul,
                         quantized_pool2d, relative_error)

CONV_X = (2, 16, 16, 8)
CONV_W = (3, 3, 8, 16)


def _randn(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


# --------------------------------------------------------------------------
# Zero-scale regression (satellite): all-zero tensors must round-trip
# exactly instead of producing NaNs.
# --------------------------------------------------------------------------
def test_all_zero_acts_quantize_without_nan():
    z = jnp.zeros((4, 8))
    q = quantize_acts(z)
    assert float(q.scale) >= MIN_SCALE / 127
    deq = dequantize(q)
    assert not bool(jnp.isnan(deq).any())
    np.testing.assert_array_equal(np.asarray(deq), np.zeros((4, 8)))


def test_all_zero_weight_channel_quantizes_without_nan(rng):
    w = _randn(rng, (8, 4))
    w = w.at[:, 2].set(0.0)     # one dead output channel
    wq = quantize_weights(w)
    deq = dequantize(wq)
    assert not bool(jnp.isnan(deq).any())
    np.testing.assert_array_equal(np.asarray(deq[:, 2]), np.zeros(8))
    assert quantization_error(jnp.zeros((8, 4))) == 0.0


def test_quantize_bits_parameter():
    x = jnp.linspace(-3.0, 3.0, 64)
    q8, q16 = quantize_acts(x, bits=8), quantize_acts(x, bits=16)
    assert q8.q.dtype == jnp.int8 and q16.q.dtype == jnp.int16
    e8 = relative_error(dequantize(q8), x)
    e16 = relative_error(dequantize(q16), x)
    assert e16 < e8 < 5e-2
    with pytest.raises(ValueError, match="unsupported quantization width"):
        quantize_acts(x, bits=12)


# --------------------------------------------------------------------------
# Quantized kernels vs the family oracles (per-kernel accuracy bounds)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("bits,bound", [(8, 5e-2), (16, 1e-3)])
def test_quantized_conv2d_close_to_ref(rng, bits, bound):
    from repro.kernels.conv2d.ref import conv2d_ref
    x = _randn(rng, CONV_X)
    w = _randn(rng, CONV_W, scale=0.1)
    ref = conv2d_ref(x, w)
    for ip in ("ip1_vpu", "ip2_mxu"):
        y = quantized_conv2d(x, w, bits=bits, ip=ip)
        assert y.dtype == jnp.float32
        assert relative_error(y, ref) < bound, (ip, bits)


@pytest.mark.parametrize("mode", ["max", "avg"])
def test_quantized_pool2d_close_to_ref(rng, mode):
    from repro.kernels.pool2d.ref import pool2d_ref
    x = _randn(rng, (2, 8, 8, 16))
    ref = pool2d_ref(x, window=(2, 2), mode=mode)
    for ip in ("pool_vpu", "pool_im2col"):
        y = quantized_pool2d(x, window=(2, 2), mode=mode, bits=8, ip=ip)
        assert relative_error(y, ref) < 5e-2, (ip, mode)


@pytest.mark.parametrize("kind", ["relu", "tanh", "sigmoid"])
def test_quantized_activation_close_to_ref(rng, kind):
    from repro.kernels.activation.ref import activation_ref
    x = _randn(rng, (2, 8, 8, 4), scale=2.0)
    ref = activation_ref(x, kind=kind)
    y = quantized_activation(x, kind=kind, bits=8, ip="act_vpu")
    assert relative_error(y, ref) < 5e-2, kind


def test_quantized_matmul_close_to_ref(rng):
    a = _randn(rng, (32, 64))
    b = _randn(rng, (64, 48))
    ref = a @ b
    for ip in ("mm_mxu", "mm_vpu"):
        y = quantized_matmul(a, b, bits=8, ip=ip)
        assert relative_error(y, ref) < 5e-2, ip
    assert relative_error(quantized_matmul(a, b, bits=16, ip="mm_mxu"),
                          ref) < 1e-3


# --------------------------------------------------------------------------
# Calibration
# --------------------------------------------------------------------------
def test_calibrator_running_max_and_scale(rng):
    cal = Calibrator()
    batches = [_randn(rng, (16, 8), scale=s) for s in (0.5, 2.0, 1.0)]
    for b in batches:
        cal.observe("ffn.in", b)
    worst = max(float(jnp.max(jnp.abs(b))) for b in batches)
    assert cal.amax("ffn.in") == pytest.approx(worst)
    assert cal.scale("ffn.in", bits=8) == pytest.approx(worst / 127)
    q = cal.quantize("ffn.in", batches[0])
    assert relative_error(dequantize(q), batches[0]) < 5e-2
    with pytest.raises(KeyError, match="never observed"):
        cal.scale("unknown")


def test_calibrator_ema_and_round_trip():
    cal = Calibrator(momentum=0.5)
    cal.observe("x", jnp.asarray([1.0]))
    cal.observe("x", jnp.asarray([3.0]))
    assert cal.amax("x") == pytest.approx(0.5 * 1.0 + 0.5 * 3.0)
    runmax = Calibrator()
    runmax.observe("x", jnp.asarray([1.0]))
    runmax.observe("x", jnp.asarray([3.0]))
    assert runmax.amax("x") == pytest.approx(3.0)
    restored = Calibrator.from_dict(cal.to_dict())
    assert restored.amax("x") == pytest.approx(cal.amax("x"))
    assert restored.momentum == cal.momentum


# --------------------------------------------------------------------------
# The precision ladder in the planner
# --------------------------------------------------------------------------
def _conv_site(ladder=(), name="c.conv"):
    return SiteSpec.make(name, "conv2d", (CONV_X, CONV_W), "float32",
                         ladder=ladder, dual=False)


def test_ladder_descends_only_on_failure():
    ample = ResourceBudget()
    assert plan_single(_conv_site(ladder=(16, 8)), ample).precision_bits == 32
    tight = ResourceBudget(vmem_bytes=17 * 1024)
    with pytest.raises(ValueError, match="no feasible"):
        plan_single(_conv_site(), tight)
    planned = plan_single(_conv_site(ladder=(16, 8)), tight)
    assert planned.precision_bits == 8 and planned.lowered
    mid = ResourceBudget(vmem_bytes=20 * 1024)
    assert plan_single(_conv_site(ladder=(16, 8)), mid).precision_bits == 16


def test_ladder_unlocks_packed_dual_member():
    """A bf16 dual conv site cannot use ip3_packed (8-bit ceiling); with
    a ladder and no MXU, lowering to int8 is the only way to run."""
    spec = SiteSpec.make("d.conv", "conv2d", (CONV_X, CONV_W), "bfloat16",
                         ladder=(8,), dual=True)
    no_mxu = ResourceBudget(mxu_available=False)
    planned = plan_single(spec, no_mxu)
    assert planned.ip.name == "conv2d.ip3_packed"
    assert planned.precision_bits == 8
    bare = SiteSpec.make("d2.conv", "conv2d", (CONV_X, CONV_W), "bfloat16",
                         dual=True)
    with pytest.raises(ValueError, match="no feasible IP"):
        plan_single(bare, no_mxu)


def test_attention_is_never_lowered():
    spec = SiteSpec.make("a.attn", "attention",
                         ((2, 8, 128, 64), (2, 2, 128, 64)), "bfloat16",
                         ladder=(8,))
    planned = plan_single(spec, ResourceBudget())
    assert planned.precision_bits == spec.native_bits
    assert not planned.lowered


def test_native_int8_site_is_not_lowered():
    spec = SiteSpec.make("i8.conv", "conv2d", (CONV_X, CONV_W), "int8",
                         ladder=(16, 8), dual=False)
    planned = plan_single(spec, ResourceBudget())
    assert planned.precision_bits == 8 and not planned.lowered


def test_mixed_precision_plan_json_round_trip():
    specs = [
        _conv_site(ladder=(16, 8), name="m.conv"),
        SiteSpec.make("m.pool", "pool2d", ((2, 14, 14, 16),), "float32",
                      ladder=(16, 8), window=(2, 2), mode="max"),
        SiteSpec.make("m.act", "activation", ((2, 7, 7, 16),), "float32",
                      kind="relu"),
    ]
    # fuse=False: the squeeze that forces mixed precision targets the
    # per-op footprints (the fused group fits 40 KiB without lowering)
    plan = plan_network(specs, ResourceBudget(vmem_bytes=40 * 1024),
                        fuse=False)
    bits = {s.spec.name: s.precision_bits for s in plan.sites}
    assert any(s.lowered for s in plan.sites)
    assert len(set(bits.values())) > 1      # genuinely mixed precisions
    restored = NetworkPlan.from_json(plan.to_json())
    assert restored == plan
    for name in plan:
        assert restored.precision_of(name) == bits[name]
        assert restored.site(name).spec.ladder == plan.site(name).spec.ladder


def test_sitespec_ladder_round_trip_and_validation():
    spec = _conv_site(ladder=(8, 16))
    assert spec.ladder == (16, 8)           # normalized descending
    back = SiteSpec.from_dict(spec.to_dict())
    assert back == spec
    hash(back)
    with pytest.raises(ValueError, match="unsupported ladder width"):
        SiteSpec.make("bad", "conv2d", (CONV_X, CONV_W), "float32",
                      ladder=(12,), dual=False)


# --------------------------------------------------------------------------
# Mixed-precision execution
# --------------------------------------------------------------------------
def test_ops_wrapper_executes_lowered_plan(rng):
    from repro.kernels.conv2d.ops import conv2d
    from repro.kernels.conv2d.ref import conv2d_ref
    x = _randn(rng, CONV_X)
    w = _randn(rng, CONV_W, scale=0.1)
    ref = conv2d_ref(x, w)
    y = conv2d(x, w, budget=ResourceBudget(vmem_bytes=17 * 1024),
               ladder=(16, 8))
    assert y.dtype == jnp.float32
    assert relative_error(y, ref) < 5e-2


def test_apply_cnn_block_mixed_precision_end_to_end(rng):
    from repro.models.blocks import apply_cnn_block, init_cnn_block
    block = init_cnn_block(jax.random.PRNGKey(0), cin=8, cout=16, k=3)
    x = _randn(rng, CONV_X)
    y_f32 = apply_cnn_block(block, x, activation="relu")
    # fuse=False below: 28 KiB starves the per-op sites (the fused
    # group's smaller working set would still fit at f32)
    tight = ResourceBudget(vmem_bytes=28 * 1024)
    with pytest.raises(ValueError, match="no feasible"):
        apply_cnn_block(block, x, budget=tight, activation="relu",
                        fuse=False)
    report = {}
    y = apply_cnn_block(block, x, budget=tight, ladder=(16, 8),
                        activation="relu", quant_report=report, fuse=False)
    assert y.dtype == y_f32.dtype and y.shape == y_f32.shape
    assert relative_error(y, y_f32) < 5e-2
    # the report covers every site and every quantized site is bounded
    assert set(report) == {"cnn_block.conv", "cnn_block.pool",
                           "cnn_block.act"}
    assert any(r.lowered for r in report.values())
    assert max_rel_error(report) < 5e-2
    for r in report.values():
        assert r.rel_error < 5e-2


def test_apply_cnn_frontend_with_ladder(rng):
    from repro.models.frontends import apply_cnn_frontend, init_cnn_frontend
    p = init_cnn_frontend(jax.random.PRNGKey(1), channels=(3, 8, 16),
                          d_model=32)
    imgs = _randn(rng, (2, 16, 16, 3))
    y_f32 = apply_cnn_frontend(p, imgs)
    report = {}
    y = apply_cnn_frontend(p, imgs, budget=ResourceBudget(vmem_bytes=64
                                                          * 1024),
                           ladder=(16, 8), quant_report=report, fuse=False)
    assert y.shape == y_f32.shape
    assert relative_error(y, y_f32) < 5e-2
    assert len(report) == 6                 # 2 blocks x 3 sites


def test_fake_quant_precision_ordering(rng):
    w = _randn(rng, (32, 16))
    e8 = relative_error(fake_quant(w, bits=8, axis=-1), w)
    e16 = relative_error(fake_quant(w, bits=16, axis=-1), w)
    assert e16 < e8


# --------------------------------------------------------------------------
# table_precision acceptance (benchmarks/run.py)
# --------------------------------------------------------------------------
def _load_bench():
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "benchmarks" / "run.py")
    spec = importlib.util.spec_from_file_location("bench_run_quant", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_table_precision_ladder_wins_and_errors_bounded():
    bench = _load_bench()
    bench.table_precision()
    rows = [d for n, _, d in bench.ROWS if n.startswith("table_precision.")]
    assert rows
    # at least one budget where the f32-only plan is infeasible (or
    # slower) and the ladder plan runs
    assert any("f32=x" in d and "ladder=x" not in d for d in rows), rows
    assert any("ladder_wins=1" in d for d in rows), rows
    # every executed row reports bounded per-site error
    executed = [d for d in rows if "max_rel_err" in d]
    assert executed
    assert all("err_ok=1" in d for d in executed), executed
