"""Resource-driven selector: feasibility + the paper's Table I logic,
as properties over random budgets."""
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.library import (ACTIVATION, ATTENTION, CONV2D, MATMUL,
                                POOL2D, get_ip)
from repro.core.resources import Footprint, ResourceBudget
from repro.core.selector import (select_attention_ip, select_conv_ip,
                                 select_matmul_ip)

CONV_SHAPE = ((2, 32, 32, 3), (3, 3, 3, 16))


def test_no_mxu_budget_forces_conv1():
    ip = select_conv_ip(*CONV_SHAPE, dual=False, dtype=jnp.int8,
                        budget=ResourceBudget(mxu_available=False))
    assert ip.name == "conv2d.ip1_vpu"


def test_logic_starved_budget_forces_conv2():
    """Tight VPU budget (paper: 'limited logic resources') -> DSP IP.
    Budget admits ip2's im2col bookkeeping (~49K vector ops) but not
    ip1's full multiply-accumulate load (~1.5M)."""
    ip = select_conv_ip(*CONV_SHAPE, dual=False, dtype=jnp.int8,
                        budget=ResourceBudget(vpu_ops_budget=100_000))
    assert ip.name == "conv2d.ip2_mxu"


def test_dual_int8_prefers_packed_under_mxu_pressure():
    ip = select_conv_ip(*CONV_SHAPE, dual=True, dtype=jnp.int8,
                        budget=ResourceBudget(precision_bits=8,
                                              mxu_passes_budget=1))
    assert ip.name == "conv2d.ip3_packed"


def test_dual_wide_precision_forces_conv4():
    """16-bit operands make Conv3 infeasible (paper Table I)."""
    ip = select_conv_ip(*CONV_SHAPE, dual=True, dtype=jnp.int16,
                        budget=ResourceBudget(precision_bits=16))
    assert ip.name == "conv2d.ip4_dual"


def test_matmul_defaults_to_mxu_at_scale():
    ip = select_matmul_ip((512, 512), (512, 512), dual=False,
                          dtype=jnp.bfloat16)
    assert ip.name == "matmul.mm_mxu"


def test_attention_decode_routing():
    assert select_attention_ip((2, 16, 1, 128), (2, 4, 32768, 128)).name \
        == "attention.attn_decode"
    assert select_attention_ip((2, 16, 4096, 128), (2, 4, 4096, 128)).name \
        == "attention.attn_flash"


def test_infeasible_budget_raises():
    with pytest.raises(ValueError, match="no feasible IP"):
        select_conv_ip(*CONV_SHAPE, dual=True, dtype=jnp.int16,
                       budget=ResourceBudget(precision_bits=16,
                                             mxu_available=False))


# --------------------------------------------------------------------------
# Properties
# --------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(vmem_mb=st.integers(1, 256), mxu=st.booleans(),
       bits=st.sampled_from([8, 16]), parallel=st.booleans())
def test_selection_always_feasible(vmem_mb, mxu, bits, parallel):
    budget = ResourceBudget(vmem_bytes=vmem_mb * 2**20, mxu_available=mxu,
                            precision_bits=bits,
                            prefer_parallel_streams=parallel)
    dtype = jnp.int8 if bits == 8 else jnp.int16
    for dual in (False, True):
        try:
            ip = select_conv_ip(*CONV_SHAPE, dual=dual, dtype=dtype,
                                budget=budget)
        except ValueError:
            continue  # "no feasible IP" is an allowed outcome
        n, h, w, cin = CONV_SHAPE[0]
        kh, kw, _, cout = CONV_SHAPE[1]
        fp = ip.footprint(n, h, w, cin, kh, kw, cout,
                          itemsize=jnp.dtype(dtype).itemsize)
        assert fp.fits(budget), (ip.name, fp, budget)
        assert bits <= fp.max_operand_bits


@settings(max_examples=30, deadline=None)
@given(m=st.integers(16, 2048), k=st.integers(16, 2048),
       n=st.integers(16, 2048))
def test_matmul_selection_feasible(m, k, n):
    ip = select_matmul_ip((m, k), (k, n), dual=False, dtype=jnp.bfloat16)
    fp = ip.footprint(m, k, n, itemsize=2)
    assert fp.fits(ResourceBudget())


def test_library_registry_integrity():
    for fam in (CONV2D, POOL2D, ACTIVATION, MATMUL, ATTENTION):
        for ip in fam:
            assert ip.name.startswith(fam.name + ".")
            assert callable(ip.impl)
    assert get_ip("conv2d.ip3_packed").max_operand_bits == 8
    assert get_ip("conv2d.ip3_packed").outputs_per_pass == 2
    assert get_ip("matmul.mm_vpu").uses_mxu is False
    assert get_ip("pool2d.pool_vpu").uses_mxu is False
    assert get_ip("activation.act_lut").max_operand_bits == 8
