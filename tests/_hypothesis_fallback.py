"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The tier-1 suite must collect and run in environments without the
``hypothesis`` test extra (the real library is declared in
``pyproject.toml`` under ``[project.optional-dependencies] test`` and is
used when present).  This module mimics the slice of the API the tests
use — ``given``, ``settings``, and the ``integers`` / ``booleans`` /
``sampled_from`` strategies — by running each property a fixed number of
times over a seeded PRNG.  It is installed into ``sys.modules`` by
``conftest.py`` only when the real package is missing.
"""
from __future__ import annotations

import inspect
import random
import types

__version__ = "0.0-fallback"

# How many deterministic examples to draw per property.  Kept small:
# the fallback is a smoke-level property check, not a shrinking fuzzer.
MAX_FALLBACK_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value, max_value):
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def booleans():
    return _Strategy(lambda rnd: bool(rnd.getrandbits(1)))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rnd: rnd.choice(elements))


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))


def lists(elements, min_size=0, max_size=None, **_kw):
    hi = max_size if max_size is not None else min_size + 10

    def draw(rnd):
        n = rnd.randint(min_size, hi)
        return [elements.draw(rnd) for _ in range(n)]

    return _Strategy(draw)


strategies = types.SimpleNamespace(
    integers=integers, booleans=booleans, sampled_from=sampled_from,
    floats=floats, lists=lists)


def settings(**kwargs):
    def deco(fn):
        fn._fallback_settings = kwargs
        return fn
    return deco


def given(**strats):
    def deco(fn):
        def wrapper(*args, **kwargs):
            # @settings may sit outside @given (attr lands on this wrapper)
            # or inside it (attr landed on the raw fn) — honor both.
            cfg = getattr(wrapper, "_fallback_settings",
                          getattr(fn, "_fallback_settings", {}))
            n = min(int(cfg.get("max_examples", MAX_FALLBACK_EXAMPLES)),
                    MAX_FALLBACK_EXAMPLES)
            rnd = random.Random(0xADA9)
            for _ in range(n):
                drawn = {k: s.draw(rnd) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)

        # Pytest resolves fixtures from the signature: expose the original
        # parameters minus the ones @given supplies, so strategy kwargs are
        # not mistaken for fixtures.
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items() if name not in strats]
        wrapper.__signature__ = sig.replace(parameters=params)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
