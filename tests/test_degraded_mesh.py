"""Degraded-mesh survival: the degree ladder, post-loss grant previews
(ladder snap), ``BudgetArbiter.on_device_loss``, spare-plan pre-warming
against the exact keys the degraded mesh re-plans under, and the
end-to-end lose-a-device-keep-serving path (subprocess: 2 forced host
devices).  Degradation ordering: the degree ladder descends BEFORE the
precision ladder — survivors keep the full per-device budget, so plans
never lower on a device loss."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

from repro.core.plan import (STATS, clear_plan_cache, plan_cache_contains,
                             plan_network, replan)
from repro.core.resources import MeshSpec, ResourceBudget
from repro.core.shard import degree_ladder
from repro.models.frontends import init_cnn_frontend
from repro.obs import EVENTS
from repro.runtime import AdaptiveServer
from repro.runtime.arbiter import BudgetArbiter
from repro.runtime.recovery import cold_replans_since

REPO = Path(__file__).resolve().parents[1]
DEVICE = ResourceBudget(vpu_ops_budget=15_000_000)


def run_sub(body: str, n_dev: int = 2, timeout: int = 420) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_dev}")
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=str(REPO / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# --------------------------------------------------------------------------
# The degree ladder
# --------------------------------------------------------------------------
def test_degree_ladder_is_divisors_descending():
    assert degree_ladder(12) == (12, 6, 4, 3, 2, 1)
    assert degree_ladder(1) == (1,)
    assert degree_ladder(7) == (7, 1)


def test_degree_ladder_survivors_filter():
    assert degree_ladder(12, survivors=5) == (4, 3, 2, 1)
    assert degree_ladder(4, survivors=4) == (4, 2, 1)
    # a single survivor always leaves the replicated rung
    assert degree_ladder(16, survivors=1) == (1,)


def test_degree_ladder_validation():
    with pytest.raises(ValueError, match="degree"):
        degree_ladder(0)
    with pytest.raises(ValueError, match="survivors"):
        degree_ladder(4, survivors=0)


def test_every_rung_keeps_batches_tileable():
    """The point of the ladder: any batch size that tiled at the original
    degree still tiles at every rung — no batch shape strands."""
    for degree in (2, 4, 6, 8, 12, 16):
        for batch in range(degree, 4 * degree + 1, degree):
            for rung in degree_ladder(degree):
                assert batch % rung == 0


# --------------------------------------------------------------------------
# Arbiter: post-loss grants
# --------------------------------------------------------------------------
def _mesh_arbiter(devices, tenants=("a", "b")):
    arb = BudgetArbiter(ResourceBudget(), mesh=MeshSpec(devices=devices))
    for name in tenants:
        arb.register(name, 0.05)
    for name in tenants:
        arb.observe(name, 100.0)
    arb.split()
    return arb


def test_degraded_grants_is_a_pure_preview():
    arb = _mesh_arbiter(6)
    before_devices = dict(arb._devices)
    grants = arb.degraded_grants(1)
    assert sum(grants.values()) <= 5
    assert all(g >= 1 for g in grants.values())
    # preview only: nothing moved
    assert arb.mesh.devices == 6 and arb._devices == before_devices


def test_degraded_grants_snap_down_the_ladder():
    """A tenant holding 4 devices that must shrink to 3 lands on 2 — the
    largest divisor of its pre-loss degree — so every batch that sharded
    4-wide still shards."""
    arb = BudgetArbiter(ResourceBudget(), mesh=MeshSpec(devices=5))
    arb.register("big", 0.05)
    arb.register("small", 0.05)
    arb.observe("big", 1000.0)
    arb.observe("small", 1.0)
    arb.split()
    assert arb._devices == {"big": 4, "small": 1}
    grants = arb.degraded_grants(1)
    assert grants["big"] in degree_ladder(4)
    assert grants["small"] >= 1


def test_degraded_grants_refuses_eviction():
    arb = _mesh_arbiter(2)
    with pytest.raises(ValueError, match="at least one whole device"):
        arb.degraded_grants(1)


def test_degraded_grants_is_mesh_only():
    arb = BudgetArbiter(ResourceBudget())
    arb.register("a", 0.1)
    with pytest.raises(ValueError, match="mesh-mode only"):
        arb.degraded_grants(1)
    with pytest.raises(ValueError, match="mesh-mode only"):
        arb.on_device_loss()


def test_on_device_loss_shrinks_and_regrants():
    EVENTS.clear()
    arb = _mesh_arbiter(4)
    rebalances = arb.rebalances
    affected = arb.on_device_loss(3)
    assert arb.mesh.devices <= 3
    assert sum(arb._devices.values()) <= arb.mesh.devices
    assert all(g >= 1 for g in arb._devices.values())
    assert affected                       # someone's grant moved
    assert arb.rebalances == rebalances + 1
    evs = EVENTS.recent(kind="mesh.degraded")
    assert evs and evs[-1]["lost"] == 3


def test_on_device_loss_refuses_eviction():
    arb = _mesh_arbiter(2)
    with pytest.raises(ValueError, match="recover instead"):
        arb.on_device_loss()
    assert arb.mesh.devices == 2          # refused, not half-applied


# --------------------------------------------------------------------------
# Spare-plan pre-warming: the exact keys the degraded mesh asks for
# --------------------------------------------------------------------------
def _mesh_server(max_batch=4):
    srv = AdaptiveServer(DEVICE, mesh=MeshSpec(devices=2),
                         max_batch=max_batch)
    srv.register("a", init_cnn_frontend(jax.random.PRNGKey(0),
                                        channels=(6, 12), d_model=16),
                 (12, 12, 6))
    srv.arbiter.observe("a", 100.0)
    srv._apply_shares(srv.arbiter.split())
    return srv


def test_prewarm_spares_is_mesh_only():
    srv = AdaptiveServer(DEVICE, max_batch=2)
    srv.register("a", init_cnn_frontend(jax.random.PRNGKey(0),
                                        channels=(6, 12), d_model=16),
                 (12, 12, 6))
    with pytest.raises(ValueError, match="mesh-mode only"):
        srv.prewarm_spares()


def test_prewarm_then_degrade_replans_nothing_cold():
    """The headline: pre-warmed spare plans sit under the exact cache
    keys a post-loss re-plan asks for, so degradation is plan-cache-hit
    only.  Pure planning (no sharded execution) — the end-to-end run is
    the subprocess test below."""
    clear_plan_cache()
    srv = _mesh_server(max_batch=4)
    t = srv.tenants["a"]
    # registration warmed b=1 and b=4 (non-mesh, full budget); the
    # intermediate batch shapes are cold until prewarm fills them
    specs_b3 = srv._specs(t.params, (3,) + t.input_shape, "float32",
                          t.pool_window, t.activation, t.ladder)
    assert not plan_cache_contains(specs_b3, srv.budget, fuse=srv.fuse)
    warmed = srv.prewarm_spares(losses=1)
    assert warmed >= srv.max_batch
    assert plan_cache_contains(specs_b3, srv.budget, fuse=srv.fuse)

    before = STATS.plan_misses
    affected = srv.on_device_loss(1)
    assert affected == ["a"]
    assert srv.mesh.devices == 1 and srv.arbiter.devices_for("a") == 1
    for b in range(1, srv.max_batch + 1):
        specs = srv._specs(t.params, (b,) + t.input_shape, "float32",
                           t.pool_window, t.activation, t.ladder)
        replan(specs, srv.arbiter.budget_for("a"), fuse=srv.fuse,
               mesh=srv.arbiter.mesh_for("a"))
    assert cold_replans_since(before) == 0
    assert t.telemetry.degradations == 1


def test_degraded_plan_keeps_full_precision():
    """Degree before precision: the surviving device still plans under
    the FULL per-device budget, so a device loss moves the shard degree,
    never the precision bits."""
    srv = _mesh_server(max_batch=2)
    t = srv.tenants["a"]
    specs = srv._specs(t.params, (2,) + t.input_shape, "float32",
                       t.pool_window, t.activation, t.ladder)
    p2 = plan_network(specs, srv.arbiter.budget_for("a"), fuse=srv.fuse,
                      mesh=srv.arbiter.mesh_for("a"))
    srv.on_device_loss(1)
    p1 = plan_network(specs, srv.arbiter.budget_for("a"), fuse=srv.fuse,
                      mesh=srv.arbiter.mesh_for("a"))
    assert max(s.shard_degree for s in p2.sites) >= 1
    assert all(s.shard_degree == 1 for s in p1.sites)
    assert all(s.precision_bits == 32 for s in p1.sites)
    assert all(not s.lowered for s in p1.sites)


# --------------------------------------------------------------------------
# End to end (subprocess: 2 forced host devices): lose a device mid-
# serving, keep serving
# --------------------------------------------------------------------------
def test_server_survives_device_loss_end_to_end():
    out = run_sub("""
        from repro.core.plan import STATS
        from repro.core.resources import MeshSpec, ResourceBudget
        from repro.models.frontends import init_cnn_frontend
        from repro.runtime import (AdaptiveServer, FaultSpec, GuardPolicy,
                                   INJECTOR)

        srv = AdaptiveServer(ResourceBudget(vpu_ops_budget=15_000_000),
                             mesh=MeshSpec(devices=2), max_batch=2)
        srv.register("a", init_cnn_frontend(jax.random.PRNGKey(0),
                                            channels=(6, 12), d_model=16),
                     (12, 12, 6))
        srv.set_guard("a", GuardPolicy(max_retries=2,
                                       backoff_base_s=0.001))
        rng = np.random.default_rng(0)

        def wave(n=2):
            for _ in range(n):
                srv.submit("a",
                           rng.normal(size=(12, 12, 6)).astype(np.float32))
            return srv.drain()

        healthy = wave()
        assert all(c.ok for c in healthy)
        srv.prewarm_spares(losses=1)

        before = STATS.plan_misses
        # lose the tail device (the convention: surviving slices are
        # contiguous from 0) mid-serving; the guard absorbs the loss
        with INJECTOR.armed([FaultSpec("device_loss", step=0, param=1)]):
            degraded = wave()
        assert all(c.ok for c in degraded), degraded
        assert srv.mesh.devices == 1
        tel = srv.telemetry()["a"]
        assert tel["degradations"] == 1
        assert sorted(tel["shard_degree_mix"]) == [1, 2]
        assert set(tel["precision_mix"]) == {32}   # degree moved, not bits
        print("COLD", STATS.plan_misses - before)
        print("SURVIVED", len(degraded))
    """)
    assert "COLD 0" in out
    assert "SURVIVED 2" in out
