"""End-to-end adaptive CNN layer: conv -> pool -> activation, all three
dispatched through the resource-driven selector under one budget — the
paper's future-work scenario as a single block."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.resources import ResourceBudget
from repro.models.blocks import apply_cnn_block, init_cnn_block
from repro.models.frontends import apply_cnn_frontend, init_cnn_frontend


@pytest.fixture
def block():
    return init_cnn_block(jax.random.PRNGKey(0), cin=3, cout=16, k=3)


@pytest.fixture
def images(rng):
    return jnp.asarray(rng.normal(size=(2, 16, 16, 3)).astype(np.float32))


def test_block_shapes_and_plan(block, images):
    plan = {}
    y = apply_cnn_block(block, images, plan=plan, activation="tanh")
    # 16x16 -(3x3 valid)-> 14x14 -(2x2 pool)-> 7x7
    assert y.shape == (2, 7, 7, 16)
    # the default plan fuses the whole block into one launch...
    assert set(plan) == {"cnn_block.fused"}
    assert plan["cnn_block.fused"][0].family == "cnn_fused"
    # ...and fuse=False still exposes the three per-op decisions
    plan = {}
    y2 = apply_cnn_block(block, images, plan=plan, activation="tanh",
                         fuse=False)
    assert set(plan) == {"cnn_block.conv", "cnn_block.pool", "cnn_block.act"}
    for site, (ip, fp) in plan.items():
        assert fp.fits(ResourceBudget()), (site, ip.name)
    assert plan["cnn_block.conv"][0].family == "conv2d"
    assert plan["cnn_block.pool"][0].family == "pool2d"
    assert plan["cnn_block.act"][0].family == "activation"
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


def test_block_budget_invariance(block, images):
    """Different budgets select different IPs but identical math."""
    base = apply_cnn_block(block, images, activation="relu")
    for budget in [ResourceBudget(mxu_available=False),
                   ResourceBudget(vmem_bytes=2 * 2**20)]:
        out = apply_cnn_block(block, images, budget=budget,
                              activation="relu")
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=1e-5, atol=1e-5)


def test_block_matches_plain_jnp_oracle(block, images):
    from repro.kernels.activation.ref import activation_ref
    from repro.kernels.conv2d.ref import conv2d_ref
    from repro.kernels.pool2d.ref import pool2d_ref
    out = apply_cnn_block(block, images, pool_mode="avg", activation="tanh")
    ref = activation_ref(pool2d_ref(conv2d_ref(images, block["w"]),
                                    mode="avg"), kind="tanh")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_block_avg_pool_and_every_activation(block, images):
    for kind in ("relu", "relu6", "sigmoid", "tanh", "gelu"):
        y = apply_cnn_block(block, images, pool_mode="avg", activation=kind)
        assert y.shape == (2, 7, 7, 16)
        assert bool(jnp.all(jnp.isfinite(y)))


def test_frontend_produces_patch_embeddings(rng):
    p = init_cnn_frontend(jax.random.PRNGKey(1), channels=(3, 8, 16),
                          d_model=32)
    imgs = jnp.asarray(rng.normal(size=(2, 16, 16, 3)).astype(np.float32))
    plan = {}
    emb = apply_cnn_frontend(p, imgs, plan=plan)
    # 16 -> conv 14 -> pool 7 -> conv 5 -> pool 2; S = 2*2
    assert emb.shape == (2, 4, 32)
    # two blocks, each fused to one selector decision by default
    assert len(plan) == 2
    # opting out of fusion exposes three decisions per block
    plan = {}
    apply_cnn_frontend(p, imgs, plan=plan, fuse=False)
    assert len(plan) == 6


def test_frontend_budget_invariance(rng):
    p = init_cnn_frontend(jax.random.PRNGKey(2), channels=(3, 8, 8),
                          d_model=16)
    imgs = jnp.asarray(rng.normal(size=(1, 12, 12, 3)).astype(np.float32))
    base = apply_cnn_frontend(p, imgs)
    starved = apply_cnn_frontend(p, imgs,
                                 budget=ResourceBudget(mxu_available=False))
    np.testing.assert_allclose(np.asarray(starved), np.asarray(base),
                               rtol=1e-5, atol=1e-5)
