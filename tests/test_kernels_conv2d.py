"""conv2d IP family vs the pure-jnp oracle: shape/dtype sweeps +
bit-exactness of the Conv3 packing trick (the paper's core mechanism)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.conv2d.ops import conv2d, conv2d_dual
from repro.kernels.conv2d.ref import conv2d_dual_ref, conv2d_ref

SHAPES = [  # (N, H, W, Cin, KH, KW, Cout)
    (1, 8, 8, 1, 3, 3, 1),
    (2, 12, 12, 3, 3, 3, 8),
    (1, 16, 9, 4, 5, 3, 16),
    (3, 7, 7, 2, 1, 1, 4),
    (1, 10, 10, 8, 3, 3, 130),   # cout > one lane tile
]


def _int_data(rng, shape, dtype=np.int8):
    lo, hi = (-128, 128) if dtype == np.int8 else (-32768, 32768)
    return jnp.asarray(rng.integers(lo, hi, shape, dtype=dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("ip", ["ip1_vpu", "ip2_mxu"])
def test_single_stream_int8_exact(rng, shape, ip):
    n, h, w, cin, kh, kw, cout = shape
    x = _int_data(rng, (n, h, w, cin))
    wgt = _int_data(rng, (kh, kw, cin, cout))
    out = conv2d(x, wgt, ip=ip)
    ref = conv2d_ref(x, wgt)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("ip", ["ip3_packed", "ip4_dual"])
def test_dual_stream_int8_exact(rng, shape, ip):
    n, h, w, cin, kh, kw, cout = shape
    xa = _int_data(rng, (n, h, w, cin))
    xb = _int_data(rng, (n, h, w, cin))
    wgt = _int_data(rng, (kh, kw, cin, cout))
    ya, yb = conv2d_dual(xa, xb, wgt, ip=ip)
    ra, rb = conv2d_dual_ref(xa, xb, wgt)
    np.testing.assert_array_equal(np.asarray(ya), np.asarray(ra))
    np.testing.assert_array_equal(np.asarray(yb), np.asarray(rb))


@pytest.mark.parametrize("ip", ["ip1_vpu", "ip2_mxu"])
@pytest.mark.parametrize("dtype", [np.float32])
def test_single_stream_float(rng, ip, dtype):
    x = jnp.asarray(rng.normal(size=(2, 10, 10, 4)).astype(dtype))
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)).astype(dtype))
    out = conv2d(x, w, ip=ip)
    np.testing.assert_allclose(np.asarray(out), np.asarray(conv2d_ref(x, w)),
                               rtol=1e-4, atol=1e-5)


def test_ip3_rejects_wide_operands(rng):
    xa = jnp.asarray(rng.integers(-100, 100, (1, 6, 6, 2), dtype=np.int16))
    w = jnp.asarray(rng.integers(-100, 100, (3, 3, 2, 2), dtype=np.int8))
    with pytest.raises(TypeError, match="8-bit"):
        conv2d_dual(xa, xa, w, ip="ip3_packed")


# --------------------------------------------------------------------------
# Property: the packing identity is exact for ALL int8 operand values,
# including the sign-borrow corner cases (the paper's Conv3 contract).
# --------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       h=st.integers(3, 8), cin=st.integers(1, 3), cout=st.integers(1, 4))
def test_ip3_packing_exact_property(seed, h, cin, cout):
    rng = np.random.default_rng(seed)
    xa = jnp.asarray(rng.integers(-128, 128, (1, h, h, cin), dtype=np.int8))
    xb = jnp.asarray(rng.integers(-128, 128, (1, h, h, cin), dtype=np.int8))
    w = jnp.asarray(rng.integers(-128, 128, (3, 3, cin, cout), dtype=np.int8))
    if h < 3:
        return
    ya, yb = conv2d_dual(xa, xb, w, ip="ip3_packed")
    ra, rb = conv2d_dual_ref(xa, xb, w)
    np.testing.assert_array_equal(np.asarray(ya), np.asarray(ra))
    np.testing.assert_array_equal(np.asarray(yb), np.asarray(rb))


def test_ip3_extreme_values():
    """-128 * -128 and friends: the borrow correction must be exact."""
    for a_val, b_val, w_val in [(-128, -128, -128), (-128, 127, -128),
                                (127, -128, 127), (127, 127, 127),
                                (-1, 1, -1), (0, -128, 127)]:
        xa = jnp.full((1, 3, 3, 1), a_val, jnp.int8)
        xb = jnp.full((1, 3, 3, 1), b_val, jnp.int8)
        w = jnp.full((3, 3, 1, 1), w_val, jnp.int8)
        ya, yb = conv2d_dual(xa, xb, w, ip="ip3_packed")
        assert int(ya[0, 0, 0, 0]) == 9 * a_val * w_val
        assert int(yb[0, 0, 0, 0]) == 9 * b_val * w_val
