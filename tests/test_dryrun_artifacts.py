"""Regression locks on the committed dry-run artifacts.

These read `experiments/dryrun/*.json` (produced by
`python -m repro.launch.dryrun`); skipped when absent so the suite
stays runnable on a fresh checkout.
"""
import json
from pathlib import Path

import pytest

DRYRUN = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"

pytestmark = pytest.mark.skipif(
    not DRYRUN.exists() or not list(DRYRUN.glob("*.json")),
    reason="dry-run artifacts not generated")


def _load(name):
    f = DRYRUN / f"{name}.json"
    if not f.exists():
        pytest.skip(f"{name} not generated")
    return json.loads(f.read_text())


def test_all_cells_ok_or_skipped():
    statuses = {}
    for f in DRYRUN.glob("*.json"):
        r = json.loads(f.read_text())
        statuses[r["cell"]] = r["status"]
    assert statuses, "no cells"
    bad = {c: s for c, s in statuses.items() if s == "error"}
    assert not bad, bad


def test_skips_are_exactly_long500k_full_attention():
    skipped = []
    for f in DRYRUN.glob("*.json"):
        r = json.loads(f.read_text())
        if r["status"] == "skipped":
            skipped.append((r["arch"], r["shape"]))
            assert r["shape"] == "long_500k", r["cell"]
    subq = {"rwkv6-3b", "jamba-1.5-large-398b"}
    assert not any(a in subq for a, _ in skipped)


def test_memory_fits_hbm_budget():
    """Every compiled cell's static bytes/device must fit 16 GiB."""
    for f in DRYRUN.glob("*.json"):
        r = json.loads(f.read_text())
        if r["status"] != "ok":
            continue
        assert r["static_bytes_per_device"] < 16 * 2**30, r["cell"]


def test_multi_pod_halves_fsdp_state():
    s = _load("grok-1-314b__train_4k__single")
    m = _load("grok-1-314b__train_4k__multi")
    if s["status"] != "ok" or m["status"] != "ok":
        pytest.skip("cells missing")
    ratio = s["static_bytes_per_device"] / m["static_bytes_per_device"]
    assert 1.8 < ratio < 2.2, ratio  # pod axis doubles the dp shards


def test_hillclimb_improvements_locked():
    """The §Perf opt variants must beat their baselines."""
    for arch, shape, min_gain in [
            ("olmo-1b", "train_4k", 1.15),
            ("grok-1-314b", "train_4k", 1.3),
            ("llava-next-34b", "prefill_32k", 10.0)]:
        base = _load(f"{arch}__{shape}__single")
        opt = _load(f"{arch}__{shape}__single__opt")
        if base["status"] != "ok" or opt["status"] != "ok":
            pytest.skip("cells missing")
        gain = (opt["roofline"]["roofline_fraction"]
                / base["roofline"]["roofline_fraction"])
        assert gain >= min_gain, (arch, shape, gain)


def test_calibration_sane():
    """Calibrated totals must exceed the raw scan-graph numbers by
    roughly the group count (the while-body undercount)."""
    r = _load("olmo-1b__train_4k__single")
    if r["status"] != "ok":
        pytest.skip()
    g = r["calibration"]["n_groups"]
    ratio = r["totals_per_device"]["flops"] / r["scan_graph"]["flops"]
    assert g * 0.3 < ratio < g * 2.5, (ratio, g)
