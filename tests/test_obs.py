"""Cross-layer observability (src/repro/obs): span tracer contracts
(thread safety, allocation-free disabled path, Chrome trace-event JSON
schema), the always-on event log and its runtime routing (watchdog
timeouts, plan-cache evictions, arbiter rebalances), plan decision
audits with concrete rejection reasons, the metrics registry and its
Prometheus exposition, telemetry shard columns, and the calibration
drift monitor's flag/recalibrate loop."""
import json
import threading

import jax
import numpy as np
import pytest

from repro.core import plan as plan_mod
from repro.core.calibrate_cost import CalibrationTable
from repro.core.ip import SiteSpec
from repro.core.plan import (NetworkPlan, clear_plan_cache, plan_network,
                             replan)
from repro.core.resources import Footprint, ResourceBudget, hbm_cycles
from repro.models.blocks import cnn_block_site_specs
from repro.models.frontends import init_cnn_frontend
from repro.obs import (EVENTS, NOOP_SPAN, TRACER, DriftMonitor,
                       MetricsRegistry, PlanAudit, log_event,
                       mis_scaled_table, percentile, system_metrics,
                       unfit_reason)
from repro.runtime import AdaptiveServer
from repro.runtime.fault_tolerance import Watchdog
from repro.runtime.telemetry import TenantTelemetry


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with the tracer off and both global
    buffers empty — the singletons must not leak across tests."""
    TRACER.disable()
    TRACER.clear()
    EVENTS.clear()
    yield
    TRACER.disable()
    TRACER.clear()
    EVENTS.clear()


def _block_specs(site="obs"):
    specs, _ = cnn_block_site_specs((2, 16, 16, 4), (3, 3, 4, 16),
                                    x_dtype="float32", site=site)
    return tuple(specs)


# --------------------------------------------------------------------------
# Span tracer
# --------------------------------------------------------------------------
def test_tracer_disabled_path_is_noop_singleton():
    assert not TRACER.enabled
    # The disabled path hands back the one shared object — nothing to
    # allocate, nothing recorded.
    assert TRACER.span("anything", "cat", {"k": 1}) is NOOP_SPAN
    with TRACER.span("x"):
        pass
    TRACER.instant("marker")
    assert TRACER.events() == []
    assert TRACER.stats()["events"] == 0


def test_tracer_records_spans_and_instants():
    TRACER.enable()
    with TRACER.span("work", "test", {"n": 3}):
        TRACER.instant("tick", "test")
    TRACER.disable()
    events = TRACER.events()
    assert [e["ph"] for e in events] == ["i", "X"]  # span closes after
    span = events[1]
    assert span["name"] == "work" and span["cat"] == "test"
    assert span["dur"] >= 0.0
    assert span["args"] == {"n": 3}
    assert span["tid"] == threading.get_ident()


def test_tracer_thread_safety():
    TRACER.enable()
    # The barrier holds all 8 threads alive at once: thread idents stay
    # distinct (Python reuses idents of finished threads).
    barrier = threading.Barrier(8)

    def worker():
        barrier.wait()
        for _ in range(200):
            with TRACER.span("w", "threads"):
                pass

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    TRACER.disable()
    events = TRACER.events()
    assert len(events) == 8 * 200
    assert len({e["tid"] for e in events}) == 8
    json.loads(TRACER.export_chrome_trace())    # buffer survived the race


def test_chrome_trace_export_schema():
    TRACER.enable()
    with TRACER.span("a", "plan"):
        pass
    TRACER.instant("b", "events", {"x": 1})
    TRACER.disable()
    doc = json.loads(TRACER.export_chrome_trace())
    assert doc["displayTimeUnit"] == "ms"
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    for ev in doc["traceEvents"]:
        assert ev["ph"] in ("X", "i")
        assert isinstance(ev["name"], str) and ev["name"]
        assert isinstance(ev["ts"], float) and ev["ts"] >= 0
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0


def test_tracer_buffer_bounds_and_counts_drops():
    from repro.obs.trace import SpanTracer
    t = SpanTracer(max_events=3)
    t.enable()
    for _ in range(5):
        with t.span("s"):
            pass
    assert t.stats()["events"] == 3
    assert t.stats()["dropped"] == 2
    doc = json.loads(t.export_chrome_trace())
    assert doc["otherData"]["dropped_events"] == 2


# --------------------------------------------------------------------------
# Event log + runtime routing
# --------------------------------------------------------------------------
def test_watchdog_timeout_routes_to_event_log():
    fired = threading.Event()
    wd = Watchdog(timeout_s=0.05, on_timeout=fired.set)
    wd.start()
    assert fired.wait(timeout=5.0)
    wd.stop()
    events = EVENTS.recent(kind="watchdog.timeout")
    assert events and events[-1]["timeout_s"] == pytest.approx(0.05)


def test_plan_cache_eviction_routes_to_event_log():
    clear_plan_cache()
    specs = _block_specs()
    old_max = plan_mod._PLAN_CACHE_MAX
    plan_mod._PLAN_CACHE_MAX = 1
    try:
        plan_network(specs, ResourceBudget())
        plan_network(specs, ResourceBudget(vmem_bytes=2 * 2**20))
    finally:
        plan_mod._PLAN_CACHE_MAX = old_max
    evs = EVENTS.recent(kind="plan_cache.evict")
    assert evs and evs[-1]["capacity"] == 1


def test_arbiter_rebalance_routes_to_event_log():
    from repro.runtime import BudgetArbiter
    arb = BudgetArbiter(ResourceBudget(), rebalance_threshold=0.01,
                        demand_alpha=1.0)
    arb.register("a")
    arb.register("b")
    arb.split()                         # first grant: no rebalance
    arb.observe("a", 1000.0)
    arb.split()                         # demand skew past threshold
    assert arb.rebalances == 1
    evs = EVENTS.recent(kind="arbiter.rebalance")
    assert evs and evs[-1]["cause"] == "drift"


def test_event_log_mirrors_into_enabled_tracer():
    TRACER.enable()
    EVENTS.log("test.kind", value=7)
    TRACER.disable()
    (ev,) = TRACER.events()
    assert ev["name"] == "test.kind" and ev["ph"] == "i"
    assert ev["args"] == {"value": 7}


# --------------------------------------------------------------------------
# Plan decision audit
# --------------------------------------------------------------------------
def test_unfit_reason_names_the_failing_axis():
    fp = Footprint(vmem_bytes=700 * 1024, hbm_bytes=1024, mxu_passes=0,
                   vpu_ops=100, est_cycles=1000.0)
    reason = unfit_reason(fp, ResourceBudget(vmem_bytes=600 * 1024))
    assert "vmem" in reason and "700KiB" in reason and "600KiB" in reason
    reason = unfit_reason(
        Footprint(vmem_bytes=10, hbm_bytes=10, mxu_passes=4, vpu_ops=0,
                  est_cycles=1.0),
        ResourceBudget(mxu_available=False))
    assert "mxu_available=False" in reason


def test_plan_audit_names_concrete_rejection_reasons():
    clear_plan_cache()
    specs = _block_specs()
    ample = plan_network(specs, ResourceBudget())
    assert ample.audit is not None
    # Squeeze the VPU path: any site whose choice moved must carry a
    # concrete rejection for the member it abandoned.
    tight = plan_network(specs,
                         ResourceBudget(vpu_ops_budget=100_000))
    moved = [s for s, a in zip(tight.sites, ample.sites)
             if s.ip.name != a.ip.name
             or s.precision_bits != a.precision_bits]
    assert moved, "budget squeeze did not move any site"
    for site in moved:
        audit = tight.audit.site(site.spec.name)
        reasons = audit.rejection_reasons()
        assert reasons, f"no rejection recorded for {site.spec.name}"
        assert any(ch.isdigit() for r in reasons for ch in r), \
            "rejection reasons must carry concrete numbers"
    assert tight.explain()


def test_plan_audit_roundtrips_through_json():
    clear_plan_cache()
    specs = _block_specs()
    plan = plan_network(specs, ResourceBudget(vpu_ops_budget=100_000))
    back = NetworkPlan.from_json(plan.to_json())
    assert back.audit is not None
    assert back.audit.to_dict() == plan.audit.to_dict()
    assert back.explain() == plan.explain()


def test_cached_plan_keeps_its_audit():
    clear_plan_cache()
    specs = _block_specs()
    cold = plan_network(specs, ResourceBudget())
    warm = plan_network(specs, ResourceBudget())
    assert warm is cold and warm.audit is not None


def test_replan_fast_path_records_audit_event():
    clear_plan_cache()
    specs = _block_specs()
    plan_network(specs, ResourceBudget())        # warms the share cache
    plan = replan(specs, ResourceBudget().scaled(0.7))
    assert plan.audit is not None
    assert any("replan fast path" in e for e in plan.audit.events)


def test_explain_handles_missing_audit():
    plan = NetworkPlan(budget=ResourceBudget(), sites=())
    assert "no audit" in plan.explain()


# --------------------------------------------------------------------------
# Metrics registry
# --------------------------------------------------------------------------
def test_registry_counter_gauge_histogram_and_render():
    reg = MetricsRegistry(namespace="t")
    reg.counter("reqs", "served requests", tenant="a").inc(3)
    reg.gauge("depth").set(2.5)
    h = reg.histogram("lat", "latency")
    h.observe_many([1.0, 2.0, 3.0, 4.0])
    snap = reg.snapshot()
    assert snap["reqs"][0]["value"] == 3
    assert snap["lat"][0]["count"] == 4
    text = reg.render()
    assert "# TYPE t_reqs counter" in text
    assert 't_reqs{tenant="a"} 3' in text
    assert "# TYPE t_lat summary" in text
    assert "t_lat_count 4" in text
    assert 't_lat{quantile="0.5"} 2.5' in text


def test_registry_is_idempotent_but_kind_conflicts_raise():
    reg = MetricsRegistry()
    c = reg.counter("x")
    assert reg.counter("x") is c
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x")


def test_registry_labels_may_shadow_registration_args():
    # system_metrics renders event counts labelled kind=...; label names
    # must never collide with _get's own parameters
    reg = MetricsRegistry(namespace="t")
    reg.counter("events", "event-log entries",
                kind="watchdog.timeout", name="n", help_="h").inc(2)
    text = reg.render()
    assert 'kind="watchdog.timeout"' in text and 'name="n"' in text


def test_system_metrics_counts_logged_events_by_kind():
    log_event("watchdog.timeout", timeout_s=0.1)
    log_event("watchdog.timeout", timeout_s=0.2)
    text = system_metrics().render()
    assert 'repro_events_total{kind="watchdog.timeout"} 2' in text


def test_counter_rejects_negative_increment():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="only go up"):
        reg.counter("c").inc(-1)


def test_system_metrics_includes_tenant_shard_columns():
    clear_plan_cache()
    srv = AdaptiveServer(ResourceBudget(), max_batch=2)
    srv.register("t", init_cnn_frontend(jax.random.PRNGKey(0),
                                        channels=(6, 12), d_model=16),
                 (12, 12, 6))
    rng = np.random.default_rng(0)
    srv.submit("t", rng.normal(size=(12, 12, 6)).astype(np.float32))
    srv.drain()
    text = srv.metrics().render()
    assert 'repro_tenant_shard_degree{tenant="t"} 1' in text
    assert 'repro_tenant_comm_cycles_share{tenant="t"} 0' in text
    assert 'repro_tenant_requests_total{tenant="t"} 1' in text
    assert srv.queue_stats()["popped_requests"] == 1


# --------------------------------------------------------------------------
# Telemetry shard columns + shared percentile
# --------------------------------------------------------------------------
def _planned_site_stub(deg, comm, est):
    class _S:
        precision_bits = 32
        shard_degree = deg
        footprint = Footprint(vmem_bytes=1, hbm_bytes=0, mxu_passes=0,
                              vpu_ops=0, est_cycles=est, comm_cycles=comm)
    return _S()


def test_telemetry_snapshot_gains_shard_columns():
    tel = TenantTelemetry(name="t", max_batch=4)

    class _Plan:
        sites = (_planned_site_stub(4, 250.0, 1000.0),
                 _planned_site_stub(1, 0.0, 1000.0))

    tel.record_batch(2, [10.0, 12.0], _Plan(), cache_hits=1,
                     cache_misses=0)
    snap = tel.snapshot()
    assert snap["shard_degree"] == 4
    assert snap["shard_degree_mix"] == {1: 1, 4: 1}
    assert snap["comm_cycles_share"] == pytest.approx(250.0 / 2000.0)


def test_latency_percentile_delegates_to_shared_estimator():
    tel = TenantTelemetry(name="t", max_batch=4)
    tel.latencies.extend([5.0, 1.0, 3.0, 2.0, 4.0])
    for q in (0, 25, 50, 90, 100):
        assert tel.latency_percentile(q) == percentile(
            [1.0, 2.0, 3.0, 4.0, 5.0], q)


# --------------------------------------------------------------------------
# Calibration drift monitor
# --------------------------------------------------------------------------
def _fp(compute=1000.0, hbm=4096):
    return Footprint(vmem_bytes=1024, hbm_bytes=hbm, mxu_passes=0,
                     vpu_ops=100, est_cycles=compute + hbm_cycles(hbm))


def _fitted_table(a=0.002, b=1e-6, c=5.0):
    """A table fit on points lying exactly on us = a*compute + b*hbm + c."""
    table = CalibrationTable()
    for comp, hbm in ((1000.0, 4096), (2000.0, 8192), (4000.0, 2048),
                      (8000.0, 16384)):
        table.record("m", _fp(comp, int(hbm)), a * comp + b * hbm + c)
    return table.fit(min_samples=3)


def test_drift_monitor_quiet_on_honest_table():
    table = _fitted_table()
    mon = DriftMonitor(table, threshold=0.5, min_observations=3)
    for comp in (1500.0, 2500.0, 3500.0, 4500.0):
        fp = _fp(comp)
        truth = 0.002 * comp + 1e-6 * fp.hbm_bytes + 5.0
        assert mon.observe("m", fp, truth) is None
    assert not mon.drifted
    assert mon.mean_rel_error < 0.05


def test_drift_monitor_flags_mis_scaled_table_once():
    table = _fitted_table()
    bad = mis_scaled_table(table, 8.0)
    hits = []
    mon = DriftMonitor(bad, threshold=0.5, min_observations=3,
                       on_drift=hits.append)
    report = None
    for comp in (1500.0, 2500.0, 3500.0, 4500.0):
        fp = _fp(comp)
        truth = 0.002 * comp + 1e-6 * fp.hbm_bytes + 5.0
        report = mon.observe("m", fp, truth) or report
    assert mon.drifted and report is not None
    assert report.mean_rel_error > 0.5
    assert len(hits) == 1               # one flag per excursion
    assert len(mon.reports) == 1
    assert EVENTS.recent(kind="calibration.drift")


def test_drift_monitor_recalibrate_rearms_and_quiets():
    table = _fitted_table()
    bad = mis_scaled_table(table, 8.0)
    mon = DriftMonitor(bad, threshold=0.5, min_observations=3)
    obs = []
    for comp in (1500.0, 2500.0, 3500.0, 4500.0):
        fp = _fp(comp)
        truth = 0.002 * comp + 1e-6 * fp.hbm_bytes + 5.0
        obs.append((fp, truth))
        mon.observe("m", fp, truth)
    assert mon.drifted
    before = bad.fingerprint()
    after = mon.recalibrate()
    assert after != before              # refit moved the table identity
    assert not mon.drifted
    for fp, truth in obs:               # the refit table predicts truth
        assert mon.observe("m", fp, truth) is None
    assert not mon.drifted
    assert EVENTS.recent(kind="calibration.refit")


def test_drift_monitor_no_verdict_without_fit():
    mon = DriftMonitor(CalibrationTable(), threshold=0.5,
                       min_observations=1)
    assert mon.observe("m", _fp(), 10.0) is None
    assert mon.predictions == 0 and mon.observations == 1
