"""Property tests for the shared percentile estimator
(``repro.obs.metrics.percentile``) — the single rule serving telemetry
(``TenantTelemetry.latency_percentile``), the metrics ``Histogram``,
and the Prometheus exposition all price quantiles by.  If this
estimator and numpy's linear-interpolation percentile ever disagree,
dashboards and telemetry snapshots report different p95s for the same
window.

Runs under real ``hypothesis`` when installed, else the deterministic
fallback shim (``tests/_hypothesis_fallback.py`` via ``conftest.py``).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.obs.metrics import Histogram, percentile
from repro.runtime.telemetry import TenantTelemetry

_VALUES = st.lists(st.floats(min_value=-1e6, max_value=1e6),
                   min_size=1, max_size=40)
_Q = st.floats(min_value=0.0, max_value=100.0)


@settings(max_examples=50)
@given(xs=_VALUES, q=_Q)
def test_percentile_within_data_range(xs, q):
    p = percentile(xs, q)
    assert min(xs) <= p <= max(xs)


@settings(max_examples=50)
@given(xs=_VALUES, q1=_Q, q2=_Q)
def test_percentile_monotone_in_q(xs, q1, q2):
    lo, hi = sorted((q1, q2))
    assert percentile(xs, lo) <= percentile(xs, hi)


@settings(max_examples=50)
@given(xs=_VALUES)
def test_percentile_endpoints_are_min_and_max(xs):
    assert percentile(xs, 0) == pytest.approx(min(xs))
    assert percentile(xs, 100) == pytest.approx(max(xs))


@settings(max_examples=50)
@given(xs=_VALUES, q=_Q)
def test_percentile_matches_numpy_linear(xs, q):
    want = float(np.percentile(np.asarray(xs, dtype=np.float64), q,
                               method="linear"))
    assert percentile(xs, q) == pytest.approx(want, rel=1e-9, abs=1e-6)


@settings(max_examples=50)
@given(xs=_VALUES, q=_Q)
def test_percentile_invariant_to_input_order(xs, q):
    assert percentile(xs, q) == percentile(list(reversed(xs)), q)


@settings(max_examples=50)
@given(xs=_VALUES, q=_Q)
def test_telemetry_and_histogram_agree_with_estimator(xs, q):
    # One window, three consumers, one answer.
    tel = TenantTelemetry(name="t", max_batch=4)
    tel.latencies.extend(xs)
    hist = Histogram()
    hist.observe_many(xs)
    want = percentile(xs, q)
    assert tel.latency_percentile(q) == pytest.approx(want)
    assert hist.quantile(q / 100.0) == pytest.approx(want)


def test_percentile_edge_cases():
    assert percentile([], 50) == 0.0
    assert percentile([7.0], 99) == 7.0
    # out-of-range q clamps instead of raising
    assert percentile([1.0, 2.0], -5) == 1.0
    assert percentile([1.0, 2.0], 200) == 2.0
