"""autotune + quantize modules: feasibility, alignment, error bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.autotune import (autotune_conv, autotune_flash,
                                 autotune_matmul)
from repro.core.quantize import (int8_matmul, quantization_error,
                                 quantize_acts, quantize_weights)
from repro.core.resources import MXU_DIM, ResourceBudget


# --------------------------------------------------------------------------
# autotune
# --------------------------------------------------------------------------
def test_autotune_matmul_alignment_and_fit():
    r = autotune_matmul(1024, 4096, 1024)
    for key in ("bm", "bn", "bk"):
        assert r.params[key] % MXU_DIM == 0
    assert r.footprint.fits(ResourceBudget())


def test_autotune_matmul_respects_tight_vmem():
    tight = ResourceBudget(vmem_bytes=2 * 2**20)
    r = autotune_matmul(2048, 2048, 2048, budget=tight)
    assert r.footprint.vmem_bytes <= tight.vmem_bytes
    ample = autotune_matmul(2048, 2048, 2048)
    assert r.footprint.vmem_bytes <= ample.footprint.vmem_bytes


def test_autotune_flash_and_conv():
    r = autotune_flash(8, 32, 8, 4096, 4096, 128)
    assert r.params["bq"] >= 128 and r.params["bk"] >= 128
    assert r.footprint.fits(ResourceBudget())
    c = autotune_conv(4, 64, 64, 16, 3, 3, 256)
    assert c.params["block_cout"] % 128 == 0


def test_autotune_measured_agrees_with_feasible():
    r = autotune_matmul(256, 256, 256, measure=True)
    assert r.measured_us is not None and r.measured_us > 0


# --------------------------------------------------------------------------
# quantize
# --------------------------------------------------------------------------
def test_weight_quantization_error_small(rng):
    w = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    assert quantization_error(w) < 0.01


def test_int8_matmul_close_to_f32(rng):
    x = jnp.asarray(rng.normal(size=(4, 64, 256)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    wq = quantize_weights(w)
    y_q = int8_matmul(x, wq)
    y_f = jnp.einsum("...k,kn->...n", x, w)
    # w8a8 keeps ~1% relative error on gaussian data
    rel = float(jnp.linalg.norm(y_q - y_f) / jnp.linalg.norm(y_f))
    assert rel < 0.02, rel


def test_int8_matmul_kernel_path_matches_jnp(rng):
    x = jnp.asarray(rng.normal(size=(32, 96)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(96, 48)).astype(np.float32))
    wq = quantize_weights(w)
    y1 = int8_matmul(x, wq, use_kernel=False)
    y2 = int8_matmul(x, wq, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), ch=st.integers(1, 64))
def test_quantize_roundtrip_bounded(seed, ch):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(16, ch)).astype(np.float32))
    wq = quantize_weights(w)
    deq = wq.q.astype(jnp.float32) * wq.scale
    err = np.abs(np.asarray(deq) - np.asarray(w))
    # error bounded by half a quantization step per channel
    bound = np.asarray(wq.scale)[0] * 0.5 + 1e-6
    assert (err <= bound + 1e-6).all()


def test_quantize_acts_range(rng):
    x = jnp.asarray(rng.normal(size=(100,)).astype(np.float32) * 50)
    q = quantize_acts(x)
    assert q.q.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(q.q))) <= 127
