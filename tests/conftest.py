"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the real single
device; multi-device tests spawn subprocesses (see test_distributed.py)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
