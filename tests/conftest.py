"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the real single
device; multi-device tests spawn subprocesses (see test_distributed.py)."""
import importlib.util
import pathlib
import sys

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# hypothesis is an optional test dependency (pyproject [test] extra).  When
# absent, install the deterministic fallback so property-based modules still
# collect and run (each property executes a small seeded example sweep).
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        pathlib.Path(__file__).with_name("_hypothesis_fallback.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies


@pytest.fixture
def rng():
    return np.random.default_rng(0)
