"""activation IP family vs the pure-jnp oracle: exactness of the VPU
member, bounded error of the fixed-point LUT member, capability
filtering, footprint monotonicity, and selector behavior."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.resources import ResourceBudget
from repro.core.selector import select_activation_ip
from repro.kernels.activation.lut_poly import (RANGES, SUPPORTED_KINDS,
                                               activation_lut,
                                               footprint as fp_lut)
from repro.kernels.activation.ops import activation
from repro.kernels.activation.ref import KINDS, activation_ref
from repro.kernels.activation.vpu_exact import footprint as fp_exact

SHAPES = [(2, 8, 8, 16), (5, 300), (1000,), (3, 1, 7)]

# Worst-case LUT error: half a 256-level quantization step times the
# activation's Lipschitz constant, plus the saturation tail.
LUT_ATOL = 0.05


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("kind", KINDS)
def test_exact_member_matches_oracle(rng, shape, kind):
    x = jnp.asarray(rng.normal(0, 2, shape).astype(np.float32))
    out = activation(x, kind=kind, ip="act_vpu")
    ref = activation_ref(x, kind=kind)
    assert out.shape == ref.shape and out.dtype == ref.dtype
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("kind", SUPPORTED_KINDS)
def test_lut_member_bounded_error(rng, kind):
    # Cover the tabulated range AND the saturated tails.
    x = jnp.asarray(rng.uniform(-3 * RANGES[kind], 3 * RANGES[kind],
                                (4, 512)).astype(np.float32))
    out = activation(x, kind=kind, ip="act_lut")
    ref = activation_ref(x, kind=kind)
    err = np.abs(np.asarray(out) - np.asarray(ref)).max()
    assert err < LUT_ATOL, (kind, err)


def test_lut_rejects_unbounded_kinds():
    x = jnp.ones((4, 4), jnp.float32)
    for kind in ("relu", "gelu"):
        with pytest.raises(ValueError, match="saturating"):
            activation_lut(x, kind=kind)


def test_dtype_contract(rng):
    xf = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    assert activation(xf.astype(jnp.bfloat16), kind="tanh",
                      ip="act_vpu").dtype == jnp.bfloat16
    assert activation(xf.astype(jnp.bfloat16), kind="tanh",
                      ip="act_lut").dtype == jnp.bfloat16
    xi = jnp.asarray(rng.integers(-5, 5, (3, 4)).astype(np.int32))
    assert activation(xi, kind="relu", ip="act_vpu").dtype == jnp.float32


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       kind=st.sampled_from(list(SUPPORTED_KINDS)))
def test_lut_error_bound_property(seed, kind):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 4, (2, 256)).astype(np.float32))
    out = activation_lut(x, kind=kind)
    ref = activation_ref(x, kind=kind)
    assert np.abs(np.asarray(out) - np.asarray(ref)).max() < LUT_ATOL


# --------------------------------------------------------------------------
# Footprints
# --------------------------------------------------------------------------
def test_footprint_monotone_in_elements():
    for fp_fn, kind in [(fp_exact, "tanh"), (fp_lut, "tanh")]:
        small = fp_fn(1 << 10, itemsize=4, kind=kind)
        big = fp_fn(1 << 20, itemsize=4, kind=kind)
        assert big.hbm_bytes > small.hbm_bytes
        assert big.vpu_ops > small.vpu_ops
        assert big.est_cycles > small.est_cycles


def test_lut_is_the_low_resource_member():
    n = 1 << 20
    exact = fp_exact(n, itemsize=4, kind="tanh")
    lut = fp_lut(n, itemsize=4, kind="tanh")
    assert lut.vpu_ops < exact.vpu_ops
    assert lut.hbm_bytes < exact.hbm_bytes     # 1-byte operand streaming
    assert lut.est_cycles < exact.est_cycles
    assert lut.max_operand_bits == 8
    assert exact.max_operand_bits == 32


# --------------------------------------------------------------------------
# Selector
# --------------------------------------------------------------------------
XS = (2, 16, 16, 64)


def test_full_precision_budget_forces_exact():
    ip = select_activation_ip(XS, kind="tanh",
                              budget=ResourceBudget(precision_bits=16))
    assert ip.name == "activation.act_vpu"


def test_low_precision_budget_selects_lut():
    ip = select_activation_ip(XS, kind="tanh",
                              budget=ResourceBudget(precision_bits=8))
    assert ip.name == "activation.act_lut"


def test_unbounded_kind_falls_back_to_exact_even_at_low_precision():
    ip = select_activation_ip(XS, kind="gelu",
                              budget=ResourceBudget(precision_bits=8))
    assert ip.name == "activation.act_vpu"


def test_infeasible_everywhere_raises_like_conv2d():
    with pytest.raises(ValueError, match="no feasible IP"):
        select_activation_ip(XS, kind="tanh",
                             budget=ResourceBudget(vpu_ops_budget=10))


def test_selected_ip_always_fits_budget():
    for budget in [ResourceBudget(), ResourceBudget(precision_bits=8),
                   ResourceBudget(mxu_available=False)]:
        for kind in KINDS:
            ip = select_activation_ip(XS, kind=kind, budget=budget)
            fp = ip.footprint(int(np.prod(XS)), itemsize=4, kind=kind)
            assert fp.fits(budget), (ip.name, kind, budget)
