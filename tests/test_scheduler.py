"""SLO scheduler: admission, continuous batching, deadline-aware
dispatch (EDF + priority preemption), load shedding, queue-depth caps,
the dual-clock telemetry contract, and state round-trips."""
import jax
import numpy as np
import pytest

from repro.core.resources import ResourceBudget
from repro.models.frontends import init_cnn_frontend
from repro.obs import EVENTS
from repro.runtime import AdaptiveServer, BudgetArbiter, SLOScheduler, SLOSpec

DEVICE = ResourceBudget(vpu_ops_budget=15_000_000)


class FakeWall:
    """Manually advanced monotonic clock."""

    def __init__(self, step: float = 0.0):
        self.t = 0.0
        self.step = step      # auto-advance per reading (0 = manual)

    def __call__(self) -> float:
        t = self.t
        self.t += self.step
        return t

    def advance(self, dt: float) -> None:
        self.t += dt


def _frontend(key=0, channels=(6, 12), d_model=16):
    return init_cnn_frontend(jax.random.PRNGKey(key), channels=channels,
                             d_model=d_model)


def _deployment(wall=None, **slo_kwargs):
    srv = AdaptiveServer(DEVICE, policy="demand", max_batch=4)
    sched = (SLOScheduler(srv, wall=wall) if wall is not None
             else SLOScheduler(srv))
    sched.register("t", _frontend(), (12, 12, 6),
                   slo=SLOSpec(**(slo_kwargs or {"deadline_s": 60.0})))
    return srv, sched


def _sample(rng, shape=(12, 12, 6)):
    return rng.normal(size=shape).astype(np.float32)


# --------------------------------------------------------------------------
# SLOSpec + registration validation
# --------------------------------------------------------------------------
def test_slospec_validates_fields():
    with pytest.raises(ValueError):
        SLOSpec(deadline_s=0.0)
    with pytest.raises(ValueError):
        SLOSpec(deadline_s=-1.0)
    with pytest.raises(ValueError):
        SLOSpec(deadline_s=1.0, max_queue_depth=0)
    spec = SLOSpec(deadline_s=1.0, priority=3, max_queue_depth=2)
    assert (spec.deadline_s, spec.priority, spec.max_queue_depth) \
        == (1.0, 3, 2)


def test_register_requires_slospec_and_submit_validates():
    srv = AdaptiveServer(DEVICE, max_batch=4)
    sched = SLOScheduler(srv)
    with pytest.raises(TypeError):
        sched.register("t", _frontend(), (12, 12, 6), slo=1.5)
    sched.register("t", _frontend(), (12, 12, 6),
                   slo=SLOSpec(deadline_s=1.0))
    rng = np.random.default_rng(0)
    with pytest.raises(KeyError):
        sched.submit("ghost", _sample(rng))
    with pytest.raises(ValueError):
        sched.submit("t", _sample(rng, (8, 8, 3)))


def test_scheduler_refuses_server_with_queued_requests(rng):
    srv = AdaptiveServer(DEVICE, max_batch=4)
    srv.register("t", _frontend(), (12, 12, 6))
    srv.submit("t", _sample(rng))
    with pytest.raises(ValueError):
        SLOScheduler(srv)


# --------------------------------------------------------------------------
# Continuous batching + deferred arrivals
# --------------------------------------------------------------------------
def test_batches_fill_to_max_batch(rng):
    srv, sched = _deployment()
    rids = [sched.submit("t", _sample(rng)) for _ in range(6)]
    comps = sched.run()
    assert len(comps) == 6
    assert sched.launches == 2            # 4 + 2, not 6 singles
    assert all(sched.outcomes[r] == "ok" for r in rids)
    assert sched.pending() == 0


def test_deferred_arrival_waits_for_its_clock(rng):
    srv, sched = _deployment()
    early = sched.submit("t", _sample(rng))
    late = sched.submit("t", _sample(rng), at=sched.now + 1e9)
    comps = sched.run()
    assert len(comps) == 2
    assert sched.launches == 2            # the late arrival missed batch 1
    assert {c.rid for c in comps} == {early, late}
    # the dispatch frontier advanced to the deferred arrival
    assert sched.now >= 1e9


# --------------------------------------------------------------------------
# Deadline-aware dispatch: EDF across buckets, priority preemption
# --------------------------------------------------------------------------
def test_earliest_deadline_jumps_queue_without_priority(rng):
    """Equal priorities: the tighter-deadline bucket launches first —
    an EDF reorder, not a preemption."""
    srv = AdaptiveServer(DEVICE, max_batch=4)
    sched = SLOScheduler(srv)
    sched.register("loose", _frontend(0), (12, 12, 6),
                   slo=SLOSpec(deadline_s=100.0))
    sched.register("tight", _frontend(1), (12, 12, 6),
                   slo=SLOSpec(deadline_s=0.5))
    sched.submit("loose", _sample(rng))
    sched.submit("tight", _sample(rng))
    comps = sched.run()
    assert comps[0].tenant == "tight"
    assert sched.preemptions == 0


def test_priority_preempts_queued_bucket_and_moves_grant(rng):
    EVENTS.clear()
    srv = AdaptiveServer(DEVICE, max_batch=4)
    sched = SLOScheduler(srv)
    sched.register("bulk", _frontend(0), (12, 12, 6),
                   slo=SLOSpec(deadline_s=60.0, priority=0))
    sched.register("rt", _frontend(1), (12, 12, 6),
                   slo=SLOSpec(deadline_s=60.0, priority=2))
    sched.submit("bulk", _sample(rng))       # queued first (FIFO baseline)
    sched.submit("rt", _sample(rng))
    comps = sched.run()
    assert comps[0].tenant == "rt"           # jumped the earlier bucket
    assert sched.preemptions >= 1
    assert srv.tenants["rt"].telemetry.preemptions >= 1
    assert srv.arbiter.preemptions >= 1      # grant actually moved
    evs = EVENTS.recent(kind="scheduler.preempt")
    assert evs and evs[-1]["winner"] == "rt" and evs[-1]["victim"] == "bulk"


# --------------------------------------------------------------------------
# Load shedding + queue-depth caps
# --------------------------------------------------------------------------
def test_expired_requests_are_shed_not_executed(rng):
    EVENTS.clear()
    wall = FakeWall()
    srv, sched = _deployment(wall=wall, deadline_s=0.5)
    rids = [sched.submit("t", _sample(rng)) for _ in range(8)]
    sched.run(max_launches=sched.launches + 1)   # first 4 served at t=0
    wall.advance(1.0)                            # the rest expire queued
    comps = sched.run()
    assert comps == []
    assert sched.sheds == 4
    assert sorted(sched.outcomes[r] for r in rids) \
        == ["ok"] * 4 + ["shed"] * 4
    assert sched.pending() == 0
    assert srv.tenants["t"].telemetry.shed == 4
    assert srv.arbiter.miss_rate("t") > 0.0      # sheds feed the EWMA
    assert EVENTS.recent(kind="scheduler.shed")


def test_max_queue_depth_rejects_overflow(rng):
    srv, sched = _deployment(deadline_s=60.0, max_queue_depth=2)
    rids = [sched.submit("t", _sample(rng)) for _ in range(5)]
    comps = sched.run()
    assert len(comps) == 2
    assert sched.rejections == 3
    outcomes = [sched.outcomes[r] for r in rids]
    assert outcomes.count("rejected") == 3 and outcomes.count("ok") == 2
    assert srv.tenants["t"].telemetry.shed == 3  # rejections count as shed


# --------------------------------------------------------------------------
# Dual-clock contract: est-cycles lanes, wall deadlines — both reported
# --------------------------------------------------------------------------
def test_telemetry_reports_both_clocks(rng):
    srv, sched = _deployment(deadline_s=60.0)
    for _ in range(4):
        sched.submit("t", _sample(rng))
    sched.run()
    snap = srv.tenants["t"].telemetry.snapshot()
    assert snap["p95_cycles"] > 0.0              # modeled est-cycles clock
    assert snap["wall_p95_s"] >= 0.0             # measured wall clock
    assert snap["slo_tracked"] == 4
    assert snap["deadline_misses"] == 0
    assert snap["deadline_miss_rate"] == 0.0


def test_wall_clock_judges_misses_not_the_model_clock(rng):
    # auto-advancing wall + shedding disabled: every request is judged
    # LATE on the wall even though the modeled est-cycles latency is
    # tiny — the dual-clock rule in action
    wall = FakeWall(step=0.1)
    srv = AdaptiveServer(DEVICE, max_batch=4)
    sched = SLOScheduler(srv, wall=wall, shed_margin_s=-1e9)
    sched.register("t", _frontend(), (12, 12, 6),
                   slo=SLOSpec(deadline_s=0.05))
    rids = [sched.submit("t", _sample(rng)) for _ in range(4)]
    comps = sched.run()
    assert len(comps) == 4                       # executed, not shed
    assert all(sched.outcomes[r] == "miss" for r in rids)
    snap = srv.tenants["t"].telemetry.snapshot()
    assert snap["deadline_misses"] == 4
    assert snap["deadline_miss_rate"] == 1.0
    assert srv.arbiter.miss_rate("t") > 0.0


# --------------------------------------------------------------------------
# Arbiter extensions the scheduler rides on
# --------------------------------------------------------------------------
def test_grant_quantum_bounds_budget_key_space():
    arb = BudgetArbiter(ResourceBudget(), rebalance_threshold=0.0,
                        demand_alpha=1.0, grant_quantum=1 / 8)
    arb.register("a", floor=0.05)
    arb.register("b", floor=0.05)
    arb.observe("a", 700.0)
    arb.observe("b", 300.0)
    shares = arb.split()
    for s in shares.values():
        on_grid = abs(s.fraction / (1 / 8) - round(s.fraction / (1 / 8))) \
            < 1e-9
        assert on_grid or s.fraction == pytest.approx(s.floor)
        assert s.fraction >= s.floor
    assert sum(s.fraction for s in shares.values()) <= 1.0 + 1e-9


def test_grant_quantum_validation():
    with pytest.raises(ValueError):
        BudgetArbiter(ResourceBudget(), grant_quantum=1.0)
    with pytest.raises(ValueError):
        BudgetArbiter(ResourceBudget(), grant_quantum=-0.1)


def test_slo_pressure_amplifies_missing_tenant():
    arb = BudgetArbiter(ResourceBudget(), rebalance_threshold=0.0,
                        demand_alpha=1.0, slo_pressure=4.0, miss_alpha=1.0)
    arb.register("a")
    arb.register("b")
    arb.observe("a", 500.0)
    arb.observe("b", 500.0)
    even = arb.split()
    assert even["a"].fraction == pytest.approx(even["b"].fraction)
    arb.observe("a", 500.0)
    arb.observe("b", 500.0)
    arb.record_outcome("a", served=4, missed=4)  # a is missing deadlines
    shares = arb.split()
    assert shares["a"].fraction > shares["b"].fraction


# --------------------------------------------------------------------------
# State round-trip (what a plan-preserving restart carries)
# --------------------------------------------------------------------------
def test_state_dict_roundtrip(rng):
    srv, sched = _deployment(deadline_s=2.5)
    sched.submit("t", _sample(rng))
    sched.run()
    state = sched.state_dict()
    assert state["slos"]["t"]["deadline_s"] == 2.5
    assert state["launches"] == sched.launches

    srv2 = AdaptiveServer(DEVICE, max_batch=4)
    srv2.register("t", _frontend(), (12, 12, 6))
    sched2 = SLOScheduler(srv2)
    sched2.load_state(state)
    assert sched2.slos["t"] == sched.slos["t"]
    assert sched2.launches == sched.launches


def test_load_state_rejects_unregistered_tenant():
    srv = AdaptiveServer(DEVICE, max_batch=4)
    sched = SLOScheduler(srv)
    with pytest.raises(ValueError):
        sched.load_state({"slos": {"ghost": {"deadline_s": 1.0,
                                             "priority": 0,
                                             "max_queue_depth": None}}})
