"""Distributed semantics on 8 placeholder devices — each case runs in a
subprocess so the 8-device XLA flag never leaks into other tests."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def run_sub(body: str, n_dev: int = 8, timeout: int = 420) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=str(REPO / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_ring_all_reduce_equals_psum():
    run_sub("""
        from jax.experimental.shard_map import shard_map
        from repro.distributed.collectives import ring_all_reduce
        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.arange(8 * 6, dtype=jnp.float32).reshape(8, 6)

        def ring(xl):
            return ring_all_reduce(xl, "data")

        def ref(xl):
            return jax.lax.psum(xl, "data")

        got = shard_map(ring, mesh=mesh, in_specs=P("data"),
                        out_specs=P("data"), check_rep=False)(x)
        want = shard_map(ref, mesh=mesh, in_specs=P("data"),
                         out_specs=P("data"), check_rep=False)(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6)
        # odd payload size exercises the padding path
        y = jnp.arange(8 * 7, dtype=jnp.float32).reshape(8, 7)
        got = shard_map(ring, mesh=mesh, in_specs=P("data"),
                        out_specs=P("data"), check_rep=False)(y)
        want = shard_map(ref, mesh=mesh, in_specs=P("data"),
                         out_specs=P("data"), check_rep=False)(y)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6)
        print("ring OK")
    """)


def test_bucketed_psum_matches_fused():
    run_sub("""
        from jax.experimental.shard_map import shard_map
        from repro.distributed.collectives import bucketed_psum
        mesh = jax.make_mesh((8,), ("data",))
        tree = {"a": jnp.ones((8, 4)), "b": jnp.arange(8.0).reshape(8, 1),
                "c": {"d": jnp.full((8, 3), 2.0)}}

        def f(t):
            return bucketed_psum(t, "data", n_buckets=2)

        got = shard_map(f, mesh=mesh, in_specs=P("data"),
                        out_specs=P("data"), check_rep=False)(tree)
        want = shard_map(lambda t: jax.tree.map(
                             lambda x: jax.lax.psum(x, "data"), t),
                         mesh=mesh, in_specs=P("data"),
                         out_specs=P("data"), check_rep=False)(tree)
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w))
        print("bucketed OK")
    """)


def test_gpipe_pipeline_forward():
    run_sub("""
        from repro.distributed.pipeline import gpipe_forward
        mesh = jax.make_mesh((4,), ("pipe",))
        # 4 stages, each y = x @ W_s (W_s = (s+1) * I), so pipeline
        # output = x * 1*2*3*4 = 24 x
        eye = jnp.eye(4)
        params = jnp.stack([eye * (s + 1) for s in range(4)])

        def stage(w, x):
            return x @ w

        fn = gpipe_forward(stage, mesh, axis="pipe")
        x_micro = jnp.arange(6 * 2 * 4, dtype=jnp.float32).reshape(6, 2, 4)
        out = jax.jit(fn)(params, x_micro)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x_micro) * 24,
                                   rtol=1e-5)
        print("gpipe OK")
    """)


def test_ring_all_reduce_padding_and_dtypes():
    """Edge cases of the explicit ring: payloads where x.size % n != 0
    (the padding path), a 1-device axis (identity), and integer dtypes —
    int sums are associative, so ring and psum must agree BIT-exactly."""
    run_sub("""
        from jax.experimental.shard_map import shard_map
        from repro.distributed.collectives import ring_all_reduce

        def both(mesh, axis, x):
            ring = shard_map(lambda v: ring_all_reduce(v, axis), mesh=mesh,
                             in_specs=P(axis), out_specs=P(axis),
                             check_rep=False)(x)
            ref = shard_map(lambda v: jax.lax.psum(v, axis), mesh=mesh,
                            in_specs=P(axis), out_specs=P(axis),
                            check_rep=False)(x)
            return np.asarray(ring), np.asarray(ref)

        mesh8 = jax.make_mesh((8,), ("data",))
        # per-device payload 3*5 = 15 elements: 15 % 8 != 0 pads by 1
        xi = jnp.arange(8 * 3 * 5, dtype=jnp.int32).reshape(8, 3, 5)
        g, w = both(mesh8, "data", xi)
        np.testing.assert_array_equal(g, w)          # bit-exact (ints)
        # payload smaller than the axis: 3 % 8 != 0 pads by 5
        xs = jnp.arange(8 * 3, dtype=jnp.int32).reshape(8, 3)
        g, w = both(mesh8, "data", xs)
        np.testing.assert_array_equal(g, w)
        # float with the padding path engaged: same sum up to order
        xf = jnp.linspace(-3, 3, 8 * 7).reshape(8, 7).astype(jnp.float32)
        g, w = both(mesh8, "data", xf)
        np.testing.assert_allclose(g, w, rtol=1e-6, atol=1e-5)
        # n == 1: the ring is the identity and must equal psum bit-exact
        mesh1 = Mesh(np.array(jax.devices()[:1]), ("one",))
        x1 = jnp.linspace(0, 1, 10).reshape(2, 5).astype(jnp.float32)
        g, w = both(mesh1, "one", x1)
        np.testing.assert_array_equal(g, w)
        np.testing.assert_array_equal(g, np.asarray(x1))
        print("ring edges OK")
    """)


def test_gpipe_fill_drain_vs_sequential():
    """Fill+drain schedule against a per-microbatch sequential reference,
    with a stage fn whose f(0) != 0 — stale fill/drain ticks compute on
    zero buffers, and only an explicit validity mask keeps their output
    out of the handoff ring."""
    run_sub("""
        from repro.distributed.pipeline import gpipe_forward
        n_stages = 4
        mesh = jax.make_mesh((n_stages,), ("pipe",))
        rng = np.random.default_rng(0)
        params = jnp.asarray(rng.normal(0, 0.5, (n_stages, 4, 4))
                             .astype(np.float32))

        def stage(w, x):
            # f(0) = 1 != 0: an unmasked drain tick would inject ones
            return x @ w + 1.0

        fn = jax.jit(gpipe_forward(stage, mesh, axis="pipe"))
        for n_micro in (1, 5, 6):
            x_micro = jnp.asarray(
                rng.normal(size=(n_micro, 2, 4)).astype(np.float32))
            ref = x_micro
            for s in range(n_stages):
                ref = jnp.einsum("mbi,ij->mbj", ref, params[s]) + 1.0
            out = fn(params, x_micro)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-5, atol=1e-6)
        print("gpipe fill+drain OK")
    """)


def test_sharded_train_step_matches_single_device():
    """The pjit'd train step on a 4x2 mesh computes the same loss as the
    unsharded step (up to float tolerance) — DP+TP correctness."""
    run_sub("""
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.distributed.sharding import (ShardingPolicy, batch_pspecs,
                                                state_pspecs, to_shardings)
        from repro.models import api
        from repro.models.frontends import make_inputs
        from repro.optim.adamw import AdamWConfig

        cfg = get_config("chatglm3-6b", smoke=True)
        opt = AdamWConfig(warmup_steps=2, total_steps=10)
        shape = ShapeConfig("t", 32, 8, "train")
        batch = make_inputs(cfg, shape, abstract=False)
        state = api.init_train_state(cfg, opt, jax.random.PRNGKey(0))
        _, m_ref = jax.jit(lambda s, b: api.train_step(cfg, opt, s, b))(
            state, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        policy = ShardingPolicy()
        sspec = state_pspecs(cfg, mesh, state, policy)
        bspec = batch_pspecs(cfg, mesh, batch)
        with mesh:
            st_sh = jax.device_put(state, to_shardings(mesh, sspec))
            b_sh = jax.device_put(batch, to_shardings(mesh, bspec))
            new_state, m = jax.jit(
                lambda s, b: api.train_step(cfg, opt, s, b),
                in_shardings=(to_shardings(mesh, sspec),
                              to_shardings(mesh, bspec)))(st_sh, b_sh)
        assert abs(float(m["loss"]) - float(m_ref["loss"])) < 1e-3, (
            float(m["loss"]), float(m_ref["loss"]))
        print("sharded train OK", float(m["loss"]))
    """)


def test_fsdp_sharded_state_fits_and_runs():
    run_sub("""
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.distributed.sharding import (ShardingPolicy, batch_pspecs,
                                                state_pspecs, to_shardings)
        from repro.models import api
        from repro.models.frontends import make_inputs
        from repro.optim.adamw import AdamWConfig
        import dataclasses

        cfg = get_config("llama3.2-1b", smoke=True)
        cfg = dataclasses.replace(cfg, d_model=128, d_ff=512, head_dim=16,
                                  fsdp=True)
        opt = AdamWConfig(warmup_steps=2, total_steps=10)
        shape = ShapeConfig("t", 32, 8, "train")
        batch = make_inputs(cfg, shape, abstract=False)
        state = api.init_train_state(cfg, opt, jax.random.PRNGKey(0))
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        policy = ShardingPolicy(fsdp=True)
        sspec = state_pspecs(cfg, mesh, state, policy)
        with mesh:
            st_sh = jax.device_put(state, to_shardings(mesh, sspec))
            # big leaves actually sharded over data
            emb = st_sh.params["embed"]
            assert len(emb.sharding.device_set) == 8, emb.sharding
            _, m = jax.jit(lambda s, b: api.train_step(cfg, opt, s, b))(
                st_sh, batch)
        assert np.isfinite(float(m["loss"]))
        print("fsdp OK", float(m["loss"]))
    """)


def test_elastic_remesh_restore():
    """Save under a 4x2 mesh, restore under 3x2 (simulating a lost
    host) — the checkpoint reshards onto the surviving devices."""
    run_sub("""
        import tempfile
        from repro.checkpoint import store
        from repro.configs import get_config
        from repro.distributed.sharding import (ShardingPolicy, state_pspecs,
                                                to_shardings)
        from repro.models import api
        from repro.optim.adamw import AdamWConfig
        from repro.runtime.fault_tolerance import elastic_remesh

        cfg = get_config("olmo-1b", smoke=True)
        opt = AdamWConfig()
        state = api.init_train_state(cfg, opt, jax.random.PRNGKey(0))
        mesh1 = jax.make_mesh((4, 2), ("data", "model"))
        sspec1 = state_pspecs(cfg, mesh1, state, ShardingPolicy())
        st1 = jax.device_put(state, to_shardings(mesh1, sspec1))
        d = tempfile.mkdtemp()
        store.save(d, 3, st1, extra={"next_step": 4})

        # 2 devices died: remesh over 6
        mesh2 = elastic_remesh(6, prefer_model=2)
        assert mesh2.devices.size == 6
        sspec2 = state_pspecs(cfg, mesh2, state, ShardingPolicy())
        restored, extra = store.restore(
            d, state, shardings=to_shardings(mesh2, sspec2))
        assert extra["next_step"] == 4
        for a, b in zip(jax.tree.leaves(st1), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("elastic OK")
    """)


def test_dryrun_cells_tiny_mesh():
    """End-to-end dry-run machinery on an 8-device mesh: one train cell
    and one decode cell must lower+compile with coherent shardings."""
    run_sub("""
        import repro.launch.mesh as mesh_mod
        # monkeypatch the production mesh down to 4x2 for this test
        mesh_mod.make_production_mesh = \
            lambda multi_pod=False: jax.make_mesh(
                (2, 2, 2) if multi_pod else (4, 2),
                ("pod", "data", "model") if multi_pod else ("data", "model"))
        import repro.launch.dryrun as dr
        dr.make_production_mesh = mesh_mod.make_production_mesh
        import dataclasses, json, tempfile
        from pathlib import Path
        import repro.configs as C
        # shrink shapes so the tiny mesh compiles fast
        C.SHAPES["train_4k"] = dataclasses.replace(
            C.SHAPES["train_4k"], seq_len=64, global_batch=8)
        C.SHAPES["decode_32k"] = dataclasses.replace(
            C.SHAPES["decode_32k"], seq_len=128, global_batch=8)
        dr.SHAPES = C.SHAPES
        out = Path(tempfile.mkdtemp())
        for shape in ("train_4k", "decode_32k"):
            for multi in (False, True):
                rec = dr.run_cell("olmo-1b", shape, multi, out,
                                  force=True, calibrate=False)
                assert rec["status"] == "ok", rec.get("error")
        print("dryrun tiny OK")
    """, timeout=420)
