"""Fused CNN-block kernels + fusion-aware planning.

Numerics: the fused members share the standalone kernels' inner-loop
bodies, so float32 fused output is BITWISE equal to the three-launch
chain; lowered rungs stay within the deployment error bound (5e-2)
against the composite f32 oracle.  Planner: fusable conv->pool->act
triples substitute a single fused site when the combined footprint fits
and wins, fall back per group otherwise, and flow through replan —
whose strict= escape hatch verifies the fast path against a cold plan.
"""
import dataclasses
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ip import SiteSpec
from repro.core.library import CNN_FUSED, _fused_ref
from repro.core.plan import (clear_plan_cache, plan_network, planner_stats,
                             replan)
from repro.core.resources import ResourceBudget
from repro.kernels.activation.ops import activation
from repro.kernels.conv2d.ops import conv2d
from repro.kernels.fused.cnn_block import fused_cnn_mxu, fused_cnn_vpu
from repro.kernels.pool2d.ops import pool2d
from repro.models.blocks import apply_cnn_block, cnn_block_site_specs


def _unfused_chain(x, w, conv_ip, *, window, stride, mode, kind):
    y = conv2d(x, w, ip=conv_ip)
    y = pool2d(y, window=window, stride=stride, mode=mode, ip="pool_vpu")
    return activation(y, kind=kind, ip="act_vpu")


def _block_specs(shape=(2, 16, 16, 4), cout=16, ladder=(), site="blk",
                 dtype="float32", **kw):
    cin = shape[-1]
    specs, _ = cnn_block_site_specs(shape, (3, 3, cin, cout), x_dtype=dtype,
                                    site=site, ladder=ladder, **kw)
    return specs


# --------------------------------------------------------------------------
# Numerics: fused vs the three-launch path
# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape,cout", [((2, 12, 12, 4), 8),
                                        ((1, 16, 16, 3), 16),
                                        ((2, 9, 11, 2), 5)])
@pytest.mark.parametrize("stride", [None, (1, 1)])
@pytest.mark.parametrize("mode,kind", [("max", "relu"), ("avg", "tanh")])
def test_fused_f32_bitwise_equals_three_launch_path(rng, shape, cout,
                                                    stride, mode, kind):
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, shape[-1], cout))
                    .astype(np.float32))
    for fused, conv_ip in ((fused_cnn_vpu, "ip1_vpu"),
                           (fused_cnn_mxu, "ip2_mxu")):
        want = _unfused_chain(x, w, conv_ip, window=(2, 2), stride=stride,
                              mode=mode, kind=kind)
        got = fused(x, w, pool_window=(2, 2), pool_stride=stride,
                    pool_mode=mode, act_kind=kind)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_native_int8_bitwise_equals_three_launch_path(rng):
    x = jnp.asarray(rng.integers(-20, 20, (2, 12, 12, 4)).astype(np.int8))
    w = jnp.asarray(rng.integers(-8, 8, (3, 3, 4, 8)).astype(np.int8))
    for mode in ("max", "avg"):    # int avg must keep the floor division
        want = _unfused_chain(x, w, "ip1_vpu", window=(2, 2), stride=None,
                              mode=mode, kind="relu")
        got = fused_cnn_vpu(x, w, pool_mode=mode, act_kind="relu")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", [8, 16])
@pytest.mark.parametrize("ip", ["fused_vpu", "fused_mxu"])
def test_quantized_fused_within_bound_of_oracle(rng, bits, ip):
    from repro.quant.ops import quantized_fused_cnn_block
    x = jnp.asarray(rng.normal(size=(2, 12, 12, 4)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, (3 * 3 * 4) ** -0.5, size=(3, 3, 4, 8))
                    .astype(np.float32))
    ref = _fused_ref(x, w, window=(2, 2), mode="max", kind="relu")
    got = quantized_fused_cnn_block(x, w, pool_mode="max",
                                    activation="relu", bits=bits, ip=ip)
    rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    assert rel <= 5e-2, rel


def test_fused_block_execution_matches_unfused_plan(rng):
    from repro.models.blocks import init_cnn_block
    blk = init_cnn_block(jax.random.PRNGKey(0), cin=4, cout=16, k=3)
    x = jnp.asarray(rng.normal(size=(2, 16, 16, 4)).astype(np.float32))
    y0 = apply_cnn_block(blk, x, activation="relu")
    plan = {}
    y1 = apply_cnn_block(blk, x, activation="relu", fuse=True, plan=plan)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    assert list(plan) == ["cnn_block.fused"]   # ONE launch recorded


def test_fused_frontend_matches_unfused(rng):
    from repro.models.frontends import apply_cnn_frontend, init_cnn_frontend
    p = init_cnn_frontend(jax.random.PRNGKey(1), channels=(3, 8, 16),
                          d_model=32)
    imgs = jnp.asarray(rng.normal(size=(2, 16, 16, 3)).astype(np.float32))
    z0 = apply_cnn_frontend(p, imgs)
    z1 = apply_cnn_frontend(p, imgs, fuse=True)
    np.testing.assert_array_equal(np.asarray(z0), np.asarray(z1))


def test_mismatched_fused_network_rejected(rng):
    from repro.models.blocks import init_cnn_block
    blk = init_cnn_block(jax.random.PRNGKey(0), cin=3, cout=16, k=3)
    images = jnp.asarray(rng.normal(size=(2, 16, 16, 3)).astype(np.float32))
    specs, _ = cnn_block_site_specs(images.shape, blk["w"].shape,
                                    x_dtype=images.dtype, activation="relu")
    network = plan_network(specs, fuse=True)
    assert "cnn_block.fused" in network
    with pytest.raises(ValueError, match="plan/site mismatch"):
        apply_cnn_block(blk, images, activation="tanh", network=network)


# --------------------------------------------------------------------------
# Fusion-aware planning
# --------------------------------------------------------------------------
def test_fused_plan_collapses_sites_and_cycles():
    specs = []
    shape = (2, 32, 32, 8)
    for li, (cin, cout) in enumerate([(8, 16), (16, 32)]):
        layer, out = cnn_block_site_specs(shape, (3, 3, cin, cout),
                                          x_dtype="float32",
                                          site=f"fuse{li}", ladder=(16, 8))
        specs += layer
        shape = out.shape
    for budget in (ResourceBudget(), ResourceBudget(mxu_available=False),
                   ResourceBudget(vmem_bytes=600 * 1024)):
        unfused = plan_network(specs, budget, fuse=False)
        fused = plan_network(specs, budget, fuse=True)
        assert len(fused) == 2 and len(unfused) == 6
        assert fused.total_launches == 2           # 3 -> 1 per block
        assert unfused.total_launches == 6
        assert fused.total_cycles < unfused.total_cycles
        for s in fused.sites:
            assert s.spec.family == "cnn_fused"
            assert s.footprint.hbm_bytes < sum(
                u.footprint.hbm_bytes for u in unfused.sites
                if u.spec.name.startswith(s.spec.name.split(".")[0]))


def test_fusion_is_default_with_explicit_opt_out():
    # Fusion is on by default (it is the honest est-cycles winner);
    # fuse=False remains the explicit escape hatch for per-op plans.
    specs = _block_specs(site="nofuse")
    plan = plan_network(specs, ResourceBudget())
    assert [s.spec.family for s in plan.sites] == ["cnn_fused"]
    unfused = plan_network(specs, ResourceBudget(), fuse=False)
    assert len(unfused) == 3
    assert all(s.spec.family != "cnn_fused" for s in unfused.sites)


def test_dual_conv_is_not_fused():
    conv = SiteSpec.make("d.conv", "conv2d",
                         ((2, 16, 16, 4), (3, 3, 4, 8)), "int8", dual=True)
    pool = SiteSpec.make("d.pool", "pool2d", ((2, 14, 14, 8),), "int32",
                         window=(2, 2), stride=None, mode="max")
    act = SiteSpec.make("d.act", "activation", ((2, 7, 7, 8),), "int32",
                        kind="relu")
    assert CNN_FUSED.fuse_sites((conv, pool, act)) is None


def test_nonchaining_shapes_are_not_fused():
    conv = SiteSpec.make("n.conv", "conv2d",
                         ((2, 16, 16, 4), (3, 3, 4, 8)), "float32",
                         dual=False)
    pool = SiteSpec.make("n.pool", "pool2d", ((2, 10, 10, 8),), "float32",
                         window=(2, 2), stride=None, mode="max")
    act = SiteSpec.make("n.act", "activation", ((2, 5, 5, 8),), "float32",
                        kind="relu")
    plan = plan_network((conv, pool, act), ResourceBudget(), fuse=True)
    assert all(s.spec.family != "cnn_fused" for s in plan.sites)


def test_fused_partition_failure_falls_back_per_group():
    """When a fused footprint is individually feasible but the fused
    groups jointly overflow the envelope, the planner unfuses group by
    group instead of failing — the unfused triple is the floor."""
    specs = _block_specs((2, 16, 16, 4), 16, site="fb0") + \
        _block_specs((2, 16, 16, 4), 16, site="fb1")
    budget = ResourceBudget(vmem_bytes=96 * 1024)
    members = [CNN_FUSED.members[n] for n in sorted(CNN_FUSED.members)]
    originals = [m.footprint_fn for m in members]

    # each inflated fused group needs ~51% of the envelope: feasible at
    # full budget (and alongside one unfused triple at ~48%), but two
    # fused groups cannot share it
    def inflate(fn):
        def wrapped(*a, **kw):
            fp = fn(*a, **kw)
            return dataclasses.replace(fp, vmem_bytes=49 * 1024)
        return wrapped

    try:
        for m, fn in zip(members, originals):
            object.__setattr__(m, "footprint_fn", inflate(fn))
        clear_plan_cache()
        before = planner_stats().fused_fallbacks
        plan = plan_network(specs, budget, fuse=True)
        # one group kept fused (40 KiB fits alone), the other unfused
        fams = [s.spec.family for s in plan.sites]
        assert fams.count("cnn_fused") == 1
        assert len(plan) == 4                  # 1 fused + 3 unfused
        assert planner_stats().fused_fallbacks > before
    finally:
        for m, fn in zip(members, originals):
            object.__setattr__(m, "footprint_fn", fn)
        clear_plan_cache()


def test_fused_dma_traffic_strictly_smaller():
    """The counted DMA saving that drives the est-cycles win: the fused
    footprint's HBM column drops the intermediate conv and pool tensors
    entirely."""
    specs = _block_specs((2, 16, 16, 4), 16, site="resc")
    unfused = plan_network(specs, ResourceBudget(), fuse=False)
    fused = plan_network(specs, ResourceBudget(), fuse=True)
    total_unfused_hbm = sum(s.footprint.hbm_bytes for s in unfused.sites)
    assert fused.site("resc.fused").footprint.hbm_bytes < total_unfused_hbm
    assert fused.total_cycles < unfused.total_cycles


# --------------------------------------------------------------------------
# replan: fusion flows through the fast path; strict= verifies it
# --------------------------------------------------------------------------
def test_replan_fast_path_serves_fused_graphs():
    specs = tuple(_block_specs((2, 32, 32, 8), 16, site="rp",
                               ladder=(16, 8)))
    clear_plan_cache()
    plan_network(specs, ResourceBudget(), fuse=True)
    stats = planner_stats()
    fast0 = stats.replan_fast
    moved = replan(specs, ResourceBudget(vmem_bytes=2 * 2**20), fuse=True)
    assert stats.replan_fast == fast0 + 1
    assert any(s.spec.family == "cnn_fused" for s in moved.sites)


def test_replan_cold_counter_counts_unknown_graphs():
    specs = tuple(_block_specs((1, 12, 12, 3), 8, site="cold"))
    clear_plan_cache()
    stats = planner_stats()
    cold0 = stats.replan_cold
    replan(specs, ResourceBudget())
    assert stats.replan_cold == cold0 + 1


@pytest.mark.parametrize("fuse", [False, True])
def test_replan_strict_matches_cold_plan(fuse):
    """The PR 4 caveat, closed: strict=True guarantees the replan result
    carries the same assignment a cold plan would choose."""
    from repro.core.plan import _assignment, _plan_uncached
    specs = tuple(_block_specs((2, 32, 32, 8), 32, site="strict",
                               ladder=(16, 8)))
    clear_plan_cache()
    plan_network(specs, ResourceBudget(), fuse=fuse)
    for vmem in (4 * 2**20, 600 * 1024, 350 * 1024):
        budget = ResourceBudget(vmem_bytes=vmem)
        try:
            got = replan(specs, budget, fuse=fuse, strict=True)
        except ValueError:
            continue
        cold = _plan_uncached(specs, budget, fuse=fuse)
        assert _assignment(got) == _assignment(cold)


def test_fused_network_with_unfusable_call_raises_value_error(rng):
    """A fused plan paired with a call whose geometry cannot fuse must
    fail with the explanatory mismatch error, not a KeyError."""
    from repro.models.blocks import init_cnn_block
    blk = init_cnn_block(jax.random.PRNGKey(0), cin=3, cout=16, k=3)
    images = jnp.asarray(rng.normal(size=(2, 16, 16, 3)).astype(np.float32))
    specs, _ = cnn_block_site_specs(images.shape, blk["w"].shape,
                                    x_dtype=images.dtype, activation="relu")
    network = plan_network(specs, fuse=True)
    with pytest.raises(ValueError, match="plan/site mismatch"):
        apply_cnn_block(blk, images, activation="relu", network=network,
                        pool_window=(3, 3))


def test_replan_strict_ignores_cached_heuristic_after_share_eviction():
    """strict=True must not trust a plan a prior non-strict replan
    cached, even when the share/fuse caches were since evicted."""
    from repro.core import plan as plan_mod
    from repro.core.plan import _assignment, _plan_uncached
    specs = tuple(_block_specs((2, 32, 32, 8), 16, site="evict",
                               ladder=(16, 8)))
    clear_plan_cache()
    plan_network(specs, ResourceBudget(), fuse=True)
    budget = ResourceBudget(vmem_bytes=2 * 2**20)
    replan(specs, budget, fuse=True)          # heuristic plan now cached
    plan_mod._SHARE_CACHE.clear()
    plan_mod._FUSE_CACHE.clear()
    got = replan(specs, budget, fuse=True, strict=True)
    assert _assignment(got) == _assignment(
        _plan_uncached(specs, budget, fuse=True))


# --------------------------------------------------------------------------
# Serving + autotune integration
# --------------------------------------------------------------------------
def test_serving_fused_lowers_latency_and_matches_numerics(rng):
    from repro.models.frontends import init_cnn_frontend
    from repro.runtime import AdaptiveServer
    params = init_cnn_frontend(jax.random.PRNGKey(0), channels=(8, 16),
                               d_model=32)
    x = rng.normal(size=(32, 32, 8)).astype(np.float32)
    results = {}
    for fuse in (False, True):
        clear_plan_cache()
        srv = AdaptiveServer(ResourceBudget(), policy="static",
                             max_batch=2, fuse=fuse)
        srv.register("t", params, (32, 32, 8))
        srv.submit("t", x)
        (c,) = srv.drain()
        results[fuse] = c
    np.testing.assert_array_equal(np.asarray(results[False].result),
                                  np.asarray(results[True].result))
    # latency is est-cycles of the executed plan: the fused plan's saved
    # HBM round-trips make the serving hot path strictly cheaper
    assert results[True].latency < results[False].latency


def test_autotune_covers_fused_sites(rng):
    from repro.core.autotune import plan_tile_overrides
    from repro.models.blocks import init_cnn_block
    specs = _block_specs((2, 16, 16, 4), 16, site="tune")
    plan = plan_network(specs, ResourceBudget(), fuse=True)
    overrides = plan_tile_overrides(plan)
    assert "tune.fused" in overrides
    assert "block_cout" in overrides["tune.fused"]
    blk = init_cnn_block(jax.random.PRNGKey(0), cin=4, cout=16, k=3)
    x = jnp.asarray(rng.normal(size=(2, 16, 16, 4)).astype(np.float32))
    y0 = apply_cnn_block(blk, x, activation="relu", site="tune")
    y1 = apply_cnn_block(blk, x, activation="relu", site="tune",
                         network=plan, tile_overrides=overrides)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


# --------------------------------------------------------------------------
# Bench acceptance (benchmarks/run.py::table_fusion)
# --------------------------------------------------------------------------
def _load_bench():
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "benchmarks" / "run.py")
    spec = importlib.util.spec_from_file_location("bench_run_fusion", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_table_fusion_reports_modeled_and_measured_separately():
    bench = _load_bench()
    bench.table_fusion()
    rows = [d for n, _, d in bench.ROWS if n.startswith("table_fusion.")]
    assert rows
    both = [d for d in rows if "unfused=x" not in d and "fused=x" not in d]
    # The analytical model prices fused strictly cheaper on >= 2 budgets
    # (the counted DMA-byte saving) — a claim about the MODEL only.
    assert sum("modeled_wins=1" in d for d in both) >= 2, both
    # The measured verdict must be reported as its OWN flag on every
    # row (never asserted to equal the modeled one: the two disagreeing
    # is real data — it is why the calibration layer exists).
    for d in both:
        assert "measured_wins=" in d, d
        assert "never_worse" not in d and "fused_wins" not in d, d
    # launch count 3 -> 1 per block, errors within the deployment bound
    for d in both:
        assert "launches_unfused=9" in d and "launches_fused=3" in d, d
        assert "err_ok=1" in d, d
