"""Pallas selective-scan vs the lax.scan oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mamba_scan.ref import selective_scan_ref
from repro.kernels.mamba_scan.scan import selective_scan

CASES = [  # (B, T, Di, Ds, block_di)
    (1, 8, 16, 4, 16),
    (2, 16, 32, 8, 16),     # Di > block -> grid over di blocks
    (2, 12, 24, 4, 8),
]


def _data(rng, b, t, di, ds):
    x = jnp.asarray(rng.normal(size=(b, t, di)).astype(np.float32))
    dt = jnp.asarray(0.1 * np.abs(rng.normal(size=(b, t, di))).astype(np.float32))
    bp = jnp.asarray(rng.normal(size=(b, t, ds)).astype(np.float32))
    cp = jnp.asarray(rng.normal(size=(b, t, ds)).astype(np.float32))
    a = jnp.asarray(-np.abs(rng.normal(size=(di, ds))).astype(np.float32))
    return x, dt, bp, cp, a


@pytest.mark.parametrize("case", CASES)
def test_selective_scan_matches_ref(rng, case):
    b, t, di, ds, bdi = case
    x, dt, bp, cp, a = _data(rng, b, t, di, ds)
    y_k, h_k = selective_scan(x, dt, bp, cp, a, block_di=bdi)
    y_r, h_r = selective_scan_ref(x, dt, bp, cp, a)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=1e-5, atol=1e-6)


def test_footprint_hbm_advantage():
    """The kernel's HBM traffic must beat the scan twin's state
    round-trips by ~Ds for long sequences."""
    from repro.kernels.mamba_scan.scan import footprint
    b, t, di, ds = 8, 4096, 4096, 16
    fp = footprint(b, t, di, ds)
    scan_twin_state_traffic = 2 * b * t * di * ds * 4  # h out+in per step
    assert fp.hbm_bytes * 4 < scan_twin_state_traffic
    assert fp.mxu_passes == 0  # Conv1-style logic-only member
