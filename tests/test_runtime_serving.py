"""Serving runtime: arbiter split semantics, shape-bucketed batching
correctness, ladder descent under budget pressure, plan-cache
statistics, and the replan fast path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as plan_mod
from repro.core.ip import SiteSpec
from repro.core.plan import (clear_plan_cache, network_min_fraction,
                             plan_cache_stats, plan_network, planner_stats,
                             replan)
from repro.core.resources import ResourceBudget
from repro.models.frontends import apply_cnn_frontend, init_cnn_frontend
from repro.runtime import AdaptiveServer, BudgetArbiter, ShapeBucketQueue
from repro.runtime.batching import Request

SERVING_DEVICE = ResourceBudget(vpu_ops_budget=15_000_000)


def _frontend(key=0, channels=(6, 12), d_model=16):
    return init_cnn_frontend(jax.random.PRNGKey(key), channels=channels,
                             d_model=d_model)


# --------------------------------------------------------------------------
# Arbiter: proportional split + needs-floor interaction
# --------------------------------------------------------------------------
def test_arbiter_demand_proportional_with_floors():
    arb = BudgetArbiter(ResourceBudget(), rebalance_threshold=0.01,
                        demand_alpha=1.0)
    arb.register("a", floor=0.3)
    arb.register("b", floor=0.1)
    arb.observe("a", 100.0)
    arb.observe("b", 900.0)
    shares = arb.split()
    # surplus 0.6 follows demand: a = 0.3 + 0.6*0.1, b = 0.1 + 0.6*0.9
    assert shares["a"].fraction == pytest.approx(0.36)
    assert shares["b"].fraction == pytest.approx(0.64)
    assert sum(s.fraction for s in shares.values()) == pytest.approx(1.0)
    # every grant respects its floor no matter the skew
    assert shares["a"].fraction >= shares["a"].floor
    assert shares["b"].fraction >= shares["b"].floor


def test_arbiter_static_ignores_demand():
    arb = BudgetArbiter(ResourceBudget(), policy="static")
    arb.register("a", floor=0.3)
    arb.register("b", floor=0.0)
    arb.observe("a", 1.0)
    arb.observe("b", 1e9)
    shares = arb.split()
    assert shares["a"].fraction == pytest.approx(0.5)
    assert shares["b"].fraction == pytest.approx(0.5)


def test_arbiter_floors_exceeding_envelope_rejected():
    arb = BudgetArbiter(ResourceBudget())
    arb.register("a", floor=0.7)
    with pytest.raises(ValueError, match="jointly need"):
        arb.register("b", floor=0.5)
    # regression: a rejected registration leaves no ghost tenant behind
    assert "b" not in arb._floors
    shares = arb.split()
    assert set(shares) == {"a"}
    # and the name is re-registrable with feasible parameters
    arb.register("b", floor=0.1)
    assert set(arb.split()) == {"a", "b"}


def test_arbiter_static_rejects_floor_above_even_share():
    """Regression: static policy grants an unconditional 1/n, so a
    tenant whose floor exceeds that must be rejected at admission (the
    demand policy would happily serve the same pair)."""
    arb = BudgetArbiter(ResourceBudget(), policy="static")
    arb.register("a", floor=0.65)       # fine alone: 1/1 grant
    with pytest.raises(ValueError, match="static even split"):
        arb.register("b", floor=0.1)    # would shrink a's grant to 0.5
    assert "b" not in arb._floors
    demand = BudgetArbiter(ResourceBudget(), policy="demand")
    demand.register("a", floor=0.65)
    demand.register("b", floor=0.1)     # jointly 0.75: demand serves it


def test_arbiter_hysteresis_gates_rebalances():
    arb = BudgetArbiter(ResourceBudget(), rebalance_threshold=0.2,
                        demand_alpha=1.0)
    arb.register("a")
    arb.register("b")
    arb.observe("a", 100.0)
    arb.observe("b", 100.0)
    first = arb.split()
    assert arb.rebalances == 0          # initial grant is not a rebalance
    # small drift: inside the threshold, grants hold
    arb.observe("a", 120.0)
    arb.observe("b", 100.0)
    held = arb.split()
    assert held["a"].fraction == first["a"].fraction
    assert arb.rebalances == 0
    # large drift: grants snap to target
    arb.observe("a", 1000.0)
    arb.observe("b", 10.0)
    moved = arb.split()
    assert moved["a"].fraction > 0.8
    assert arb.rebalances == 1


def test_arbiter_late_registration_regrants():
    """Regression: a tenant registered after the first split must be
    granted on the next round even when no drift crosses the
    hysteresis threshold."""
    arb = BudgetArbiter(ResourceBudget(), rebalance_threshold=0.05,
                        demand_alpha=1.0)
    arb.register("a", floor=0.3)
    arb.observe("a", 100.0)
    arb.split()
    arb.register("b", floor=0.02)       # low floor, zero demand
    arb.observe("a", 100.0)
    shares = arb.split()                # must not KeyError
    assert shares["b"].fraction >= shares["b"].floor
    assert sum(s.fraction for s in shares.values()) == pytest.approx(1.0)
    assert arb.rebalances == 1          # topology change forced a re-grant


def test_network_min_fraction_is_feasibility_threshold():
    specs = tuple(
        SiteSpec.make(f"c{i}.conv", "conv2d",
                      ((2, 16, 16, 8), (3, 3, 8, 16)), "int8", dual=False)
        for i in range(3))
    budget = ResourceBudget(vmem_bytes=2 * 2**20)
    floor = network_min_fraction(specs, budget)
    assert 0.0 < floor <= 1.0
    plan_network(specs, budget.scaled(min(1.0, floor * 1.05)))  # feasible
    if floor > 0.02:
        with pytest.raises(ValueError):
            plan_network(specs, budget.scaled(floor * 0.5))


# --------------------------------------------------------------------------
# Shape-bucketed batching
# --------------------------------------------------------------------------
def test_bucket_queue_groups_by_tenant_and_shape():
    q = ShapeBucketQueue()
    a1 = np.zeros((4, 4, 1), np.float32)
    a2 = np.zeros((8, 8, 1), np.float32)
    for rid, (tenant, x) in enumerate([("t1", a1), ("t1", a1), ("t2", a1),
                                       ("t1", a2)]):
        q.push(Request(rid=rid, tenant=tenant, x=x, arrival=0.0))
    assert len(q) == 4
    assert q.pending("t1") == 3
    keys = q.keys()
    assert len(keys) == 3               # (t1, 4x4), (t2, 4x4), (t1, 8x8)
    batch = q.pop_batch(keys[0], max_batch=8)
    assert [r.rid for r in batch] == [0, 1]   # FIFO within the bucket
    assert q.pending("t1") == 1


def test_server_batching_matches_per_request_execution(rng):
    clear_plan_cache()
    params = _frontend()
    srv = AdaptiveServer(ResourceBudget(), max_batch=4)
    srv.register("t", params, (12, 12, 6))
    xs = [rng.normal(size=(12, 12, 6)).astype(np.float32) for _ in range(5)]
    rids = [srv.submit("t", x) for x in xs]
    completions = {c.rid: c for c in srv.drain()}
    assert len(completions) == 5
    # 5 requests at max_batch 4 -> batches of 4 and 1
    assert sorted(c.batch_size for c in completions.values()) == \
        [1, 4, 4, 4, 4]
    for rid, x in zip(rids, xs):
        want = apply_cnn_frontend(params, jnp.asarray(x)[None])[0]
        np.testing.assert_allclose(np.asarray(completions[rid].result),
                                   np.asarray(want), rtol=1e-5, atol=1e-5)


def test_server_buckets_mixed_shapes_separately(rng):
    clear_plan_cache()
    params = _frontend()
    srv = AdaptiveServer(ResourceBudget(), max_batch=4)
    srv.register("t", params, (12, 12, 6))
    small = rng.normal(size=(12, 12, 6)).astype(np.float32)
    with pytest.raises(ValueError, match="expects samples of shape"):
        srv.submit("t", rng.normal(size=(16, 16, 6)).astype(np.float32))
    rid = srv.submit("t", small)
    (done,) = srv.drain()
    assert done.rid == rid and done.batch_size == 1


def test_server_batch_submission_fans_out(rng):
    clear_plan_cache()
    srv = AdaptiveServer(ResourceBudget(), max_batch=4)
    srv.register("t", _frontend(), (12, 12, 6))
    rids = srv.submit("t", rng.normal(size=(3, 12, 12, 6)).astype(np.float32))
    assert len(rids) == 3
    done = srv.drain()
    assert {c.rid for c in done} == set(rids)
    assert all(c.batch_size == 3 for c in done)


# --------------------------------------------------------------------------
# Ladder descent under budget pressure (degrade-before-fail)
# --------------------------------------------------------------------------
def test_squeezed_tenant_descends_ladder_within_error_bound(rng):
    clear_plan_cache()
    # fuse=False: the squeeze thresholds below were sized against the
    # per-op footprints — the fused group fits the slice without lowering
    srv = AdaptiveServer(SERVING_DEVICE, policy="demand", max_batch=4,
                         fuse=False)
    srv.register("heavy", _frontend(0, channels=(8, 16), d_model=32),
                 (32, 32, 8))
    srv.register("light", _frontend(1), (24, 24, 6), activation="tanh",
                 ladder=(16, 8), measure_quant=True)
    for _ in range(10):
        srv.submit("heavy", rng.normal(size=(32, 32, 8)).astype(np.float32))
    for _ in range(2):
        srv.submit("light", rng.normal(size=(24, 24, 6)).astype(np.float32))
    srv.drain()
    tel = srv.telemetry()
    light = tel["light"]
    # squeezed below its f32 footprint, the tenant serves lowered...
    assert light["granted_fraction"] < 0.15
    assert light["lowered_fraction"] > 0.0
    assert any(b < 32 for b in light["precision_mix"])
    # ...within the documented error bound
    assert 0.0 < light["max_quant_rel_err"] <= 5e-2
    # the heavy tenant was granted the bulk and stayed full-precision
    heavy = tel["heavy"]
    assert heavy["granted_fraction"] > 0.8
    assert set(heavy["precision_mix"]) == {32}


def test_static_even_split_leaves_light_tenant_at_f32(rng):
    clear_plan_cache()
    srv = AdaptiveServer(SERVING_DEVICE, policy="static", max_batch=4)
    srv.register("heavy", _frontend(0, channels=(8, 16), d_model=32),
                 (32, 32, 8))
    srv.register("light", _frontend(1), (24, 24, 6), activation="tanh",
                 ladder=(16, 8), measure_quant=True)
    for _ in range(4):
        srv.submit("heavy", rng.normal(size=(32, 32, 8)).astype(np.float32))
    srv.submit("light", rng.normal(size=(24, 24, 6)).astype(np.float32))
    srv.drain()
    light = srv.telemetry()["light"]
    assert light["granted_fraction"] == pytest.approx(0.5)
    assert set(light["precision_mix"]) == {32}


def test_infeasible_tenant_rejected_at_registration():
    clear_plan_cache()
    srv = AdaptiveServer(ResourceBudget(vmem_bytes=1024), max_batch=2)
    with pytest.raises(ValueError, match="no feasible"):
        srv.register("t", _frontend(), (12, 12, 6))


def test_registration_prices_the_max_batch_graph_too():
    """Regression: a tenant whose one-sample graph fits the device but
    whose max-batch graph does not must be rejected at admission, not
    crash at serving time with requests already dequeued."""
    clear_plan_cache()
    device = ResourceBudget(vpu_ops_budget=80_000)
    srv = AdaptiveServer(device, max_batch=4)
    with pytest.raises(ValueError, match="no feasible"):
        srv.register("t", _frontend(1), (24, 24, 6), activation="tanh")
    # the same tenant at max_batch=1 is admissible
    srv1 = AdaptiveServer(device, max_batch=1)
    srv1.register("t", _frontend(1), (24, 24, 6), activation="tanh")


# --------------------------------------------------------------------------
# Plan-cache statistics + eviction
# --------------------------------------------------------------------------
def test_plan_cache_stats_track_hits_and_misses():
    clear_plan_cache()
    spec = SiteSpec.make("s.conv", "conv2d",
                         ((2, 16, 16, 8), (3, 3, 8, 16)), "int8", dual=False)
    before = plan_cache_stats()
    plan_network([spec], ResourceBudget())
    plan_network([spec], ResourceBudget())
    after = plan_cache_stats()
    assert after["misses"] == before["misses"] + 1
    assert after["hits"] == before["hits"] + 1
    assert after["size"] >= 1
    assert after["capacity"] == plan_mod._PLAN_CACHE_MAX
    assert 0.0 <= after["hit_rate"] <= 1.0


def test_plan_cache_evicts_lru_at_capacity(monkeypatch):
    clear_plan_cache()
    monkeypatch.setattr(plan_mod, "_PLAN_CACHE_MAX", 2)
    def spec(i):
        return SiteSpec.make(f"s{i}.conv", "conv2d",
                             ((1, 8 + i, 8 + i, 4), (3, 3, 4, 8)),
                             "int8", dual=False)
    ev0 = planner_stats().plan_evictions
    plan_network([spec(0)], ResourceBudget())
    plan_network([spec(1)], ResourceBudget())
    plan_network([spec(0)], ResourceBudget())     # refresh 0 -> 1 is LRU
    plan_network([spec(2)], ResourceBudget())     # evicts 1
    assert planner_stats().plan_evictions == ev0 + 1
    assert len(plan_mod._PLAN_CACHE) == 2
    misses = planner_stats().plan_misses
    plan_network([spec(0)], ResourceBudget())     # still cached
    assert planner_stats().plan_misses == misses
    plan_network([spec(1)], ResourceBudget())     # was evicted: a miss
    assert planner_stats().plan_misses == misses + 1


# --------------------------------------------------------------------------
# replan(): the live re-planning fast path
# --------------------------------------------------------------------------
def _replan_specs():
    return tuple(
        SiteSpec.make(f"r{i}.conv", "conv2d",
                      ((2, 24, 24, 8), (3, 3, 8, 16)), "float32",
                      ladder=(16, 8), dual=False)
        for i in range(2))


def test_replan_skips_baseline_on_known_graph():
    clear_plan_cache()
    specs = _replan_specs()
    plan_network(specs, ResourceBudget())          # seeds the cost shares
    evals_cold = planner_stats().selector_evals
    fast = planner_stats().replan_fast
    new_budget = ResourceBudget(vmem_bytes=16 * 2**20)
    plan = replan(specs, new_budget)
    assert planner_stats().replan_fast == fast + 1
    assert plan.budget == new_budget
    assert abs(sum(s.fraction for s in plan.sites) - 1.0) < 1e-6
    for s in plan.sites:
        assert s.footprint.fits(new_budget.scaled(s.fraction)), s.spec.name
    # an identical replan is a pure cache hit
    evals = planner_stats().selector_evals
    assert replan(specs, new_budget) is plan
    assert planner_stats().selector_evals == evals
    assert evals > evals_cold          # the fast path did *some* work...
    # ...but strictly less than a cold plan of the same graph
    clear_plan_cache()
    e0 = planner_stats().selector_evals
    plan_network(specs, new_budget)
    cold_evals = planner_stats().selector_evals - e0
    assert evals - evals_cold < cold_evals


def test_replan_cold_graph_falls_through_to_plan_network():
    clear_plan_cache()
    specs = _replan_specs()
    fast = planner_stats().replan_fast
    plan = replan(specs, ResourceBudget())
    assert planner_stats().replan_fast == fast     # no fast path taken
    assert plan is plan_network(specs, ResourceBudget())


def test_replan_surfaces_canonical_infeasibility():
    clear_plan_cache()
    specs = _replan_specs()
    plan_network(specs, ResourceBudget())
    with pytest.raises(ValueError, match="no feasible"):
        replan(specs, ResourceBudget(vmem_bytes=4 * 1024))


def test_server_counts_replans_on_grant_moves(rng):
    clear_plan_cache()
    srv = AdaptiveServer(SERVING_DEVICE, policy="demand", max_batch=2,
                         rebalance_threshold=0.05)
    srv.register("a", _frontend(0), (12, 12, 6))
    srv.register("b", _frontend(1), (12, 12, 6))
    x = rng.normal(size=(12, 12, 6)).astype(np.float32)
    # wave 1: balanced -> ~even grants
    srv.submit("a", x)
    srv.submit("b", x)
    srv.step()
    # wave 2: heavy skew to a -> grants move, b re-planned
    for _ in range(8):
        srv.submit("a", x)
    srv.submit("b", x)
    srv.step()
    tel = srv.telemetry()
    assert srv.arbiter.rebalances >= 1
    assert tel["a"]["replans"] + tel["b"]["replans"] >= 1


# --------------------------------------------------------------------------
# Calibration: the server plans, prices demand, and accounts lane time
# under a measurement-derived CalibrationTable (core/calibrate_cost.py)
# --------------------------------------------------------------------------
def test_server_prices_and_accounts_under_calibration(rng):
    from repro.core.calibrate_cost import AffineFit, CalibrationTable
    clear_plan_cache()
    params = _frontend()
    x = rng.normal(size=(12, 12, 6)).astype(np.float32)
    # a table covering EVERY member via the global fallback: each launch
    # predicts a constant 100us -> 9.4e4 cycles, wildly different from
    # the analytical est-cycles, so calibrated accounting is observable
    table = CalibrationTable(
        global_fit=AffineFit(us_per_compute_cycle=0.0, us_per_hbm_byte=0.0,
                             overhead_us=100.0, n_samples=3))
    results = {}
    for cal in (None, table):
        clear_plan_cache()
        srv = AdaptiveServer(ResourceBudget(), policy="static", max_batch=2,
                             calibration=cal)
        srv.register("t", params, (12, 12, 6))
        srv.submit("t", x)
        (c,) = srv.drain()
        results[cal is not None] = (srv, c)
    srv_cal, done = results[True]
    srv_raw, raw = results[False]
    # numerics are calibration-independent — only cost accounting moves
    np.testing.assert_array_equal(np.asarray(done.result),
                                  np.asarray(raw.result))
    assert done.latency != raw.latency
    tel = srv_cal.telemetry()["t"]
    assert tel["calibration_key"] == table.key()
    assert srv_raw.telemetry()["t"]["calibration_key"] is None
    # unit cost (the arbiter's demand weight) is the calibrated price
    tenant = srv_cal.tenants["t"]
    specs = srv_cal._specs(params, (1, 12, 12, 6), "float32", (2, 2),
                           "relu", ())
    want = plan_network(specs, srv_cal.budget,
                        calibration=table).calibrated_cycles(table)
    assert tenant.unit_cost == pytest.approx(want)
    # the arbiter knows which cost model its grants are denominated in
    assert srv_cal.arbiter.calibration is table
