"""Measurement-calibrated cost model (core/calibrate_cost.py): fit
recovery, fallback rules, persistence, monotonicity, and the planner
integration that re-ranks members and fusion groups by measured cost.

The fits here are synthetic (constructed samples with known ground
truth) so every property is deterministic; the wall-clock end of the
loop is exercised by ``benchmarks/run.py::table_calibration``.
"""
import json

import numpy as np
import pytest

from repro.core.calibrate_cost import (CALIBRATION_SCHEMA_VERSION, AffineFit,
                                       CalibrationTable, _affine_fit,
                                       calibration_key, collect_plan_samples,
                                       member_key, timeit_us)
from repro.core.plan import clear_plan_cache, network_min_fraction, plan_network
from repro.core.resources import CLOCK_HZ, Footprint, ResourceBudget, hbm_cycles
from repro.models.blocks import cnn_block_site_specs


def _fp(compute=1000.0, hbm=4096, vmem=1024):
    """A footprint whose analytical axes are exactly (compute, hbm)."""
    return Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=0,
                     vpu_ops=100, est_cycles=compute + hbm_cycles(hbm))


def _plane_samples(a, b, c, points):
    """(compute, hbm, comm, us) rows lying exactly on a known affine
    plane with no collective traffic (comm column all zero)."""
    return [(comp, hbm, 0.0, a * comp + b * hbm + c) for comp, hbm in points]


def _block_specs(site="cal"):
    specs, _ = cnn_block_site_specs((2, 16, 16, 4), (3, 3, 4, 16),
                                    x_dtype="float32", site=site)
    return tuple(specs)


def _const_fit(us):
    """A fit predicting a constant wall-clock regardless of footprint."""
    return AffineFit(us_per_compute_cycle=0.0, us_per_hbm_byte=0.0,
                     overhead_us=float(us), n_samples=3)


# --------------------------------------------------------------------------
# Fit recovery: known scale factors reconstructed from synthetic samples
# --------------------------------------------------------------------------
def test_affine_fit_recovers_known_plane():
    a, b, c = 2.5e-3, 4.0e-7, 12.0
    rows = _plane_samples(a, b, c, [(100, 0), (500, 1 << 16),
                                    (2000, 1 << 20), (4000, 1 << 14)])
    fit = _affine_fit(rows)
    assert fit.us_per_compute_cycle == pytest.approx(a, rel=1e-6)
    assert fit.us_per_hbm_byte == pytest.approx(b, rel=1e-6)
    assert fit.overhead_us == pytest.approx(c, rel=1e-6)
    assert fit.n_samples == 4


def test_affine_fit_clamps_coefficients_nonnegative():
    # us DECREASES in hbm_bytes here; the unconstrained solve would go
    # negative on that axis — the active-set clamp must zero it instead.
    rows = [(100.0, 1 << 20, 0.0, 50.0), (200.0, 1 << 16, 0.0, 80.0),
            (400.0, 1 << 10, 0.0, 140.0), (800.0, 1 << 4, 0.0, 260.0)]
    fit = _affine_fit(rows)
    assert fit.us_per_compute_cycle >= 0.0
    assert fit.us_per_hbm_byte >= 0.0
    assert fit.overhead_us >= 0.0


def test_fit_recovery_through_table_records():
    a, b, c = 1.5e-3, 2.0e-7, 5.0
    table = CalibrationTable()
    for comp, hbm in [(100, 1 << 12), (1000, 1 << 16), (5000, 1 << 18)]:
        fp = _fp(compute=comp, hbm=hbm)
        table.record("conv2d.ip1_vpu", fp, a * comp + b * hbm + c)
    table.fit()
    fp = _fp(compute=3000, hbm=1 << 15)
    want = a * 3000 + b * (1 << 15) + c
    assert table.predict_us("conv2d.ip1_vpu", fp.compute_cycles,
                            fp.hbm_bytes) == pytest.approx(want, rel=1e-6)


# --------------------------------------------------------------------------
# <min_samples fallback
# --------------------------------------------------------------------------
def test_sparse_member_gets_no_dedicated_fit():
    table = CalibrationTable()
    table.record("conv2d.ip1_vpu", _fp(100), 10.0)
    table.record("conv2d.ip1_vpu", _fp(200), 20.0)   # only 2 samples
    table.record("pool2d.pool_vpu", _fp(100), 1.0)
    table.record("pool2d.pool_vpu", _fp(200), 2.0)
    table.record("pool2d.pool_vpu", _fp(300), 3.0)   # 3 samples
    table.fit()
    assert "conv2d.ip1_vpu" not in table.fits
    assert "pool2d.pool_vpu" in table.fits
    # the sparse member predicts through the GLOBAL fit over all samples
    assert table.fit_for("conv2d.ip1_vpu") is table.global_fit
    assert table.global_fit is not None
    assert table.global_fit.n_samples == 5


def test_min_samples_is_tunable():
    table = CalibrationTable()
    table.record("m.a", _fp(100), 10.0)
    table.record("m.a", _fp(200), 20.0)
    assert "m.a" not in table.fit().fits
    assert "m.a" in table.fit(min_samples=2).fits


def test_unseen_member_falls_back_to_global_then_identity():
    table = CalibrationTable()
    fp = _fp(1000)
    # never fit at all: identity calibration
    assert table.calibrated_cycles(fp, "conv2d.never_seen") == fp.est_cycles
    table.record("m.a", _fp(100), 7.0)
    table.fit()
    # fit on any sample: unseen members price through the global fit
    us = table.predict_us("conv2d.never_seen", fp.compute_cycles,
                          fp.hbm_bytes)
    assert us is not None
    assert table.calibrated_cycles(fp, "conv2d.never_seen") \
        == pytest.approx(us * 1e-6 * CLOCK_HZ)


def test_empty_table_is_identity_everywhere():
    table = CalibrationTable()
    for fp in (_fp(10), _fp(1e6, hbm=1 << 24)):
        assert table.calibrated_cycles(fp, "anything") == fp.est_cycles
    assert table.fit_for("anything") is None


# --------------------------------------------------------------------------
# Monotonicity + nonnegativity (the properties the clamp buys)
# --------------------------------------------------------------------------
def test_calibrated_cost_nondecreasing_in_compute_and_hbm():
    table = CalibrationTable()
    for comp, hbm, us in [(100, 1 << 10, 5.0), (1000, 1 << 14, 30.0),
                          (4000, 1 << 18, 150.0)]:
        table.record("m.a", _fp(comp, hbm=hbm), us)
    table.fit()
    base = table.calibrated_cycles(_fp(500, hbm=1 << 12), "m.a")
    assert table.calibrated_cycles(_fp(900, hbm=1 << 12), "m.a") >= base
    assert table.calibrated_cycles(_fp(500, hbm=1 << 16), "m.a") >= base
    assert base >= 0.0


def test_predictions_clamped_nonnegative():
    table = CalibrationTable(fits={"m.a": _const_fit(0.0)})
    assert table.predict_us("m.a", 0.0, 0.0) == 0.0
    assert table.calibrated_cycles(_fp(1), "m.a") == 0.0


# --------------------------------------------------------------------------
# member_key: lowered rungs are distinct members
# --------------------------------------------------------------------------
def test_member_key_suffixes_only_lowered_widths():
    assert member_key("conv2d.ip1_vpu") == "conv2d.ip1_vpu"
    assert member_key("conv2d.ip1_vpu", 32, 32) == "conv2d.ip1_vpu"
    assert member_key("conv2d.ip1_vpu", 8, 32) == "conv2d.ip1_vpu@int8"
    assert member_key("conv2d.ip1_vpu", 16, 32) == "conv2d.ip1_vpu@int16"


def test_record_keys_lowered_variant_separately():
    table = CalibrationTable()
    table.record("conv2d.ip1_vpu", _fp(100), 10.0, bits=8, native_bits=32)
    table.record("conv2d.ip1_vpu", _fp(100), 10.0, bits=32, native_bits=32)
    assert table.sample_count("conv2d.ip1_vpu@int8") == 1
    assert table.sample_count("conv2d.ip1_vpu") == 1


# --------------------------------------------------------------------------
# Persistence: versioned JSON, bit-exact round trip
# --------------------------------------------------------------------------
def _fitted_table():
    table = CalibrationTable()
    rng = np.random.default_rng(7)
    for m in ("conv2d.ip1_vpu", "pool2d.pool_vpu", "cnn_fused.fused_vpu@int8"):
        for _ in range(4):
            comp = float(rng.uniform(50, 5000))
            hbm = int(rng.integers(1 << 10, 1 << 20))
            table.record(m, _fp(comp, hbm=hbm),
                         float(0.001 * comp + 2e-7 * hbm + rng.uniform(1, 3)))
    return table.fit()


def test_json_round_trip_bit_exact():
    table = _fitted_table()
    text = table.to_json()
    assert CalibrationTable.from_json(text).to_json() == text


def test_save_load_round_trip_equality_and_identity(tmp_path):
    table = _fitted_table()
    path = tmp_path / "cal.json"
    table.save(path)
    loaded = CalibrationTable.load(path)
    assert loaded == table
    assert loaded.key() == table.key()
    fp = _fp(777, hbm=1 << 13)
    for m in ("conv2d.ip1_vpu", "cnn_fused.fused_vpu@int8", "unseen.m"):
        assert loaded.calibrated_cycles(fp, m) \
            == table.calibrated_cycles(fp, m)


def test_unknown_schema_version_rejected():
    d = json.loads(_fitted_table().to_json())
    d["version"] = CALIBRATION_SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema version"):
        CalibrationTable.from_json(json.dumps(d))
    d["version"] = None
    with pytest.raises(ValueError, match="schema version"):
        CalibrationTable.from_json(json.dumps(d))


# --------------------------------------------------------------------------
# Identity: the cache-keying rule (fits move the key, samples do not)
# --------------------------------------------------------------------------
def test_recording_does_not_move_fingerprint_but_fit_does():
    table = _fitted_table()
    key0 = table.key()
    table.record("conv2d.ip1_vpu", _fp(123), 99.0)
    assert table.key() == key0          # predictions unchanged
    table.fit()
    assert table.key() != key0          # refit -> new identity


def test_tables_with_identical_fits_share_identity():
    t1, t2 = _fitted_table(), _fitted_table()
    assert t1.key() == t2.key()
    assert calibration_key(t1) == calibration_key(t2)
    assert calibration_key(None) is None
    assert t1.key()[0] == CALIBRATION_SCHEMA_VERSION


# --------------------------------------------------------------------------
# Timing substrate
# --------------------------------------------------------------------------
def test_timeit_us_calls_warmup_plus_repeat_and_is_positive():
    calls = []
    us = timeit_us(lambda: calls.append(1), warmup=2, repeat=5)
    assert len(calls) == 7
    assert us >= 0.0


# --------------------------------------------------------------------------
# Planner integration: calibration re-ranks, feasibility stays put
# --------------------------------------------------------------------------
def test_calibration_flips_fusion_choice():
    specs = _block_specs("flip")
    budget = ResourceBudget()
    clear_plan_cache()
    analytical = plan_network(specs, budget, fuse=True)
    assert [s.spec.family for s in analytical.sites] == ["cnn_fused"]
    # Measured verdict says the fused member is expensive: the SAME call
    # must now plan the three-launch chain.
    slow_fused = CalibrationTable(fits={"cnn_fused.fused_vpu": _const_fit(1e6)})
    unfused = plan_network(specs, budget, fuse=True, calibration=slow_fused)
    assert all(s.spec.family != "cnn_fused" for s in unfused.sites)
    assert len(unfused.sites) == 3
    # ...and a verdict agreeing with the analytical model keeps fusion
    # (1e-3 us ~ 1 cycle, far below the chain's uncalibrated est-cycles).
    fast_fused = CalibrationTable(
        fits={"cnn_fused.fused_vpu": _const_fit(1e-3)})
    fused = plan_network(specs, budget, fuse=True, calibration=fast_fused)
    assert [s.spec.family for s in fused.sites] == ["cnn_fused"]


def test_calibration_flips_member_ranking():
    # fuse=False: this test exercises PER-MEMBER ranking inside the
    # conv2d family, which the fused group would otherwise collapse away.
    specs = _block_specs("rank")
    budget = ResourceBudget()
    clear_plan_cache()
    base = plan_network(specs, budget, fuse=False)
    conv_winner = next(s.ip.name for s in base.sites
                       if s.spec.family == "conv2d")
    # Price the analytical winner as measured-terrible; the planner must
    # choose a different conv member for the same site.
    table = CalibrationTable(fits={conv_winner: _const_fit(1e6)})
    recal = plan_network(specs, budget, fuse=False, calibration=table)
    new_winner = next(s.ip.name for s in recal.sites
                      if s.spec.family == "conv2d")
    assert new_winner != conv_winner


def test_calibration_does_not_change_feasibility():
    specs = _block_specs("feas")
    table = CalibrationTable(fits={"cnn_fused.fused_vpu": _const_fit(1e6),
                                   "conv2d.ip1_vpu": _const_fit(1e6)})
    # the minimal feasible fraction is a fits() property — no calibration
    # parameter exists on it, and the planned sites still fit their slices
    budget = ResourceBudget()
    assert network_min_fraction(specs, budget) == pytest.approx(
        network_min_fraction(specs, budget))
    plan = plan_network(specs, budget, calibration=table)
    for s in plan.sites:
        assert s.footprint.fits(budget.scaled(s.fraction))
    # an infeasible deployment stays infeasible under any table
    tiny = ResourceBudget(vmem_bytes=1024)
    with pytest.raises(ValueError, match="no feasible IP"):
        plan_network(specs, tiny)
    with pytest.raises(ValueError, match="no feasible IP"):
        plan_network(specs, tiny, calibration=table)


def test_plan_calibrated_cycles_sums_per_site_predictions():
    specs = _block_specs("sum")
    clear_plan_cache()
    plan = plan_network(specs, ResourceBudget())
    table = _fitted_table()
    want = sum(
        table.calibrated_cycles(
            s.footprint, member_key(s.ip.name, s.precision_bits,
                                    s.spec.native_bits))
        / max(s.footprint.outputs_per_pass, 1)
        for s in plan.sites)
    assert plan.calibrated_cycles(table) == pytest.approx(want)
    assert plan.calibrated_cycles(None) == pytest.approx(plan.total_cycles)


def test_footprint_calibrated_cycles_identity_and_table_paths():
    fp = _fp(2000, hbm=1 << 16)
    assert fp.calibrated_cycles(None, "m.a") == fp.est_cycles
    table = CalibrationTable(fits={"m.a": _const_fit(10.0)})
    assert fp.calibrated_cycles(table, "m.a") \
        == pytest.approx(10.0 * 1e-6 * CLOCK_HZ)
    assert fp.compute_cycles == pytest.approx(2000.0)


# --------------------------------------------------------------------------
# Sample collection against real plans (no wall-clock assertions)
# --------------------------------------------------------------------------
def test_collect_plan_samples_covers_distinct_sites_once():
    specs = _block_specs("coll")
    clear_plan_cache()
    plan = plan_network(specs, ResourceBudget())
    table = collect_plan_samples([plan, plan, None], warmup=0, repeat=1)
    assert table.sample_count() == len(plan.sites)
    members = {s.member for s in table.samples}
    assert members == {member_key(s.ip.name, s.precision_bits,
                                  s.spec.native_bits) for s in plan.sites}
    # the recorded axes are exactly the footprints' analytical split
    by_member = {s.member: s for s in table.samples}
    for s in plan.sites:
        rec = by_member[member_key(s.ip.name, s.precision_bits,
                                   s.spec.native_bits)]
        assert rec.compute_cycles == pytest.approx(s.footprint.compute_cycles)
        assert rec.hbm_bytes == s.footprint.hbm_bytes
        assert rec.measured_us > 0.0
