"""core/autotune.py: candidate generation, feasibility, ranking, and
the bridge from tuned tilings into executed plans."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import (_aligned, autotune_conv, autotune_flash,
                                 autotune_matmul, plan_tile_overrides, sweep)
from repro.core.ip import SiteSpec
from repro.core.plan import plan_network
from repro.core.resources import MXU_DIM, ResourceBudget


# --------------------------------------------------------------------------
# Aligned-candidate generation
# --------------------------------------------------------------------------
def test_aligned_doubles_within_range():
    assert _aligned(128, 1024, 128) == [128, 256, 512, 1024]
    assert _aligned(128, 1000, 128) == [128, 256, 512]
    # lo excludes the small candidates
    assert _aligned(256, 1024, 128) == [256, 512, 1024]


def test_aligned_falls_back_to_alignment_when_range_is_empty():
    # nothing in [256, 200] — the alignment itself is the fallback
    assert _aligned(256, 200, 128) == [128]
    assert _aligned(1, 64, 128) == [128]


def test_aligned_candidates_are_multiples_of_alignment():
    for lo, hi in [(128, 4096), (8, 512), (128, 100)]:
        for v in _aligned(lo, hi, MXU_DIM):
            assert v % MXU_DIM == 0


# --------------------------------------------------------------------------
# Sweep: feasibility gate + est_cycles ranking
# --------------------------------------------------------------------------
def test_sweep_ranks_feasible_tilings_by_est_cycles():
    from repro.kernels.matmul.mxu import footprint_mxu
    budget = ResourceBudget()
    grid = {"bm": [128, 256], "bn": [128, 256], "bk": [128, 256]}
    res = sweep(footprint_mxu, grid, budget, 512, 512, 512, top=8,
                itemsize=2)
    assert res
    cycles = [r.est_cycles for r in res]
    assert cycles == sorted(cycles)
    for r in res:
        assert r.footprint.fits(budget)
        assert r.est_cycles == r.footprint.est_cycles


def test_sweep_excludes_tilings_that_do_not_fit():
    from repro.kernels.matmul.mxu import footprint_mxu
    tight = ResourceBudget(vmem_bytes=200 * 1024)
    grid = {"bm": [128, 1024], "bn": [128, 1024], "bk": [128, 1024]}
    res = sweep(footprint_mxu, grid, tight, 1024, 1024, 1024, top=100,
                itemsize=2)
    assert res
    for r in res:
        assert r.footprint.fits(tight)
        # the 1024^3 tile (6 MiB of operands) must have been dropped
        assert not (r.params["bm"] == r.params["bn"]
                    == r.params["bk"] == 1024)


# --------------------------------------------------------------------------
# Family entry points
# --------------------------------------------------------------------------
def test_autotune_matmul_respects_tight_vmem():
    ample = autotune_matmul(1024, 1024, 1024, itemsize=2)
    tight_budget = ResourceBudget(vmem_bytes=200 * 1024)
    tight = autotune_matmul(1024, 1024, 1024, itemsize=2,
                            budget=tight_budget)
    assert tight.footprint.fits(tight_budget)
    assert tight.footprint.vmem_bytes <= 200 * 1024
    # the unconstrained pick is at least as fast (it saw a superset of
    # feasible tilings)
    assert ample.est_cycles <= tight.est_cycles


def test_autotune_matmul_infeasible_raises():
    with pytest.raises(ValueError, match="no feasible matmul tiling"):
        autotune_matmul(1024, 1024, 1024, itemsize=2,
                        budget=ResourceBudget(vmem_bytes=1024))


def test_autotune_conv_fits_and_aligns():
    budget = ResourceBudget()
    res = autotune_conv(2, 16, 16, 8, 3, 3, 256, itemsize=4, budget=budget)
    assert res.params["block_cout"] % 128 == 0
    assert res.footprint.fits(budget)


def test_autotune_flash_fits_budget():
    budget = ResourceBudget()
    res = autotune_flash(1, 4, 2, 512, 512, 64, itemsize=2, budget=budget)
    assert set(res.params) == {"bq", "bk"}
    assert res.footprint.fits(budget)


# --------------------------------------------------------------------------
# plan_tile_overrides: tuner -> executed plans
# --------------------------------------------------------------------------
def test_plan_tile_overrides_covers_tunable_sites_only():
    specs = [
        SiteSpec.make("net.conv", "conv2d",
                      ((2, 16, 16, 8), (3, 3, 8, 256)), "float32",
                      dual=False),
        SiteSpec.make("net.mm", "matmul", ((512, 512), (512, 512)),
                      "bfloat16", dual=False),
        SiteSpec.make("net.pool", "pool2d", ((2, 14, 14, 256),), "float32",
                      window=(2, 2), mode="max"),
    ]
    plan = plan_network(specs, ResourceBudget())
    overrides = plan_tile_overrides(plan)
    # pool2d has no sweepable tiling; the others only when their MXU
    # member won the race
    assert "net.pool" not in overrides
    for name, params in overrides.items():
        site = plan.site(name)
        assert site.ip.name.split(".")[-1] in ("ip2_mxu", "mm_mxu")
        assert params  # a concrete tiling was chosen
        if site.spec.family == "matmul":
            assert set(params) <= {"bm", "bn", "bk"}
        else:
            assert set(params) == {"block_cout"}
    if "net.mm" in overrides:
        # tuned execution must match the untuned kernel numerically
        from repro.kernels.matmul.ops import matmul
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(512, 512)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(512, 512)).astype(np.float32))
        want = matmul(a, b, ip="mm_mxu")
        got = matmul(a, b, ip="mm_mxu", **overrides["net.mm"])
        # a different bk reorders the f32 accumulation; equality is
        # up to summation roundoff
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)


def test_plan_tile_overrides_skips_lowered_sites():
    spec = SiteSpec.make("low.mm", "matmul", ((512, 512), (512, 512)),
                         "float32", ladder=(8,), dual=False)
    # a vmem envelope only the int8 rung fits forces the lowering
    plan = None
    for kib in (96, 128, 192, 256, 384):
        try:
            cand = plan_network([spec],
                                ResourceBudget(vmem_bytes=kib * 1024))
        except ValueError:
            continue
        if cand.lowered_sites():
            plan = cand
            break
    if plan is None:
        pytest.skip("no vmem rung lowered the matmul on this cost model")
    assert plan.site("low.mm").lowered
    assert "low.mm" not in plan_tile_overrides(plan)


def test_cnn_block_executes_with_tile_overrides(rng):
    """tile_overrides thread through apply_cnn_block to the conv kernel
    without changing the result."""
    from repro.models.blocks import apply_cnn_block, init_cnn_block
    block = init_cnn_block(jax.random.PRNGKey(0), cin=8, cout=16, k=3)
    x = jnp.asarray(rng.normal(size=(2, 12, 12, 8)).astype(np.float32))
    # a VPU-starved budget denies ip1_vpu the conv, so the tunable
    # ip2_mxu member wins and block_cout applies
    # fuse=False: the override targets the standalone conv site, which
    # the fused default would collapse into cnn_block.fused
    budget = ResourceBudget(vpu_ops_budget=200_000)
    probe = {}
    base = apply_cnn_block(block, x, activation="relu", plan=probe,
                           budget=budget, fuse=False)
    assert probe["cnn_block.conv"][0].name.endswith("ip2_mxu")
    y = apply_cnn_block(block, x, activation="relu", budget=budget,
                        fuse=False,
                        tile_overrides={"cnn_block.conv":
                                        {"block_cout": 128}})
    np.testing.assert_allclose(np.asarray(y), np.asarray(base), rtol=1e-6)
