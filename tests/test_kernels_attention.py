"""attention IP family: flash + flash-decode vs naive oracle across
GQA group sizes, seq lengths (incl. non-divisible), causal/full."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.decode import flash_decode
from repro.kernels.attention.flash import flash_attention
from repro.kernels.attention.ref import attention_ref, decode_attention_ref

CASES = [  # (B, Hq, Hkv, Sq, Skv, D)
    (1, 4, 4, 32, 32, 16),
    (2, 8, 2, 64, 64, 32),
    (1, 8, 1, 60, 60, 16),        # non-divisible by block
    (2, 4, 4, 48, 96, 32),        # cross: Skv > Sq (cached prefill)
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_vs_ref(rng, case, causal):
    b, hq, hkv, sq, skv, d = case
    q = jnp.asarray(rng.normal(size=(b, hq, sq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, hkv, skv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, hkv, skv, d)).astype(np.float32))
    out = flash_attention(q, k, v, causal=causal, bq=16, bk=16)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("skv", [17, 64, 100, 257])
@pytest.mark.parametrize("group", [1, 4])
def test_flash_decode_vs_ref(rng, skv, group):
    b, hkv, d = 2, 2, 32
    hq = hkv * group
    q = jnp.asarray(rng.normal(size=(b, hq, 1, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, hkv, skv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, hkv, skv, d)).astype(np.float32))
    out = flash_decode(q, k, v, bk=16)
    ref = decode_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_flash_bf16(rng):
    b, hq, hkv, s, d = 1, 4, 2, 64, 32
    q = jnp.asarray(rng.normal(size=(b, hq, s, d))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d))).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, bq=16, bk=16)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(ref, dtype=np.float32),
                               rtol=5e-2, atol=5e-2)
