"""Per-arch smoke tests (deliverable f): reduced same-family config,
one train step + prefill + decode on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.configs.base import ShapeConfig
from repro.models import api
from repro.models.frontends import make_inputs
from repro.optim.adamw import AdamWConfig

TRAIN_SHAPE = ShapeConfig("smoke_train", 32, 2, "train")
PREFILL_SHAPE = ShapeConfig("smoke_prefill", 16, 2, "prefill")
OPT = AdamWConfig(warmup_steps=2, total_steps=10)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step(arch):
    cfg = get_config(arch, smoke=True)
    batch = make_inputs(cfg, TRAIN_SHAPE, abstract=False)
    state = api.init_train_state(cfg, OPT, jax.random.PRNGKey(0))
    new_state, metrics = jax.jit(
        lambda s, b: api.train_step(cfg, OPT, s, b))(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch, loss)
    assert loss > 0
    # params actually moved
    delta = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state.params, new_state.params)
    assert max(jax.tree.leaves(delta)) > 0
    # no NaNs anywhere in the updated tree
    for leaf in jax.tree.leaves(new_state.params):
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_inputs(cfg, PREFILL_SHAPE, abstract=False)
    logits, caches, pos = jax.jit(
        lambda p, b: api.prefill_step(cfg, p, b, pad_to=24))(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all(), arch
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    if cfg.embed_inputs and cfg.family != "encdec":
        tok = jax.random.normal(jax.random.PRNGKey(1),
                                (2, 1, cfg.d_model), jnp.float32)
    l2, caches2 = jax.jit(
        lambda p, c, t, i: api.decode_step(cfg, p, c, t, i))(
            params, caches, tok, jnp.int32(pos))
    assert l2.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(l2, dtype=np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_from_zero_cache(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    caches = api.init_decode_caches(cfg, 2, 16)
    tok = jnp.ones((2, 1), jnp.int32)
    if cfg.embed_inputs and cfg.family != "encdec":
        tok = jnp.ones((2, 1, cfg.d_model), jnp.float32)
    logits, _ = jax.jit(
        lambda p, c, t: api.decode_step(cfg, p, c, t, 0))(params, caches, tok)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all(), arch


def test_param_counts_full_configs():
    """The exact configs land in the right parameter-count ballpark."""
    expected = {
        "olmo-1b": (0.9e9, 1.7e9),
        "starcoder2-15b": (13e9, 18e9),
        "chatglm3-6b": (5e9, 8e9),
        "llama3.2-1b": (1.0e9, 1.8e9),
        "dbrx-132b": (110e9, 150e9),
        "grok-1-314b": (250e9, 360e9),
        "seamless-m4t-large-v2": (1.2e9, 3.0e9),
        "jamba-1.5-large-398b": (330e9, 460e9),
        "llava-next-34b": (28e9, 42e9),
        "rwkv6-3b": (2e9, 4e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:,} not in [{lo:,}, {hi:,}]"


def test_scan_vs_unrolled_equivalence():
    """scan_layers=False (calibration path) computes the same function."""
    import dataclasses
    cfg = get_config("jamba-1.5-large-398b", smoke=True)
    batch = make_inputs(cfg, TRAIN_SHAPE, abstract=False)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    loss1, _ = api.loss_fn(cfg, params, batch)
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    loss2, _ = api.loss_fn(cfg2, params, batch)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-5)
