"""The sharding contract end to end (docs/adaptive_ips.md, "Sharding
contract"): planner decisions (split wins / refusal / rescue), plan
serialization and cache identity across meshes, arbiter whole-device
grants, and sharded execution matching the replicated walk.

Planning is pure — no devices needed — so those tests run in-process.
Execution tests spawn a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` (JAX fixes its
device count at import; the flag must never leak into other tests).
The measured-wall-clock half of the contract lives in
``benchmarks/run.py::table_mesh``.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.core import plan as plan_mod
from repro.core.ip import SiteSpec
from repro.core.plan import (NetworkPlan, clear_plan_cache, plan_network,
                             replan)
from repro.core.resources import MeshSpec, ResourceBudget
from repro.core.shard import force_shard_decisions
from repro.runtime.arbiter import BudgetArbiter

REPO = Path(__file__).resolve().parent.parent
MESH2 = MeshSpec(devices=2)
# The MXU ration that forces the slow VPU member at 1 device — the
# same win workload benchmarks/run.py::table_mesh measures.
WIN_BUDGET = ResourceBudget(mxu_passes_budget=7)


def _conv(name="conv", x=(8, 16, 16, 32), w=(3, 3, 32, 128)):
    return SiteSpec.make(name, "conv2d", (x, w), "float32", dual=False)


def run_sub(body: str, n_dev: int = 2, timeout: int = 420) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_dev}")
        import dataclasses
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=str(REPO / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# --------------------------------------------------------------------------
# Planner decisions
# --------------------------------------------------------------------------
def test_split_wins_flips_member_and_cuts_cycles():
    clear_plan_cache()
    spec = _conv()
    p1 = plan_network((spec,), WIN_BUDGET)
    p2 = plan_network((spec,), WIN_BUDGET, mesh=MESH2)
    s1, s2 = p1.sites[0], p2.sites[0]
    assert not s1.sharded
    assert s2.sharded and (s2.shard_axis, s2.shard_degree) == ("batch", 2)
    # the collective bill is in the plan's own cost, not a side channel
    assert s2.footprint.comm_cycles > 0.0
    assert p2.total_cycles < p1.total_cycles
    # halving the per-device batch buys the rationed MXU member back
    assert s1.ip.name.endswith("ip1_vpu")
    assert s2.ip.name.endswith("ip2_mxu")


def test_refusal_when_collectives_dominate():
    # 1x1 conv, tiny compute, 8 MiB output: a chan split would all-
    # reduce the full output at ~11x the site's compute — degree stays 1
    spec = _conv(x=(4, 64, 64, 4), w=(1, 1, 4, 128))
    pr = plan_network((spec,), ResourceBudget(), mesh=MESH2)
    s = pr.sites[0]
    assert not s.sharded and s.shard_degree == 1
    assert s.footprint.comm_cycles == 0.0
    forced = force_shard_decisions((spec,), MESH2, axis="chan")
    assert sum(f.comm_cycles for f in forced) > pr.total_cycles


def test_sharding_rescues_single_device_infeasibility():
    # 256 KiB vmem: no 1-device member fits, but the chan split's
    # halved working set does — the mesh widens feasibility
    spec = _conv()
    tight = ResourceBudget(vmem_bytes=256 * 1024)
    with pytest.raises(ValueError, match="no feasible IP"):
        plan_network((spec,), tight)
    rescued = plan_network((spec,), tight, mesh=MESH2)
    s = rescued.sites[0]
    assert s.sharded and s.shard_degree == 2


def test_single_device_mesh_is_the_trivial_plan():
    spec = _conv("one")
    p = plan_network((spec,), WIN_BUDGET, mesh=MeshSpec(devices=1))
    assert not p.sites[0].sharded
    assert p.sites[0].footprint.comm_cycles == 0.0


# --------------------------------------------------------------------------
# Serialization + cache identity
# --------------------------------------------------------------------------
def test_plan_json_round_trips_sharding_fields():
    p2 = plan_network((_conv("json"),), WIN_BUDGET, mesh=MESH2)
    restored = NetworkPlan.from_json(p2.to_json())
    assert restored == p2
    assert restored.mesh == MESH2
    s = restored.sites[0]
    assert (s.shard_axis, s.shard_degree) == ("batch", 2)
    assert s.footprint.comm_cycles == p2.sites[0].footprint.comm_cycles
    # bit-exact: serialize(deserialize(x)) == x
    assert restored.to_json() == p2.to_json()


def test_plan_cache_keys_on_mesh():
    clear_plan_cache()
    specs = (_conv("cachemesh"),)
    p0 = plan_network(specs, WIN_BUDGET)
    p2 = plan_network(specs, WIN_BUDGET, mesh=MESH2)
    assert p0 is not p2
    keys = [k for k in plan_mod._PLAN_CACHE if k[0] == specs]
    # key layout: (specs, budget, fuse, mesh, calibration_key)
    assert {k[3] for k in keys} == {None, MESH2}
    # exact repeats are O(1) hits returning the same object...
    assert plan_network(specs, WIN_BUDGET, mesh=MESH2) is p2
    # ...and mesh replans route through the same memoized path
    assert replan(specs, WIN_BUDGET, mesh=MESH2) is p2


def test_device_plan_halves_the_sharded_dim():
    p2 = plan_network((_conv("dev"),), WIN_BUDGET, mesh=MESH2)
    dp = p2.device_plan()
    gx = p2.sites[0].spec.shapes[0]
    dx = dp.sites[0].spec.shapes[0]
    assert dx[0] == gx[0] // 2 and dx[1:] == gx[1:]
    # the global plan keeps global shapes — device_plan is a view
    assert p2.sites[0].spec.shapes[0] == gx


# --------------------------------------------------------------------------
# Arbiter whole-device grants
# --------------------------------------------------------------------------
def test_arbiter_grants_partition_the_mesh():
    arb = BudgetArbiter(ResourceBudget(), mesh=MeshSpec(devices=4))
    for name in ("a", "b", "c"):
        arb.register(name)
    arb.observe("a", 6000.0)
    arb.observe("b", 1000.0)
    arb.observe("c", 1000.0)
    shares = arb.split()
    devs = {n: s.devices for n, s in shares.items()}
    # every tenant holds >= 1 whole device and the grants tile the mesh
    assert sum(devs.values()) == 4
    assert all(v >= 1 for v in devs.values())
    assert devs["a"] == 2            # the demand-heavy tenant gets the spare
    # slices are contiguous, ordered by registration, and partition [0, 4)
    slices = [arb.device_slice(n) for n in ("a", "b", "c")]
    assert slices[0][0] == 0 and slices[-1][1] == 4
    for (_, a1), (b0, _) in zip(slices, slices[1:]):
        assert a1 == b0
    for n in devs:
        assert arb.mesh_for(n).devices == devs[n]
        # whole-device grants plan against the FULL per-device budget
        assert arb.budget_for(n) == arb.budget


def test_arbiter_rejects_tenants_beyond_devices():
    arb = BudgetArbiter(ResourceBudget(), mesh=MESH2)
    arb.register("a")
    arb.register("b")
    with pytest.raises(ValueError, match="whole device"):
        arb.register("c")
    # the rejected registration left no ghost state
    assert set(arb.split()) == {"a", "b"}


# --------------------------------------------------------------------------
# Execution (subprocess: 2 forced host devices)
# --------------------------------------------------------------------------
def test_sharded_execution_matches_replicated():
    run_sub("""
        from repro.core.ip import SiteSpec
        from repro.core.plan import plan_network
        from repro.core.resources import MeshSpec, ResourceBudget
        from repro.core.shard import force_shard_decisions
        from repro.distributed.shard_exec import (apply_plan_replicated,
                                                  apply_plan_sharded)
        mesh = MeshSpec(devices=2)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, 16, 16, 32)).astype(np.float32))
        w = jnp.asarray(rng.normal(0, (9 * 32) ** -0.5,
                                   (3, 3, 32, 128)).astype(np.float32))
        spec = SiteSpec.make("conv", "conv2d", (x.shape, w.shape),
                             "float32", dual=False)
        p2 = plan_network((spec,), ResourceBudget(mxu_passes_budget=7),
                          mesh=mesh)
        assert p2.sites[0].shard_axis == "batch"
        y_rep = apply_plan_replicated(p2, x, {"conv": w})
        y_shd = apply_plan_sharded(p2, x, {"conv": w})
        # f32 batch split reorders nothing: bit-identical
        assert (np.asarray(y_rep) == np.asarray(y_shd)).all()

        # chan split: per-device partial sums + all-reduce — equal up to
        # float summation order, for both the psum and the ring path
        force_shard_decisions((spec,), mesh, axis="chan")  # legality
        sites = tuple(dataclasses.replace(s, shard_axis="chan",
                                          shard_degree=2)
                      for s in p2.sites)
        forced = dataclasses.replace(p2, sites=sites, mesh=mesh)
        y_chan = apply_plan_sharded(forced, x, {"conv": w})
        np.testing.assert_allclose(np.asarray(y_chan), np.asarray(y_rep),
                                   rtol=1e-5, atol=1e-5)
        y_ring = apply_plan_sharded(forced, x, {"conv": w}, use_ring=True)
        np.testing.assert_allclose(np.asarray(y_ring), np.asarray(y_chan),
                                   rtol=1e-5, atol=1e-5)
        print("exec OK")
    """)


def test_sharded_fused_chain_matches_replicated():
    run_sub("""
        from repro.core.plan import plan_network
        from repro.core.resources import MeshSpec, ResourceBudget
        from repro.core.shard import force_shard_decisions
        from repro.distributed.shard_exec import (apply_plan_replicated,
                                                  apply_plan_sharded)
        from repro.models.blocks import cnn_block_site_specs
        mesh = MeshSpec(devices=2)
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(4, 16, 16, 8)).astype(np.float32))
        w = jnp.asarray(rng.normal(0, (9 * 8) ** -0.5,
                                   (3, 3, 8, 16)).astype(np.float32))
        specs, _ = cnn_block_site_specs(x.shape, w.shape,
                                        x_dtype="float32", site="blk")
        pf = plan_network(tuple(specs), ResourceBudget())  # fuses by default
        assert [s.spec.family for s in pf.sites] == ["cnn_fused"]
        gspecs = tuple(s.spec for s in pf.sites)
        force_shard_decisions(gspecs, mesh, axis="batch")  # legality
        sites = tuple(dataclasses.replace(s, shard_axis="batch",
                                          shard_degree=2)
                      for s in pf.sites)
        pff = dataclasses.replace(pf, sites=sites, mesh=mesh)
        weights = {"blk.fused": w}
        y_rep = apply_plan_replicated(pf, x, weights)
        y_shd = apply_plan_sharded(pff, x, weights)
        assert (np.asarray(y_rep) == np.asarray(y_shd)).all()
        print("fused OK")
    """)


def test_sharded_execution_refuses_lowered_plans():
    spec = SiteSpec.make("lo", "conv2d", ((2, 8, 8, 4), (3, 3, 4, 8)),
                         "float32", ladder=(16, 8), dual=False)
    plan = plan_network((spec,), ResourceBudget(vmem_bytes=3 * 1024))
    assert plan.sites[0].lowered
    from repro.distributed.shard_exec import apply_plan_sharded
    with pytest.raises(ValueError, match="float-only"):
        apply_plan_sharded(plan, None)
