"""pool2d IP family vs the pure-jnp oracle: shape/stride/dtype sweeps,
footprint monotonicity, and selector behavior under budgets."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.resources import ResourceBudget
from repro.core.selector import select_pool_ip
from repro.kernels.pool2d.mxu_im2col import footprint as fp_im2col
from repro.kernels.pool2d.ops import pool2d
from repro.kernels.pool2d.ref import pool2d_out_shape, pool2d_ref
from repro.kernels.pool2d.vpu_window import footprint as fp_window

CASES = [  # (N, H, W, C, window, stride)
    (1, 8, 8, 1, (2, 2), None),
    (2, 12, 12, 3, (2, 2), None),
    (1, 9, 7, 5, (3, 3), (2, 2)),
    (1, 10, 10, 4, (3, 2), (3, 2)),
    (2, 7, 7, 130, (2, 2), (1, 1)),   # c > one lane tile, overlapping
]

IPS = ["pool_vpu", "pool_im2col"]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("ip", IPS)
@pytest.mark.parametrize("mode", ["max", "avg"])
def test_int8_exact(rng, case, ip, mode):
    n, h, w, c, win, stride = case
    x = jnp.asarray(rng.integers(-128, 128, (n, h, w, c), dtype=np.int8))
    out = pool2d(x, window=win, stride=stride, mode=mode, ip=ip)
    ref = pool2d_ref(x, window=win, stride=stride, mode=mode)
    assert out.dtype == ref.dtype
    assert out.shape == pool2d_out_shape(x.shape, win, stride)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("ip", IPS)
@pytest.mark.parametrize("mode", ["max", "avg"])
def test_float32(rng, ip, mode):
    x = jnp.asarray(rng.normal(size=(2, 10, 10, 4)).astype(np.float32))
    out = pool2d(x, window=(2, 2), mode=mode, ip=ip)
    ref = pool2d_ref(x, window=(2, 2), mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_max_preserves_dtype_avg_promotes(rng):
    x = jnp.asarray(rng.integers(-128, 128, (1, 4, 4, 2), dtype=np.int8))
    assert pool2d(x, mode="max", ip="pool_vpu").dtype == jnp.int8
    assert pool2d(x, mode="avg", ip="pool_vpu").dtype == jnp.int32


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), h=st.integers(4, 10),
       c=st.integers(1, 6), k=st.sampled_from([2, 3]),
       mode=st.sampled_from(["max", "avg"]))
def test_members_agree_property(seed, h, c, k, mode):
    """Both members are exact vs the oracle for ALL int8 inputs."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-128, 128, (1, h, h, c), dtype=np.int8))
    ref = pool2d_ref(x, window=(k, k), mode=mode)
    for ip in IPS:
        out = pool2d(x, window=(k, k), mode=mode, ip=ip)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# --------------------------------------------------------------------------
# Footprints
# --------------------------------------------------------------------------
@pytest.mark.parametrize("fp_fn", [fp_window, fp_im2col])
def test_footprint_monotone_in_shape(fp_fn):
    base = fp_fn(1, 16, 16, 8, 2, 2, 2, 2, itemsize=1, mode="avg")
    for scaled in [fp_fn(2, 16, 16, 8, 2, 2, 2, 2, itemsize=1, mode="avg"),
                   fp_fn(1, 32, 32, 8, 2, 2, 2, 2, itemsize=1, mode="avg"),
                   fp_fn(1, 16, 16, 64, 2, 2, 2, 2, itemsize=1, mode="avg")]:
        assert scaled.hbm_bytes >= base.hbm_bytes
        assert scaled.vpu_ops >= base.vpu_ops
        assert scaled.vmem_bytes >= base.vmem_bytes
        assert scaled.est_cycles >= base.est_cycles


@pytest.mark.parametrize("fp_fn", [fp_window, fp_im2col])
def test_footprint_avg_prices_the_accumulator(fp_fn):
    """avg materializes a 4-byte accumulator copy in VMEM; the footprint
    (the resource contract) must charge for it."""
    mx = fp_fn(1, 16, 16, 8, 2, 2, 2, 2, itemsize=1, mode="max")
    av = fp_fn(1, 16, 16, 8, 2, 2, 2, 2, itemsize=1, mode="avg")
    assert av.vmem_bytes > mx.vmem_bytes


def test_oversized_window_rejected_everywhere(rng):
    x = jnp.asarray(rng.integers(-128, 128, (1, 4, 4, 2), dtype=np.int8))
    with pytest.raises(ValueError, match="exceeds the input plane"):
        pool2d(x, window=(8, 8))
    with pytest.raises(ValueError, match="exceeds the input plane"):
        select_pool_ip(x.shape, window=(8, 8))   # plan-only callers too


def test_footprint_window_needs_less_vmem():
    """The windowed-reduce member never buffers the KH*KW patch tensor."""
    a = fp_window(1, 32, 32, 16, 3, 3, 1, 1, itemsize=4, mode="max")
    b = fp_im2col(1, 32, 32, 16, 3, 3, 1, 1, itemsize=4, mode="max")
    assert a.vmem_bytes < b.vmem_bytes
    assert b.mxu_passes == 0          # max mode never touches the MXU
    assert fp_im2col(1, 32, 32, 16, 3, 3, 1, 1, itemsize=4,
                     mode="avg").mxu_passes > 0


# --------------------------------------------------------------------------
# Selector
# --------------------------------------------------------------------------
XS = (2, 32, 32, 64)


def test_no_mxu_budget_forces_windowed_avg():
    ip = select_pool_ip(XS, mode="avg",
                        budget=ResourceBudget(mxu_available=False))
    assert ip.name == "pool2d.pool_vpu"


def test_vpu_starved_budget_forces_im2col_avg():
    """Budget admits im2col's data movement but not the windowed member's
    per-tap reduce chain (2x the ops)."""
    fp = fp_im2col(*XS, 2, 2, 2, 2, itemsize=1, mode="avg")
    budget = ResourceBudget(vpu_ops_budget=int(fp.vpu_ops * 1.5))
    ip = select_pool_ip(XS, mode="avg", budget=budget)
    assert ip.name == "pool2d.pool_im2col"


def test_infeasible_everywhere_raises_like_conv2d():
    with pytest.raises(ValueError, match="no feasible IP"):
        select_pool_ip(XS, mode="avg",
                       budget=ResourceBudget(mxu_available=False,
                                             vpu_ops_budget=10))


def test_selected_ip_always_fits_budget():
    for budget in [ResourceBudget(), ResourceBudget(mxu_available=False),
                   ResourceBudget(vmem_bytes=1 * 2**20)]:
        for mode in ("max", "avg"):
            ip = select_pool_ip(XS, mode=mode, dtype=jnp.int8, budget=budget)
            fp = ip.footprint(*XS, 2, 2, 2, 2, itemsize=1, mode=mode)
            assert fp.fits(budget), (ip.name, mode, budget)
