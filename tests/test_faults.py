"""Fault injector: schedule validation, the zero-cost disabled path,
deterministic firing (step- and probability-triggered), the seam
protocol (device loss, output poisoning, latency scaling), and
bit-transparency of an armed-but-never-firing injector at the server
level."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.resources import ResourceBudget
from repro.models.frontends import init_cnn_frontend
from repro.obs import EVENTS
from repro.runtime import AdaptiveServer
from repro.runtime.faults import (FAULT_KINDS, INJECTOR, DeviceLost,
                                  FaultInjector, FaultSpec, SEAM_OF)

DEVICE = ResourceBudget(vpu_ops_budget=15_000_000)


def _frontend(key=0):
    return init_cnn_frontend(jax.random.PRNGKey(key), channels=(6, 12),
                             d_model=16)


# --------------------------------------------------------------------------
# Schedule validation
# --------------------------------------------------------------------------
def test_every_kind_has_a_seam():
    assert set(SEAM_OF) == set(FAULT_KINDS)


def test_spec_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("gamma_ray", step=0)


def test_spec_needs_a_trigger():
    with pytest.raises(ValueError, match="step=.*or p="):
        FaultSpec("nan_output")
    with pytest.raises(ValueError, match="p must be in"):
        FaultSpec("nan_output", p=1.5)


def test_arm_rejects_non_spec_entries():
    inj = FaultInjector()
    with pytest.raises(TypeError, match="FaultSpec"):
        inj.arm([{"kind": "nan_output", "step": 0}])


# --------------------------------------------------------------------------
# The disabled path: no state moves, values pass through untouched
# --------------------------------------------------------------------------
def test_disabled_injector_is_inert():
    inj = FaultInjector()
    assert not inj.enabled
    assert inj.poll("execute") == []
    assert inj.counters() == {}          # poll did not even count
    inj.check_devices(0, 8)              # no lost set: no-op
    y = jnp.ones((2, 3))
    assert inj.perturb_output("output", y) is y
    assert inj.scale_latency(123.0) == 123.0
    assert inj.counters() == {}


def test_arming_an_empty_schedule_stays_disabled():
    inj = FaultInjector()
    inj.arm([])
    assert not inj.enabled


def test_disarm_restores_the_transparent_state():
    inj = FaultInjector()
    inj.arm([FaultSpec("nan_output", step=0)])
    inj.poll("output")
    inj.lose(1)
    inj.disarm()
    assert not inj.enabled
    assert inj.counters() == {} and inj.fired == [] and inj.lost == set()


# --------------------------------------------------------------------------
# Firing semantics
# --------------------------------------------------------------------------
def test_step_trigger_fires_on_the_nth_poll_and_retires():
    inj = FaultInjector()
    with inj.armed([FaultSpec("kernel_exception", step=2)]):
        assert inj.poll("execute") == []          # step 0
        assert inj.poll("execute") == []          # step 1
        due = inj.poll("execute")                 # step 2: fires
        assert [f.kind for f in due] == ["kernel_exception"]
        assert inj.poll("execute") == []          # once=True retired it
        assert inj.counters() == {"execute": 4}
        assert inj.fired == [("kernel_exception", "execute", 2, None)]


def test_seams_count_independently():
    inj = FaultInjector()
    with inj.armed([FaultSpec("nan_output", step=1)]):
        inj.poll("execute")                       # advances only "execute"
        assert inj.poll("output") == []           # output is at step 0
        assert [f.kind for f in inj.poll("output")] == ["nan_output"]


def test_tenant_filter():
    inj = FaultInjector()
    with inj.armed([FaultSpec("nan_output", p=1.0, tenant="a", once=False)]):
        assert inj.poll("output", "b") == []      # wrong tenant: no fire
        assert [f.kind for f in inj.poll("output", "a")] == ["nan_output"]


def test_probability_trigger_replays_under_the_seed():
    def trace(seed):
        inj = FaultInjector()
        with inj.armed([FaultSpec("nan_output", p=0.5, once=False)],
                       seed=seed):
            return [bool(inj.poll("output")) for _ in range(32)]

    a, b = trace(7), trace(7)
    assert a == b                        # same seed: identical replay
    assert any(a) and not all(a)         # and the coin actually flips
    assert trace(8) != a                 # different seed: different trace


def test_fault_injected_events_are_logged():
    EVENTS.clear()
    inj = FaultInjector()
    with inj.armed([FaultSpec("latency_spike", step=0, param=3.0)]):
        inj.scale_latency(100.0, "a")
    evs = EVENTS.recent(kind="fault.injected")
    assert len(evs) == 1
    assert evs[0]["fault"] == "latency_spike"
    assert evs[0]["seam"] == "lane" and evs[0]["tenant"] == "a"


# --------------------------------------------------------------------------
# The seam effects
# --------------------------------------------------------------------------
def test_check_devices_raises_only_on_overlap():
    inj = FaultInjector()
    with inj.armed([FaultSpec("device_loss", step=0, param=3)]):
        inj.lose(3)
        inj.check_devices(0, 3)          # slice below the corpse: fine
        with pytest.raises(DeviceLost) as ei:
            inj.check_devices(2, 4)
        assert ei.value.device == 3


def test_perturb_output_nan_vs_inf():
    inj = FaultInjector()
    with inj.armed([FaultSpec("nan_output", step=0),
                    FaultSpec("collective_corrupt", step=0)]):
        y1 = inj.perturb_output("output", jnp.ones((2, 3)))
        y2 = inj.perturb_output("collective", jnp.ones((2, 3)))
    assert np.isnan(np.asarray(y1)[0, 0]) and np.isfinite(y1).sum() == 5
    assert np.isposinf(np.asarray(y2)[0, 0])


def test_scale_latency_param_and_default():
    inj = FaultInjector()
    with inj.armed([FaultSpec("latency_spike", step=0, param=2.5),
                    FaultSpec("latency_spike", step=1)]):
        assert inj.scale_latency(100.0) == pytest.approx(250.0)
        assert inj.scale_latency(100.0) == pytest.approx(400.0)  # default 4x


# --------------------------------------------------------------------------
# Bit-transparency at the server: armed-but-never-firing == disarmed
# --------------------------------------------------------------------------
def _serve_wave(rng_seed=0):
    srv = AdaptiveServer(DEVICE, max_batch=2)
    srv.register("a", _frontend(0), (12, 12, 6))
    rng = np.random.default_rng(rng_seed)
    for _ in range(4):
        srv.submit("a", rng.normal(size=(12, 12, 6)).astype(np.float32))
    comps = srv.drain()
    return srv, sorted(comps, key=lambda c: c.rid)


def test_never_firing_schedule_is_bit_transparent():
    assert not INJECTOR.enabled          # suite hygiene: nobody left it armed
    _, base = _serve_wave()
    with INJECTOR.armed([FaultSpec(k, step=10**9) for k in FAULT_KINDS]):
        srv, armed = _serve_wave()
        polls = INJECTOR.counters()
    assert polls.get("execute", 0) > 0   # the seams really were polled
    assert len(armed) == len(base) == 4
    for b, a in zip(base, armed):
        assert a.ok and a.finished == b.finished
        np.testing.assert_array_equal(np.asarray(a.result),
                                      np.asarray(b.result))
    tel = srv.telemetry()["a"]
    assert tel["guard_rejected"] == 0 and tel["degradations"] == 0
