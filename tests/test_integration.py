"""End-to-end integration: training convergence, failure->restart
resume equivalence, batched serving, analysis utilities."""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parent.parent


def _run(args, timeout=600, check=True):
    env = dict(os.environ,
               PYTHONPATH=str(REPO / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                         text=True, timeout=timeout, env=env)
    if check:
        assert out.returncode == 0, \
            f"rc={out.returncode}\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out


def test_train_loss_decreases(tmp_path):
    out = _run(["repro.launch.train", "--arch", "llama3.2-1b", "--smoke",
                "--steps", "30", "--batch", "8", "--seq", "64",
                "--lr", "1e-2", "--ckpt-dir", str(tmp_path / "ck")])
    lines = [l for l in out.stdout.splitlines() if "loss" in l and "step" in l]
    first = float(lines[0].split("loss")[1].split()[0])
    last = float(lines[-1].split("loss")[1].split()[0])
    assert last < first - 0.5, out.stdout


def test_failure_restart_resumes_exactly(tmp_path):
    """Crash at step 25, relaunch: the resumed run must continue from
    the checkpoint and finish; the data pipeline skips ahead so no batch
    is consumed twice."""
    ck = str(tmp_path / "ck")
    common = ["repro.launch.train", "--arch", "olmo-1b", "--smoke",
              "--steps", "40", "--batch", "4", "--seq", "32",
              "--ckpt-every", "10", "--ckpt-dir", ck]
    out1 = _run(common + ["--simulate-failure", "25"], check=False)
    assert out1.returncode == 17, out1.stdout + out1.stderr
    assert "FAILURE" in out1.stdout
    out2 = _run(common)
    assert "restored step" in out2.stdout
    assert "resuming at 21" in out2.stdout, out2.stdout
    assert "done" in out2.stdout


def test_uninterrupted_equals_restarted(tmp_path):
    """Gold run (no failure) and crash+resume run reach the SAME final
    loss — checkpoint + deterministic data = exact resume."""
    ck_a = str(tmp_path / "a")
    ck_b = str(tmp_path / "b")
    base = ["repro.launch.train", "--arch", "llama3.2-1b", "--smoke",
            "--steps", "24", "--batch", "4", "--seq", "32",
            "--ckpt-every", "8"]
    gold = _run(base + ["--ckpt-dir", ck_a])
    _run(base + ["--ckpt-dir", ck_b, "--simulate-failure", "18"],
         check=False)
    resumed = _run(base + ["--ckpt-dir", ck_b])

    def final_loss(stdout):
        lines = [l for l in stdout.splitlines()
                 if l.startswith("[train] step")]
        return float(lines[-1].split("loss")[1].split()[0])

    # resumed must land within float-accumulation noise of gold
    assert abs(final_loss(gold.stdout) - final_loss(resumed.stdout)) < 2e-2, \
        (gold.stdout, resumed.stdout)


def test_serve_batched_requests():
    out = _run(["repro.launch.serve", "--arch", "llama3.2-1b", "--smoke",
                "--requests", "6", "--slots", "2", "--max-new", "6",
                "--prompt-len", "8", "--max-len", "24"])
    assert "6 requests" in out.stdout
    assert "36 tokens" in out.stdout


# --------------------------------------------------------------------------
# Analysis utilities (pure python — no subprocess needed)
# --------------------------------------------------------------------------
def test_collective_parser():
    from repro.launch.analysis import collective_bytes
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ag = f32[8,64]{1,0} all-gather(f32[2,64]{1,0} %y), replica_groups=[2,4]<=[8], dimensions={0}
  %rs = bf16[16,16]{1,0} reduce-scatter(bf16[64,16]{1,0} %z), replica_groups={{0,1,2,3}}, to_apply=%sum
  %cp = f32[4]{0} collective-permute(f32[4]{0} %w), source_target_pairs={{0,1}}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 8 * 64 * 4 / 4      # result / group 4
    assert out["reduce-scatter"] == 16 * 16 * 2 * 4  # result * group 4
    assert out["collective-permute"] == 16
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_roofline_terms():
    from repro.launch.analysis import Roofline
    r = Roofline(flops=197e12 * 256, hbm_bytes=819e9 * 256,
                 coll_bytes=50e9 * 4 * 256, chips=256,
                 model_flops=197e12 * 256 * 0.5,
                 min_hbm_bytes=819e9 * 256 * 0.25,
                 min_coll_bytes=0)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert abs(r.t_collective - 1.0) < 1e-9
    assert r.dominant in ("compute", "memory", "collective")
    assert 0 < r.roofline_fraction <= 1.0


def test_ideal_traffic_sane():
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.launch.analysis import ideal_traffic, model_flops
    for arch in ("olmo-1b", "dbrx-132b", "rwkv6-3b"):
        cfg = get_config(arch)
        for shape in ("train_4k", "decode_32k"):
            hbm, coll = ideal_traffic(cfg, SHAPES[shape], dp=16, tp=16,
                                      chips=256, fsdp=cfg.fsdp)
            assert hbm > 0 and coll >= 0
            assert model_flops(cfg, SHAPES[shape]) > 0
