"""Property tests for the cost primitives in ``core/resources.py`` —
the substrate the measurement-calibrated cost model regresses over
(``core/calibrate_cost.py`` fits an affine model of
``Footprint.compute_cycles`` and ``hbm_bytes``, so the additive split
and the budget algebra below are load-bearing).

Runs under real ``hypothesis`` when installed, else the deterministic
fallback shim (``tests/_hypothesis_fallback.py`` via ``conftest.py``).
"""
import dataclasses

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.resources import (Footprint, ResourceBudget, cost_cycles,
                                  hbm_cycles)

_COMPUTE = st.floats(min_value=0.0, max_value=1e9)
_BYTES = st.integers(min_value=0, max_value=1 << 30)
_FRACTION = st.floats(min_value=0.01, max_value=1.0)


def _fp(compute, hbm, *, vmem=4096, mxu=0, vpu=100, bits=32):
    return Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=mxu,
                     vpu_ops=vpu, est_cycles=cost_cycles(compute, hbm),
                     max_operand_bits=bits)


# --------------------------------------------------------------------------
# cost_cycles: the additive compute+DMA rule
# --------------------------------------------------------------------------
@settings(max_examples=50)
@given(c1=_COMPUTE, c2=_COMPUTE, b1=_BYTES, b2=_BYTES)
def test_cost_cycles_additive_in_both_axes(c1, c2, b1, b2):
    # splitting a launch's compute and traffic across two launches costs
    # exactly the same total — no cross-term, no overlap discount
    assert cost_cycles(c1 + c2, 0) + cost_cycles(0, b1 + b2) \
        == pytest.approx(cost_cycles(c1, b1) + cost_cycles(c2, b2))


@settings(max_examples=50)
@given(c=_COMPUTE, b=_BYTES, dc=_COMPUTE, db=_BYTES)
def test_cost_cycles_monotone_and_bounded_below(c, b, dc, db):
    base = cost_cycles(c, b)
    assert cost_cycles(c + dc, b) >= base
    assert cost_cycles(c, b + db) >= base
    # never below either constituent: the serial model's floor
    assert base >= c and base >= hbm_cycles(b)
    assert cost_cycles(0.0, 0) == 0.0


@settings(max_examples=50)
@given(c=_COMPUTE, b=_BYTES)
def test_compute_cycles_inverts_the_additive_split(c, b):
    # the calibration axes recover the compute half exactly from
    # est_cycles priced under the shared rule
    assert _fp(c, b).compute_cycles == pytest.approx(c, abs=1e-6 * (1 + c))


# --------------------------------------------------------------------------
# Footprint.fits: monotone in the budget
# --------------------------------------------------------------------------
@settings(max_examples=50)
@given(vmem=st.integers(min_value=1, max_value=1 << 24),
       hbm=st.integers(min_value=1, max_value=1 << 24),
       passes=st.integers(min_value=0, max_value=64),
       vpu=st.integers(min_value=0, max_value=1 << 20),
       grow=st.integers(min_value=0, max_value=1 << 20))
def test_fits_monotone_in_budget(vmem, hbm, passes, vpu, grow):
    fp = Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=passes,
                   vpu_ops=vpu, est_cycles=1.0)
    tight = ResourceBudget(vmem_bytes=vmem, hbm_bytes=hbm,
                           mxu_passes_budget=passes or None,
                           vpu_ops_budget=max(vpu, 1),
                           precision_bits=8)
    assert fp.fits(tight)
    # enlarging ANY quantitative column (or lifting a ceiling to
    # unlimited) never turns a fitting footprint into a misfit
    wider = [dataclasses.replace(tight, vmem_bytes=tight.vmem_bytes + grow),
             dataclasses.replace(tight, hbm_bytes=tight.hbm_bytes + grow),
             dataclasses.replace(tight, mxu_passes_budget=None),
             dataclasses.replace(tight, vpu_ops_budget=None)]
    for budget in wider:
        assert fp.fits(budget)
    # and shrinking below the footprint always rejects
    assert not fp.fits(dataclasses.replace(tight, vmem_bytes=vmem - 1))
    assert not fp.fits(dataclasses.replace(tight, hbm_bytes=hbm - 1))


@settings(max_examples=30)
@given(bits=st.sampled_from([8, 16, 32]),
       need=st.sampled_from([8, 16, 32]))
def test_fits_respects_operand_width_ceiling(bits, need):
    fp = _fp(10.0, 0, bits=bits)
    assert fp.fits(ResourceBudget(precision_bits=need)) == (need <= bits)


# --------------------------------------------------------------------------
# scaled(): round-trip bounds
# --------------------------------------------------------------------------
@settings(max_examples=50)
@given(f=_FRACTION,
       vmem=st.integers(min_value=1024, max_value=1 << 30),
       passes=st.integers(min_value=1, max_value=1 << 16),
       vpu=st.integers(min_value=1, max_value=1 << 24))
def test_scaled_shrinks_quantitative_columns_within_bounds(f, vmem, passes,
                                                           vpu):
    b = ResourceBudget(vmem_bytes=vmem, mxu_passes_budget=passes,
                       vpu_ops_budget=vpu)
    s = b.scaled(f)
    # every quantitative column lands in [floor(v*f) bounds]: never
    # negative, never above the original, exact int truncation
    for got, orig in ((s.vmem_bytes, vmem), (s.hbm_bytes, b.hbm_bytes),
                      (s.mxu_passes_budget, passes),
                      (s.vpu_ops_budget, vpu)):
        assert 0 <= got <= orig
        assert got == int(orig * f)
    # qualitative knobs pass through untouched
    assert s.mxu_available == b.mxu_available
    assert s.precision_bits == b.precision_bits
    assert s.prefer_parallel_streams == b.prefer_parallel_streams


@settings(max_examples=50)
@given(f=_FRACTION, vmem=st.integers(min_value=1024, max_value=1 << 30))
def test_scaled_round_trip_bounded_by_truncation(f, vmem):
    # scaling down then back up cannot exceed the original (int
    # truncation only loses), and loses less than 1/f per column
    s = ResourceBudget(vmem_bytes=vmem).scaled(f)
    back = s.scaled(1.0 / f)
    assert back.vmem_bytes <= vmem + 1   # +1: 1/f itself truncates
    assert vmem - back.vmem_bytes <= 1.0 / f + 1
    # full-budget identity: scaled(1.0) is exact on every int column
    one = ResourceBudget(vmem_bytes=vmem).scaled(1.0)
    assert one.vmem_bytes == vmem


@settings(max_examples=30)
@given(f=_FRACTION)
def test_scaled_none_ceilings_stay_none(f):
    s = ResourceBudget().scaled(f)
    assert s.mxu_passes_budget is None and s.vpu_ops_budget is None
