"""matmul IP family vs oracle: tile-shape sweeps, int8 exactness,
shared-weight dual-stream contract."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.matmul.ops import matmul, matmul_dual
from repro.kernels.matmul.ref import matmul_dual_ref, matmul_ref

SHAPES = [(8, 8, 8), (64, 96, 48), (100, 130, 70), (33, 17, 5),
          (256, 512, 128)]
TILES = [dict(bm=32, bn=32, bk=32), dict(bm=128, bn=128, bk=128)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("tiles", TILES)
def test_mm_mxu_int8_exact(rng, shape, tiles):
    m, k, n = shape
    a = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
    b = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
    out = matmul(a, b, ip="mm_mxu", **tiles)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(matmul_ref(a, b)))


@pytest.mark.parametrize("shape", SHAPES[:4])
def test_mm_mxu_float(rng, shape):
    m, k, n = shape
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    out = matmul(a, b, ip="mm_mxu", bm=32, bn=32, bk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES[:3])
def test_mm_vpu_matches(rng, shape):
    m, k, n = shape
    a = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
    b = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
    np.testing.assert_array_equal(np.asarray(matmul(a, b, ip="mm_vpu")),
                                  np.asarray(matmul_ref(a, b)))


@pytest.mark.parametrize("shape", SHAPES[:4])
def test_mm_dual_shared_int8(rng, shape):
    m, k, n = shape
    a1 = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
    a2 = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
    b = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
    y1, y2 = matmul_dual(a1, a2, b, ip="mm_dual_shared", bm=32, bn=32, bk=32)
    e1, e2 = matmul_dual_ref(a1, a2, b)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(e1))
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(e2))


def test_mm_dual_shared_rejects_wide(rng):
    a = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
    with pytest.raises(TypeError, match="8-bit"):
        matmul_dual(a, a, a, ip="mm_dual_shared")


def test_mm_dual_full_float(rng):
    a1 = jnp.asarray(rng.normal(size=(64, 96)).astype(np.float32))
    a2 = jnp.asarray(rng.normal(size=(64, 96)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(96, 48)).astype(np.float32))
    y1, y2 = matmul_dual(a1, a2, b, ip="mm_dual_full", bm=32, bn=32, bk=32)
    e1, e2 = matmul_dual_ref(a1, a2, b)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(e1), rtol=2e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(e2), rtol=2e-4,
                               atol=1e-5)
