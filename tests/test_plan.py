"""Network planner: one selection engine, partitioned budgets, cached &
serializable plans."""
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ip import SiteSpec
from repro.core.plan import (NetworkPlan, clear_plan_cache,
                             fixed_network_cost, plan_network, planner_stats,
                             select_ip)
from repro.core.resources import ResourceBudget
from repro.core.selector import (select_activation_ip, select_attention_ip,
                                 select_conv_ip, select_matmul_ip,
                                 select_pool_ip)

CONV_SHAPE = ((2, 32, 32, 3), (3, 3, 3, 16))

BUDGET_MATRIX = [
    ResourceBudget(),
    ResourceBudget(mxu_available=False),
    ResourceBudget(vpu_ops_budget=100_000),
    ResourceBudget(vmem_bytes=2 * 2**20),
    ResourceBudget(precision_bits=8, prefer_parallel_streams=True),
    ResourceBudget(precision_bits=8, mxu_passes_budget=1),
]


def _cnn_specs(site_prefix="net", n=2, hw=32, layers=((8, 16), (16, 32))):
    specs = []
    h = w = hw
    for li, (cin, cout) in enumerate(layers):
        conv_out = (n, h - 2, w - 2, cout)
        pool_out = (n, conv_out[1] // 2, conv_out[2] // 2, cout)
        specs += [
            SiteSpec.make(f"{site_prefix}{li}.conv", "conv2d",
                          ((n, h, w, cin), (3, 3, cin, cout)), "int8",
                          dual=False),
            SiteSpec.make(f"{site_prefix}{li}.pool", "pool2d", (conv_out,),
                          "int32", window=(2, 2), mode="max"),
            SiteSpec.make(f"{site_prefix}{li}.act", "activation", (pool_out,),
                          "int32", kind="relu"),
        ]
        h, w = pool_out[1], pool_out[2]
    return specs


# --------------------------------------------------------------------------
# Shim equivalence: the five historical entry points vs the generic engine
# --------------------------------------------------------------------------
@pytest.mark.parametrize("budget", BUDGET_MATRIX)
def test_select_conv_shim_equals_generic(budget):
    for dual in (False, True):
        spec = SiteSpec.make("s", "conv2d", CONV_SHAPE, jnp.int8, dual=dual)
        try:
            want = select_conv_ip(*CONV_SHAPE, dual=dual, dtype=jnp.int8,
                                  budget=budget, with_footprint=True)
        except ValueError:
            with pytest.raises(ValueError, match="no feasible IP"):
                select_ip("conv2d", spec, budget=budget)
            continue
        got = select_ip("conv2d", spec, budget=budget, with_footprint=True)
        assert got[0] is want[0]
        assert got[1] == want[1]


@pytest.mark.parametrize("budget", BUDGET_MATRIX)
def test_other_family_shims_equal_generic(budget):
    cases = [
        ("pool2d",
         lambda: select_pool_ip((2, 16, 16, 8), window=(2, 2), mode="avg",
                                dtype=jnp.int32, budget=budget),
         SiteSpec.make("s", "pool2d", ((2, 16, 16, 8),), jnp.int32,
                       window=(2, 2), stride=None, mode="avg")),
        ("activation",
         lambda: select_activation_ip((2, 8, 8, 16), kind="tanh",
                                      dtype=jnp.float32, budget=budget),
         SiteSpec.make("s", "activation", ((2, 8, 8, 16),), jnp.float32,
                       kind="tanh")),
        ("matmul",
         lambda: select_matmul_ip((256, 256), (256, 256), dual=False,
                                  dtype=jnp.bfloat16, budget=budget),
         SiteSpec.make("s", "matmul", ((256, 256), (256, 256)),
                       jnp.bfloat16, dual=False)),
        ("attention",
         lambda: select_attention_ip((2, 8, 128, 64), (2, 2, 128, 64),
                                     budget=budget),
         SiteSpec.make("s", "attention", ((2, 8, 128, 64), (2, 2, 128, 64)),
                       jnp.bfloat16)),
    ]
    for family, shim, spec in cases:
        try:
            want = shim()
        except ValueError:
            with pytest.raises(ValueError, match="no feasible IP"):
                select_ip(family, spec, budget=budget)
            continue
        assert select_ip(family, spec, budget=budget) is want


# --------------------------------------------------------------------------
# Budget partitioning
# --------------------------------------------------------------------------
def test_partitioned_slices_fit_and_sum_to_one():
    budget = ResourceBudget(vpu_ops_budget=2_000_000)
    plan = plan_network(_cnn_specs(), budget)
    assert abs(sum(s.fraction for s in plan.sites) - 1.0) < 1e-6
    for s in plan.sites:
        assert s.footprint.fits(budget.scaled(s.fraction)), s.spec.name


def test_partition_repair_rescues_starved_site():
    """A huge conv dwarfs a small one: proportional-to-cost alone gives
    the small site a VMEM slice below any member's working set, and the
    greedy repair pass must floor it back to feasibility."""
    specs = [
        SiteSpec.make("big.conv", "conv2d",
                      ((4, 32, 32, 16), (3, 3, 16, 32)), "int8", dual=False),
        SiteSpec.make("small.conv", "conv2d",
                      ((1, 16, 16, 8), (3, 3, 8, 16)), "int8", dual=False),
    ]
    # big ip1 needs ~133 KiB vmem, small ~15 KiB; big's cost share is
    # ~99%, so under a 200 KiB envelope the small site's proportional
    # slice (~3 KiB) fits nothing.
    budget = ResourceBudget(vmem_bytes=200 * 1024)
    plan = plan_network(specs, budget)
    small = plan.site("small.conv")
    assert small.footprint.fits(budget.scaled(small.fraction))
    assert small.fraction > 0.01  # repair raised it above the cost share
    assert abs(sum(s.fraction for s in plan.sites) - 1.0) < 1e-6


def test_no_feasible_partition_raises():
    # Each site alone fits the envelope (~133 KiB need vs 200 KiB), but
    # eight of them jointly demand ~5x it.
    specs = [
        SiteSpec.make(f"c{i}.conv", "conv2d",
                      ((4, 32, 32, 16), (3, 3, 16, 32)), "int8", dual=False)
        for i in range(8)
    ]
    single = plan_network(specs[:1], ResourceBudget(vmem_bytes=200 * 1024))
    assert len(single) == 1
    with pytest.raises(ValueError, match="no feasible network plan"):
        plan_network(specs, ResourceBudget(vmem_bytes=200 * 1024))


def test_site_infeasible_under_full_budget_raises_family_error():
    spec = SiteSpec.make("c.conv", "conv2d", CONV_SHAPE, jnp.int16, dual=True)
    with pytest.raises(ValueError, match="no feasible IP"):
        plan_network([spec], ResourceBudget(precision_bits=16,
                                            mxu_available=False))


def test_duplicate_site_names_rejected():
    spec = SiteSpec.make("dup", "conv2d", CONV_SHAPE, jnp.int8, dual=False)
    with pytest.raises(ValueError, match="duplicate site names"):
        plan_network([spec, spec], ResourceBudget())


# --------------------------------------------------------------------------
# Plan cache
# --------------------------------------------------------------------------
def test_plan_cache_returns_identical_object_with_zero_evals():
    budget = ResourceBudget(vmem_bytes=32 * 2**20)
    first = plan_network(_cnn_specs("cache"), budget)
    evals = planner_stats().selector_evals
    second = plan_network(_cnn_specs("cache"), budget)
    assert second is first
    assert planner_stats().selector_evals == evals


def test_plan_cache_distinguishes_budgets():
    a = plan_network(_cnn_specs("cacheb"), ResourceBudget())
    b = plan_network(_cnn_specs("cacheb"), ResourceBudget(mxu_available=False))
    assert a is not b


def test_second_cnn_block_trace_performs_zero_selector_evals(rng):
    from repro.models.blocks import apply_cnn_block, init_cnn_block
    block = init_cnn_block(jax.random.PRNGKey(0), cin=3, cout=16, k=3)
    images = jnp.asarray(rng.normal(size=(2, 16, 16, 3)).astype(np.float32))
    y1 = apply_cnn_block(block, images, activation="relu")
    evals = planner_stats().selector_evals
    y2 = apply_cnn_block(block, images, activation="relu")
    assert planner_stats().selector_evals == evals
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))


def test_mismatched_external_network_rejected(rng):
    from repro.models.blocks import (apply_cnn_block, cnn_block_site_specs,
                                     init_cnn_block)
    block = init_cnn_block(jax.random.PRNGKey(0), cin=3, cout=16, k=3)
    images = jnp.asarray(rng.normal(size=(2, 16, 16, 3)).astype(np.float32))
    specs, _ = cnn_block_site_specs(images.shape, block["w"].shape,
                                    x_dtype=images.dtype, activation="relu")
    network = plan_network(specs)
    with pytest.raises(ValueError, match="plan/site mismatch"):
        apply_cnn_block(block, images, activation="tanh", network=network)


def test_frontend_plans_whole_stack_as_one_network(rng):
    from repro.core import plan as plan_mod
    from repro.models.frontends import apply_cnn_frontend, init_cnn_frontend
    p = init_cnn_frontend(jax.random.PRNGKey(1), channels=(3, 8, 16),
                          d_model=32)
    imgs = jnp.asarray(rng.normal(size=(2, 16, 16, 3)).astype(np.float32))
    clear_plan_cache()
    misses = planner_stats().plan_misses
    out = {}
    apply_cnn_frontend(p, imgs, plan=out, fuse=False)
    # one whole-network plan covering both blocks, not one per block
    assert planner_stats().plan_misses == misses + 1
    assert len(out) == 6
    key = next(k for k in plan_mod._PLAN_CACHE
               if len(k[0]) == 6)  # 2 blocks x 3 sites in ONE graph key
    assert {s.name.split(".")[0] for s in key[0]} == {"frontend"}


# --------------------------------------------------------------------------
# Serialization
# --------------------------------------------------------------------------
def test_plan_json_round_trip():
    budget = ResourceBudget(vpu_ops_budget=2_000_000, precision_bits=8)
    plan = plan_network(_cnn_specs("json"), budget)
    restored = NetworkPlan.from_json(plan.to_json())
    assert restored == plan
    assert restored.budget == budget
    for name, (ip, fp) in plan.items():
        rip, rfp = restored[name]
        assert rip is ip          # re-linked to the live registry object
        assert rfp == fp
    assert restored.total_cycles == plan.total_cycles


def test_sitespec_round_trip_preserves_tuple_knobs():
    spec = SiteSpec.make("s.pool", "pool2d", ((2, 16, 16, 8),), "int32",
                         window=(2, 2), stride=None, mode="max")
    back = SiteSpec.from_dict(spec.to_dict())
    assert back == spec
    assert back.knob("window") == (2, 2)
    hash(back)  # knobs stay hashable after the JSON round-trip


# --------------------------------------------------------------------------
# scaled() (satellite): the ceilings must scale with the slice
# --------------------------------------------------------------------------
def test_scaled_budget_scales_pass_and_op_ceilings():
    b = ResourceBudget(mxu_passes_budget=100, vpu_ops_budget=1_000_000)
    half = b.scaled(0.5)
    assert half.mxu_passes_budget == 50
    assert half.vpu_ops_budget == 500_000
    assert half.vmem_bytes == b.vmem_bytes // 2
    none = ResourceBudget().scaled(0.25)
    assert none.mxu_passes_budget is None and none.vpu_ops_budget is None
    assert b.scaled(0.5).precision_bits == b.precision_bits


# --------------------------------------------------------------------------
# Planned vs fixed networks (benchmarks/run.py::table3 acceptance)
# --------------------------------------------------------------------------
def _load_bench():
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "benchmarks" / "run.py")
    spec = importlib.util.spec_from_file_location("bench_run", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_planned_network_beats_every_fixed_baseline_somewhere():
    bench = _load_bench()
    bench.table3_comparison()
    rows = [d for n, _, d in bench.ROWS if n.startswith("table3.")]
    assert rows
    assert any("planned_best=1" in d for d in rows), rows


def test_fixed_network_cost_infeasible_is_none():
    specs = _cnn_specs("fix")
    assert fixed_network_cost(
        specs, {"conv2d": "ip2_mxu", "pool2d": "pool_im2col",
                "activation": "act_vpu"},
        ResourceBudget(mxu_available=False)) is None
    cost = fixed_network_cost(
        specs, {"conv2d": "ip1_vpu", "pool2d": "pool_vpu",
                "activation": "act_vpu"}, ResourceBudget())
    assert cost is not None and cost > 0


# --------------------------------------------------------------------------
# Cache correctness under calibration: a refreshed table must invalidate
# stale plans and stale replan shares (core/calibrate_cost.py)
# --------------------------------------------------------------------------
def _refit(table, plan):
    """Record 3 synthetic samples against a planned site and refit —
    the minimal operation that moves the table's identity."""
    site = plan.sites[0]
    for us in (10.0, 20.0, 30.0):
        table.record(site.ip.name, site.footprint, us,
                     bits=site.precision_bits,
                     native_bits=site.spec.native_bits)
    return table.fit()


def test_plan_cache_keys_on_calibration_identity():
    from repro.core.calibrate_cost import CalibrationTable
    specs = tuple(_cnn_specs("calkey"))
    budget = ResourceBudget()
    clear_plan_cache()
    table = CalibrationTable()
    stats = planner_stats()
    misses0 = stats.plan_misses
    p1 = plan_network(specs, budget, calibration=table)
    assert stats.plan_misses == misses0 + 1
    # identical table identity -> cache hit, same object
    hits0 = stats.plan_hits
    assert plan_network(specs, budget, calibration=table) is p1
    assert stats.plan_hits == hits0 + 1
    # refitting moves key(): the same call must MISS (no stale plan)
    key0 = table.key()
    _refit(table, p1)
    assert table.key() != key0
    misses1 = stats.plan_misses
    plan_network(specs, budget, calibration=table)
    assert stats.plan_misses == misses1 + 1


def test_calibrated_and_uncalibrated_plans_cached_separately():
    from repro.core import plan as plan_mod
    from repro.core.calibrate_cost import CalibrationTable
    specs = tuple(_cnn_specs("calsep"))
    budget = ResourceBudget()
    clear_plan_cache()
    plan_network(specs, budget)
    plan_network(specs, budget, calibration=CalibrationTable())
    keys = [k for k in plan_mod._PLAN_CACHE if k[0] == specs]
    assert len(keys) == 2
    # key layout: (specs, budget, fuse, mesh, calibration_key)
    assert {k[4] for k in keys} == {None,
                                    CalibrationTable().key()}


def test_replan_shares_keyed_on_calibration_identity():
    from repro.core.calibrate_cost import CalibrationTable
    from repro.core.plan import replan
    specs = tuple(_cnn_specs("calshare"))
    table = CalibrationTable()
    clear_plan_cache()
    stats = planner_stats()
    warm = replan(specs, ResourceBudget(), calibration=table)  # warms shares
    fast0 = stats.replan_fast
    replan(specs, ResourceBudget(vmem_bytes=2 * 2**20), calibration=table)
    assert stats.replan_fast == fast0 + 1
    # a REFIT table must not serve off the stale shares: same graph,
    # same budget shape, but the share lookup misses and falls cold
    _refit(table, warm)
    cold0 = stats.replan_cold
    replan(specs, ResourceBudget(vmem_bytes=3 * 2**20), calibration=table)
    assert stats.replan_cold == cold0 + 1


def test_replan_strict_agrees_with_cold_calibrated_plan():
    from repro.core import plan as plan_mod
    from repro.core.calibrate_cost import AffineFit, CalibrationTable
    from repro.core.plan import replan
    specs = tuple(_cnn_specs("calstrict"))
    budget = ResourceBudget(vmem_bytes=4 * 2**20)
    clear_plan_cache()
    # a table that actually changes decisions: the analytical conv
    # winner is priced as measured-terrible (fuse=False throughout —
    # the scenario targets the per-op conv member)
    base = plan_network(specs, ResourceBudget(), fuse=False)
    conv_winner = next(s.ip.name for s in base.sites
                       if s.spec.family == "conv2d")
    table = CalibrationTable(
        fits={conv_winner: AffineFit(0.0, 0.0, 1e6, 3)})
    got = replan(specs, budget, strict=True, fuse=False, calibration=table)
    cold = plan_mod._plan_uncached(specs, budget, fuse=False,
                                   calibration=table)
    assert plan_mod._assignment(got) == plan_mod._assignment(cold)
    assert all(s.ip.name != conv_winner for s in got.sites)
