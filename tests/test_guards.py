"""Execution guards: backoff-schedule properties (deadline-bounded,
monotone, seed-deterministic — property-based), every ``execute_guarded``
outcome path, and guarded serving through ``AdaptiveServer``."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.resources import ResourceBudget
from repro.models.frontends import init_cnn_frontend
from repro.obs import EVENTS
from repro.runtime import AdaptiveServer
from repro.runtime.faults import INJECTOR, DeviceLost, FaultSpec, InjectedFault
from repro.runtime.guards import (MAX_DEVICE_RETRIES, GuardPolicy,
                                  GuardViolation, backoff_schedule,
                                  execute_guarded, screen_finite)

DEVICE = ResourceBudget(vpu_ops_budget=15_000_000)

POLICY_STRATEGY = dict(
    max_retries=st.integers(min_value=0, max_value=8),
    base=st.floats(min_value=1e-4, max_value=0.1),
    factor=st.floats(min_value=1.0, max_value=4.0),
    jitter=st.floats(min_value=0.0, max_value=1.0),
    remaining=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**16),
)


def _policy(max_retries, base, factor, jitter):
    return GuardPolicy(max_retries=max_retries, backoff_base_s=base,
                       backoff_factor=factor, backoff_jitter=jitter)


# --------------------------------------------------------------------------
# backoff_schedule: the three properties the retry loop relies on
# --------------------------------------------------------------------------
@settings(max_examples=50)
@given(**POLICY_STRATEGY)
def test_backoff_total_never_exceeds_deadline(max_retries, base, factor,
                                              jitter, remaining, seed):
    delays = backoff_schedule(_policy(max_retries, base, factor, jitter),
                              remaining, seed=seed)
    assert len(delays) <= max_retries
    assert sum(delays) <= remaining + 1e-12


@settings(max_examples=50)
@given(**POLICY_STRATEGY)
def test_backoff_is_monotone_nondecreasing(max_retries, base, factor,
                                           jitter, remaining, seed):
    delays = backoff_schedule(_policy(max_retries, base, factor, jitter),
                              remaining, seed=seed)
    assert all(b >= a for a, b in zip(delays, delays[1:]))
    assert all(d >= 0.0 for d in delays)


@settings(max_examples=50)
@given(**POLICY_STRATEGY)
def test_backoff_is_deterministic_under_seed(max_retries, base, factor,
                                             jitter, remaining, seed):
    p = _policy(max_retries, base, factor, jitter)
    assert (backoff_schedule(p, remaining, seed=seed)
            == backoff_schedule(p, remaining, seed=seed))


def test_backoff_unbounded_without_deadline():
    p = GuardPolicy(max_retries=3, backoff_base_s=1.0, backoff_factor=2.0)
    assert backoff_schedule(p, None) == [1.0, 2.0, 4.0]
    # and the truncation really is at the first overdrawing delay
    assert backoff_schedule(p, 3.5) == [1.0, 2.0]


# --------------------------------------------------------------------------
# Policy validation + screening
# --------------------------------------------------------------------------
def test_policy_validation():
    with pytest.raises(ValueError, match="on_nonfinite"):
        GuardPolicy(on_nonfinite="panic")
    with pytest.raises(ValueError, match="max_retries"):
        GuardPolicy(max_retries=-1)
    with pytest.raises(ValueError, match="backoff_factor"):
        GuardPolicy(backoff_factor=0.5)
    with pytest.raises(ValueError, match="backoff_jitter"):
        GuardPolicy(backoff_jitter=2.0)


def test_screen_finite():
    assert screen_finite(np.ones((2, 2)))
    assert not screen_finite(np.array([1.0, float("nan")]))
    assert not screen_finite(np.array([1.0, float("inf")]))


# --------------------------------------------------------------------------
# execute_guarded: one test per terminal path (fake clock + sleep)
# --------------------------------------------------------------------------
class _Clock:
    """Deterministic wall/sleep pair: sleep() advances wall()."""

    def __init__(self):
        self.t = 0.0
        self.slept = []

    def wall(self):
        return self.t

    def sleep(self, d):
        self.slept.append(d)
        self.t += d


def _run(attempt, policy, **kw):
    clk = _Clock()
    y, report = execute_guarded(attempt, policy, wall=clk.wall,
                                sleep=clk.sleep, **kw)
    return y, report, clk


def test_clean_attempt_passes_through():
    y, report, clk = _run(lambda retry_f32=False: np.ones(2), GuardPolicy())
    assert report.outcome == "ok" and report.retries == 0
    assert clk.slept == [] and y is not None


def test_transient_fault_retries_and_recovers():
    calls = []

    def attempt(retry_f32=False):
        calls.append(retry_f32)
        if len(calls) == 1:
            raise InjectedFault("boom")
        return np.ones(2)

    y, report, clk = _run(attempt, GuardPolicy(max_retries=2,
                                               backoff_base_s=0.01))
    assert y is not None and report.outcome == "ok"
    assert report.retries == 1 and not report.retried_f32
    assert clk.slept == [0.01]          # the retry paid its backoff delay
    assert calls == [False, False]      # ladder untouched for plain faults


def test_nonfinite_reject_fails_immediately():
    EVENTS.clear()
    calls = []

    def attempt(retry_f32=False):
        calls.append(retry_f32)
        return np.array([float("nan")])

    y, report, _ = _run(attempt, GuardPolicy(on_nonfinite="reject",
                                             max_retries=4), tenant="a")
    assert y is None and report.outcome == "rejected"
    assert report.retries == 0 and len(calls) == 1
    evs = EVENTS.recent(kind="guard.rejected")
    assert evs and evs[-1]["tenant"] == "a"


def test_nonfinite_retry_f32_flips_the_ladder_off():
    calls = []

    def attempt(retry_f32=False):
        calls.append(retry_f32)
        return np.ones(2) if retry_f32 else np.array([float("nan")])

    y, report, _ = _run(attempt, GuardPolicy(on_nonfinite="retry_f32",
                                             backoff_base_s=0.001))
    assert y is not None and report.outcome == "ok"
    assert report.retried_f32 and calls == [False, True]


def test_screening_off_lets_nonfinite_through():
    y, report, _ = _run(lambda retry_f32=False: np.array([float("nan")]),
                        GuardPolicy(screen_outputs=False))
    assert y is not None and report.outcome == "ok"


def test_retry_budget_exhausted_is_rejected():
    def attempt(retry_f32=False):
        raise InjectedFault("always")

    y, report, clk = _run(attempt, GuardPolicy(max_retries=2,
                                               backoff_base_s=0.01))
    assert y is None and report.outcome == "rejected"
    assert report.retries == 2 and len(clk.slept) == 2
    assert "retries exhausted" in report.reason


def test_hopeless_deadline_is_shed_not_retried():
    calls = []

    def attempt(retry_f32=False):
        calls.append(1)
        raise InjectedFault("always")

    # remaining 0: the whole schedule truncates away — one attempt, shed
    y, report, clk = _run(attempt, GuardPolicy(max_retries=3,
                                               backoff_base_s=0.01),
                          remaining_s=0.0)
    assert y is None and report.outcome == "shed"
    assert len(calls) == 1 and clk.slept == []


def test_deadline_passing_mid_retry_sheds():
    """The live deadline check: the schedule fit at entry, but wall time
    spent in failing attempts eats it before the next retry."""
    clk = _Clock()

    def attempt(retry_f32=False):
        clk.t += 0.4                     # each attempt burns real time
        raise InjectedFault("slow failure")

    y, report = execute_guarded(
        attempt, GuardPolicy(max_retries=3, backoff_base_s=0.1,
                             backoff_factor=1.0),
        remaining_s=0.6, wall=clk.wall, sleep=clk.sleep)
    assert y is None and report.outcome == "shed"
    assert "hopeless" in report.reason
    assert report.retries == 1           # one retry fit, the second did not


def test_device_loss_degrades_and_retries_free():
    lost = []
    calls = []

    def attempt(retry_f32=False):
        calls.append(1)
        if len(calls) == 1:
            raise DeviceLost("corpse", device=3)
        return np.ones(2)

    y, report, clk = _run(attempt, GuardPolicy(max_retries=0),
                          on_device_loss=lambda e: lost.append(e.device))
    assert y is not None and report.outcome == "ok"
    assert lost == [3]
    assert report.retries == 1 and clk.slept == []   # structural: no backoff


def test_device_loss_without_hook_is_rejected():
    def attempt(retry_f32=False):
        raise DeviceLost("corpse", device=0)

    y, report, _ = _run(attempt, GuardPolicy())
    assert y is None and report.outcome == "rejected"


def test_device_loss_retries_are_bounded():
    calls = []

    def attempt(retry_f32=False):
        calls.append(1)
        raise DeviceLost("unkillable corpse", device=0)

    y, report, _ = _run(attempt, GuardPolicy(max_retries=8),
                        on_device_loss=lambda e: None)
    assert y is None and report.outcome == "rejected"
    assert len(calls) == MAX_DEVICE_RETRIES + 1


def test_failing_degradation_rejects():
    def attempt(retry_f32=False):
        raise DeviceLost("corpse", device=0)

    def bad_hook(e):
        raise ValueError("cannot shrink past the last tenant")

    y, report, _ = _run(attempt, GuardPolicy(), on_device_loss=bad_hook)
    assert y is None and report.outcome == "rejected"
    assert "degradation failed" in report.reason


# --------------------------------------------------------------------------
# Guarded serving through AdaptiveServer
# --------------------------------------------------------------------------
def _guarded_server(policy):
    srv = AdaptiveServer(DEVICE, max_batch=2)
    srv.register("a", init_cnn_frontend(jax.random.PRNGKey(0),
                                        channels=(6, 12), d_model=16),
                 (12, 12, 6))
    srv.set_guard("a", policy)
    return srv


def test_set_guard_validates_and_clears():
    srv = _guarded_server(GuardPolicy())
    assert srv.guard_for("a") is not None
    srv.set_guard("a", None)
    assert srv.guard_for("a") is None
    with pytest.raises(KeyError):
        srv.set_guard("ghost", GuardPolicy())


def test_poisoned_batch_is_rejected_not_served():
    srv = _guarded_server(GuardPolicy(on_nonfinite="reject"))
    rng = np.random.default_rng(0)
    with INJECTOR.armed([FaultSpec("nan_output", step=0)]):
        for _ in range(2):
            srv.submit("a", rng.normal(size=(12, 12, 6)).astype(np.float32))
        comps = srv.drain()
    assert len(comps) == 2
    assert all(not c.ok and c.result is None for c in comps)
    tel = srv.telemetry()["a"]
    assert tel["guard_rejected"] == 2 and tel["requests"] == 0
    assert srv.tenants["a"].lane_free == 0.0     # rejected work bills no lane


def test_transient_kernel_fault_is_absorbed_by_retry():
    srv = _guarded_server(GuardPolicy(max_retries=2, backoff_base_s=0.001))
    rng = np.random.default_rng(0)
    with INJECTOR.armed([FaultSpec("kernel_exception", step=0)]):
        for _ in range(2):
            srv.submit("a", rng.normal(size=(12, 12, 6)).astype(np.float32))
        comps = srv.drain()
    assert len(comps) == 2 and all(c.ok for c in comps)
    tel = srv.telemetry()["a"]
    assert tel["guard_retries"] == 1 and tel["guard_rejected"] == 0


def test_unguarded_tenant_lets_faults_propagate():
    srv = _guarded_server(GuardPolicy())
    srv.set_guard("a", None)             # back to bare execution
    rng = np.random.default_rng(0)
    with INJECTOR.armed([FaultSpec("kernel_exception", step=0)]):
        srv.submit("a", rng.normal(size=(12, 12, 6)).astype(np.float32))
        with pytest.raises(InjectedFault):
            srv.step()
