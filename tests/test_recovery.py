"""Plan-preserving failure recovery: blind checkpoint restore, the
zero-cold-replan restart guarantee, snapshot validation (calibration
identity, floor drift), and the watchdog-armed RecoveryManager."""
import json
import time
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.checkpoint.store import restore_blind, save
from repro.core.plan import STATS, plan_cache_stats
from repro.core.resources import ResourceBudget
from repro.models.frontends import init_cnn_frontend
from repro.obs import EVENTS
from repro.runtime import (AdaptiveServer, RecoveryManager, SLOScheduler,
                           SLOSpec, recover_server, simulate_worker_death,
                           snapshot_server)
from repro.runtime.recovery import cold_replans_since

DEVICE = ResourceBudget(vpu_ops_budget=15_000_000)


def _frontend(key=0, channels=(6, 12), d_model=16):
    return init_cnn_frontend(jax.random.PRNGKey(key), channels=channels,
                             d_model=d_model)


def _deployment():
    srv = AdaptiveServer(DEVICE, policy="demand", max_batch=4)
    sched = SLOScheduler(srv)
    sched.register("a", _frontend(0), (12, 12, 6),
                   slo=SLOSpec(deadline_s=60.0, priority=1))
    sched.register("b", _frontend(1), (12, 12, 6),
                   slo=SLOSpec(deadline_s=120.0))
    return srv, sched


def _wave(sched, rng, n=4):
    for _ in range(n):
        sched.submit("a", rng.normal(size=(12, 12, 6)).astype(np.float32))
        sched.submit("b", rng.normal(size=(12, 12, 6)).astype(np.float32))
    return sched.run()


# --------------------------------------------------------------------------
# Blind restore: the crash-recovery entry point
# --------------------------------------------------------------------------
def test_restore_blind_rebuilds_without_target(tmp_path):
    tree = {"m": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                  "blocks": [np.ones((2,), np.float32),
                             (np.zeros((3,), np.float32),)]}}
    save(tmp_path, 1, tree, extra={"k": 7})
    got, extra = restore_blind(tmp_path)
    assert extra == {"k": 7}
    assert set(got) == {"m"}
    np.testing.assert_array_equal(got["m"]["w"], tree["m"]["w"])
    assert isinstance(got["m"]["blocks"], list)
    assert isinstance(got["m"]["blocks"][1], tuple)
    np.testing.assert_array_equal(got["m"]["blocks"][1][0],
                                  tree["m"]["blocks"][1][0])


def test_restore_blind_requires_structure_spec(tmp_path):
    tree = {"w": np.ones((2,), np.float32)}
    save(tmp_path, 1, tree)
    d = tmp_path / "step_000000001"
    manifest = json.loads((d / "manifest.json").read_text())
    manifest["structure"] = None          # a custom-node checkpoint
    (d / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(ValueError):
        restore_blind(tmp_path)


# --------------------------------------------------------------------------
# The headline guarantee: restart re-plans ZERO cold graphs
# --------------------------------------------------------------------------
def test_recover_replans_nothing_cold(tmp_path):
    srv, sched = _deployment()
    rng = np.random.default_rng(0)
    # two identical waves settle the demand EWMA at the mix's fixed
    # point, so the post-crash wave re-arbitrates to the same grants
    _wave(sched, rng)
    _wave(sched, rng)
    grants_before = {n: t.granted for n, t in srv.tenants.items()}
    snapshot_server(srv, tmp_path, 1, scheduler=sched)

    simulate_worker_death()
    assert plan_cache_stats()["size"] == 0       # the crash was real

    before = STATS.plan_misses
    srv2, sched2 = recover_server(tmp_path)
    assert sched2 is not None
    assert sched2.slos == sched.slos
    assert srv2.clock == pytest.approx(srv.clock)
    for n, g in grants_before.items():
        assert srv2.tenants[n].granted == pytest.approx(g)
    comps = _wave(sched2, np.random.default_rng(0))
    assert len(comps) == 8                       # serving resumed
    assert cold_replans_since(before) == 0       # and NOTHING planned cold


def test_recover_without_scheduler_state(tmp_path):
    srv = AdaptiveServer(DEVICE, max_batch=4)
    srv.register("a", _frontend(0), (12, 12, 6))
    rng = np.random.default_rng(0)
    srv.submit("a", rng.normal(size=(12, 12, 6)).astype(np.float32))
    srv.step()
    snapshot_server(srv, tmp_path, 1)
    simulate_worker_death()
    srv2, sched2 = recover_server(tmp_path)
    assert sched2 is None
    assert set(srv2.tenants) == {"a"}


# --------------------------------------------------------------------------
# Snapshot validation: wrong deployment is rejected, not half-restored
# --------------------------------------------------------------------------
def test_recover_rejects_calibration_mismatch(tmp_path):
    srv, sched = _deployment()
    snapshot_server(srv, tmp_path, 1, scheduler=sched)

    class OtherTable:
        def key(self):
            return ("other-table", 42)

    with pytest.raises(ValueError, match="calibration mismatch"):
        recover_server(tmp_path, calibration=OtherTable())


def test_recover_rejects_floor_drift(tmp_path):
    srv, sched = _deployment()
    snapshot_server(srv, tmp_path, 1, scheduler=sched)
    step_dir = next(p for p in Path(tmp_path).iterdir()
                    if p.name.startswith("step_"))
    manifest = json.loads((step_dir / "manifest.json").read_text())
    manifest["extra"]["tenants"]["a"]["floor"] += 0.05   # drifted deploy
    (step_dir / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(ValueError, match="floor drifted"):
        recover_server(tmp_path)


# --------------------------------------------------------------------------
# RecoveryManager: watchdog wiring + adopt-the-replacement
# --------------------------------------------------------------------------
def test_recovery_manager_snapshot_kill_recover(tmp_path):
    srv, sched = _deployment()
    rng = np.random.default_rng(0)
    _wave(sched, rng)
    _wave(sched, rng)
    mgr = RecoveryManager(srv, tmp_path, scheduler=sched)
    mgr.snapshot()
    simulate_worker_death()
    before = STATS.plan_misses
    replacement = mgr.recover()
    assert replacement is not srv                # adopted the new server
    assert mgr.server is replacement
    assert mgr.scheduler is not None and mgr.scheduler is not sched
    _wave(mgr.scheduler, np.random.default_rng(0))
    assert cold_replans_since(before) == 0


def test_recover_rearms_the_watchdog_for_a_second_death(tmp_path):
    """Regression: the fire-once pattern (on_death stops the watchdog)
    left recovery deaf — after one recover() a SECOND worker death never
    fired.  recover() must re-arm: clear the latch on a live monitor or
    replace a joined one."""
    srv, sched = _deployment()
    died = []
    holder = {}

    def on_death():
        died.append(1)
        holder["mgr"].watchdog.stop()    # fire-once: the thread joins

    mgr = RecoveryManager(srv, tmp_path, scheduler=sched,
                          heartbeat_timeout_s=0.05, on_death=on_death)
    holder["mgr"] = mgr
    try:
        mgr.snapshot()
        deadline = time.monotonic() + 2.0
        while not died and time.monotonic() < deadline:
            time.sleep(0.01)
        assert died == [1]
        assert not mgr.watchdog._thread.is_alive()   # monitor is gone

        mgr.recover()                    # adopt replacement + re-arm
        assert mgr.watchdog._thread.is_alive()
        assert not mgr.watchdog.fired
        assert mgr.scheduler is not None
        assert mgr.scheduler.recovery is mgr   # beats reach the new dog

        deadline = time.monotonic() + 2.0
        while len(died) < 2 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert len(died) == 2            # the second death fired too
    finally:
        mgr.stop()


def test_degrade_rearms_the_watchdog(tmp_path):
    """The heartbeat path's lighter alternative: degrade() shrinks the
    mesh in place and re-arms, so a second silence still fires."""
    from repro.core.resources import MeshSpec

    srv = AdaptiveServer(DEVICE, max_batch=2, mesh=MeshSpec(devices=2))
    srv.register("a", _frontend(0), (12, 12, 6))
    srv.arbiter.observe("a", 100.0)
    srv._apply_shares(srv.arbiter.split())
    died = []
    holder = {}

    def on_death():
        died.append(1)
        holder["mgr"].watchdog.stop()

    mgr = RecoveryManager(srv, tmp_path, heartbeat_timeout_s=0.05,
                          on_death=on_death)
    holder["mgr"] = mgr
    try:
        deadline = time.monotonic() + 2.0
        while not died and time.monotonic() < deadline:
            time.sleep(0.01)
        assert died == [1]
        affected = mgr.degrade(1)        # silence treated as device loss
        assert affected == ["a"]
        assert srv.mesh.devices == 1
        assert mgr.watchdog._thread.is_alive()
        deadline = time.monotonic() + 2.0
        while len(died) < 2 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert len(died) == 2
    finally:
        mgr.stop()


def test_snapshot_round_trips_guard_policies(tmp_path):
    """Guard policies are serving state: a recovered server screens the
    same way the dead one did."""
    from repro.runtime.guards import GuardPolicy

    srv, sched = _deployment()
    policy = GuardPolicy(on_nonfinite="retry_f32", max_retries=3,
                         backoff_base_s=0.002)
    srv.set_guard("a", policy)
    snapshot_server(srv, tmp_path, 1, scheduler=sched)
    simulate_worker_death()
    srv2, _ = recover_server(tmp_path)
    assert srv2.guard_for("a") == policy
    assert srv2.guard_for("b") is None


def test_recovery_manager_watchdog_detects_silence(tmp_path):
    EVENTS.clear()
    srv, sched = _deployment()
    died = []
    mgr = RecoveryManager(srv, tmp_path, scheduler=sched,
                          heartbeat_timeout_s=0.05,
                          on_death=lambda: died.append(1))
    try:
        deadline = time.monotonic() + 2.0
        while not died and time.monotonic() < deadline:
            time.sleep(0.01)
    finally:
        mgr.stop()
    assert died
    assert EVENTS.recent(kind="recovery.heartbeat_lost")
