"""Component-level invariants: RoPE, norms, MoE routing, mamba/rwkv
recurrence step-vs-sequence consistency, loss properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.base import ModelConfig, MoEConfig
from repro.models import mamba as mamba_mod
from repro.models import rwkv as rwkv_mod
from repro.models.blocks import apply_rope, rope_freqs, softmax_xent
from repro.models.moe import _top_k_gating, apply_moe, init_moe


def _cfg(**kw) -> ModelConfig:
    base = dict(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64,
                compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
@pytest.mark.parametrize("style", ["full", "half"])
def test_rope_preserves_norm(rng, style):
    cfg = _cfg(rope_style=style)
    x = jnp.asarray(rng.normal(size=(2, 8, 4, cfg.head_dim)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    cos, sin = rope_freqs(cfg, pos)
    y = apply_rope(cfg, x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


def test_rope_relative_property(rng):
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    cfg = _cfg(rope_style="full")
    q = jnp.asarray(rng.normal(size=(1, 1, 1, cfg.head_dim)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 1, cfg.head_dim)).astype(np.float32))

    def dot_at(i, j):
        ci, si = rope_freqs(cfg, jnp.array([[i]]))
        cj, sj = rope_freqs(cfg, jnp.array([[j]]))
        qi = apply_rope(cfg, q, ci, si)
        kj = apply_rope(cfg, k, cj, sj)
        return float(jnp.sum(qi * kj))

    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4
    assert abs(dot_at(0, 0) - dot_at(9, 9)) < 1e-4


def test_rope_zero_position_identity(rng):
    cfg = _cfg(rope_style="full")
    x = jnp.asarray(rng.normal(size=(1, 1, 2, cfg.head_dim)).astype(np.float32))
    cos, sin = rope_freqs(cfg, jnp.zeros((1, 1), jnp.int32))
    np.testing.assert_allclose(np.asarray(apply_rope(cfg, x, cos, sin)),
                               np.asarray(x), atol=1e-6)


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------
def test_topk_gating_properties(rng):
    logits = jnp.asarray(rng.normal(size=(2, 16, 8)).astype(np.float32))
    idx, gate, probs = _top_k_gating(logits, 2)
    assert idx.shape == (2, 16, 2)
    # distinct experts per token
    assert (np.asarray(idx[..., 0]) != np.asarray(idx[..., 1])).all()
    # gates normalized
    np.testing.assert_allclose(np.asarray(gate.sum(-1)), 1.0, rtol=1e-5)
    # slot-0 is the argmax
    np.testing.assert_array_equal(np.asarray(idx[..., 0]),
                                  np.asarray(jnp.argmax(probs, -1)))


def test_moe_forward_and_capacity(rng):
    cfg = _cfg(family="moe", moe=MoEConfig(n_experts=4, top_k=2,
                                           capacity_factor=1.25))
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 16, 32)).astype(np.float32))
    out, aux = apply_moe(cfg, p, x, num_groups=1)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 1.0 - 1e-3  # Switch aux loss lower bound is ~1


def test_moe_group_invariance(rng):
    """Different group counts change capacity locality, not magnitude."""
    cfg = _cfg(family="moe", moe=MoEConfig(n_experts=4, top_k=2,
                                           capacity_factor=4.0))
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(4, 8, 32)).astype(np.float32))
    out1, _ = apply_moe(cfg, p, x, num_groups=1)
    out2, _ = apply_moe(cfg, p, x, num_groups=2)
    # with generous capacity nothing drops, so outputs match exactly
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=2e-4, atol=1e-5)


# --------------------------------------------------------------------------
# Mamba / RWKV: sequence forward == step-by-step decode
# --------------------------------------------------------------------------
def test_mamba_seq_vs_step(rng):
    from repro.configs.base import MambaConfig
    cfg = _cfg(family="hybrid", mamba=MambaConfig(d_state=4, d_conv=2,
                                                  expand=2))
    p = mamba_mod.init_mamba(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 6, 32)).astype(np.float32))
    y_seq, cache_seq = mamba_mod.mamba_forward_with_cache(cfg, p, x)
    cache = mamba_mod.init_mamba_cache(cfg, 2, dtype=jnp.float32)
    ys = []
    for t in range(6):
        y_t, cache = mamba_mod.mamba_step(cfg, p, x[:, t:t + 1, :], cache)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_step),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cache_seq["ssm"]),
                               np.asarray(cache["ssm"]), rtol=1e-4,
                               atol=1e-5)


def test_rwkv_seq_vs_step(rng):
    from repro.configs.base import RWKVConfig
    cfg = _cfg(family="ssm", n_kv_heads=4,
               rwkv=RWKVConfig(head_size=8, lora_rank_decay=4))
    p = rwkv_mod.init_rwkv_tm(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 5, 32)).astype(np.float32))
    st0 = rwkv_mod.init_rwkv_state(cfg, 2)
    y_seq, last_x, state_seq = rwkv_mod.rwkv_time_mix(
        cfg, p, x, st0["tm_x"], st0["state"])
    # step-by-step with carried prev-token and state
    prev = st0["tm_x"]
    state = st0["state"]
    ys = []
    for t in range(5):
        y_t, prev, state = rwkv_mod.rwkv_time_mix(
            cfg, p, x[:, t:t + 1, :], prev, state)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_step),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state_seq), np.asarray(state),
                               rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_xent_lower_bound(seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(2, 8, 32)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 32, (2, 8)), dtype=jnp.int32)
    loss = float(softmax_xent(logits, labels, z_loss=0.0))
    assert loss >= 0.0
    # perfect logits drive loss toward zero
    perfect = 100.0 * jax.nn.one_hot(labels, 32)
    assert float(softmax_xent(perfect, labels, z_loss=0.0)) < 1e-3


# --------------------------------------------------------------------------
# §Perf knobs preserve semantics
# --------------------------------------------------------------------------
def test_moe_scatter_equals_einsum_dispatch(rng):
    cfg = _cfg(family="moe", moe=MoEConfig(n_experts=4, top_k=2,
                                           capacity_factor=4.0))
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(4, 8, 32)).astype(np.float32))
    out_e, aux_e = apply_moe(cfg, p, x, num_groups=2)
    cfg_s = dataclasses.replace(cfg, moe_dispatch="scatter")
    out_s, aux_s = apply_moe(cfg_s, p, x, num_groups=2)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_s),
                               rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_e), float(aux_s), rtol=1e-5)


def test_moe_scatter_grads_flow(rng):
    cfg = _cfg(family="moe", moe=MoEConfig(n_experts=4, top_k=2),
               moe_dispatch="scatter")
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 8, 32)).astype(np.float32))

    def loss(p):
        out, aux = apply_moe(cfg, p, x, num_groups=1)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    norms = [float(jnp.abs(l).max()) for l in jax.tree.leaves(g)]
    assert max(norms) > 0
    assert all(np.isfinite(n) for n in norms)


def test_bf16_scores_close_to_f32(rng):
    from repro.models.attention import full_attention
    cfg32 = _cfg()
    cfg16 = dataclasses.replace(cfg32, attn_score_dtype="bfloat16")
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 8)).astype(np.float32))
    o32 = full_attention(cfg32, q, k, v, causal=True)
    o16 = full_attention(cfg16, q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o32), np.asarray(o16),
                               rtol=0.1, atol=0.05)


def test_dotsremat_same_loss(rng):
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.models import api
    from repro.models.frontends import make_inputs
    cfg = get_config("olmo-1b", smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_inputs(cfg, ShapeConfig("s", 32, 2, "train"),
                        abstract=False)
    l1, _ = api.loss_fn(cfg, params, batch)
    cfg2 = dataclasses.replace(cfg, remat="block_dots")
    l2, _ = api.loss_fn(cfg2, params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
