"""Substrate: data pipeline determinism/resume, checkpoint roundtrip +
atomic commit + reshard, optimizer behaviour, gradient compression EF,
fault-tolerance monitors."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import store
from repro.data.pipeline import make_pipeline
from repro.optim.adamw import (AdamWConfig, apply_updates, init_opt_state,
                               lr_at)
from repro.optim.grad_compress import (compress_grads, init_ef_state,
                                       quantize_int8, dequantize_int8,
                                       topk_mask, wire_bytes)
from repro.runtime.fault_tolerance import (StragglerMonitor, Watchdog,
                                           choose_mesh_shape)


# --------------------------------------------------------------------------
# Data pipeline
# --------------------------------------------------------------------------
def test_pipeline_deterministic_and_seekable():
    p1 = make_pipeline(1000, 16, 4, seed=7)
    p2 = make_pipeline(1000, 16, 4, seed=7)
    b_51a = p1[51]
    # read other batches in between — indexability must not be stateful
    _ = p1[0], p1[99]
    b_51b = p1[51]
    np.testing.assert_array_equal(np.asarray(b_51a["tokens"]),
                                  np.asarray(b_51b["tokens"]))
    np.testing.assert_array_equal(np.asarray(b_51a["tokens"]),
                                  np.asarray(p2[51]["tokens"]))


def test_pipeline_shards_disjoint():
    a = make_pipeline(1000, 16, 8, seed=3, n_shards=2, shard_id=0)[5]
    b = make_pipeline(1000, 16, 8, seed=3, n_shards=2, shard_id=1)[5]
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(b["tokens"]))


def test_pipeline_labels_are_shifted_tokens():
    b = make_pipeline(1000, 16, 2, seed=0)[0]
    # labels[t] == tokens[t+1] by construction (same underlying stream)
    assert b["tokens"].shape == b["labels"].shape


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), step=st.integers(0, 10_000))
def test_pipeline_vocab_range(seed, step):
    b = make_pipeline(257, 8, 2, seed=seed)[step]
    toks = np.asarray(b["tokens"])
    assert toks.min() >= 0 and toks.max() < 257


# --------------------------------------------------------------------------
# Checkpointing
# --------------------------------------------------------------------------
def _tree(rng):
    return {"a": jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jnp.asarray(rng.normal(size=(3,)).astype(np.float32))}}


def test_checkpoint_roundtrip(tmp_path, rng):
    tree = _tree(rng)
    store.save(str(tmp_path), 7, tree, extra={"next_step": 8})
    restored, extra = store.restore(str(tmp_path), tree)
    assert extra["next_step"] == 8
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_gc(tmp_path, rng):
    tree = _tree(rng)
    for s in (1, 2, 3, 4, 5):
        store.save(str(tmp_path), s, tree, keep=2)
    assert store.latest_step(str(tmp_path)) == 5
    kept = sorted(p.name for p in tmp_path.iterdir()
                  if p.name.startswith("step_"))
    assert len(kept) == 2


def test_checkpoint_atomic_commit(tmp_path, rng):
    """LATEST only moves after a fully-written snapshot exists."""
    tree = _tree(rng)
    store.save(str(tmp_path), 1, tree)
    latest_before = store.latest_step(str(tmp_path))
    # simulate a crash mid-save: partial temp dir, LATEST untouched
    (tmp_path / ".step_000000002.partial").mkdir()
    assert store.latest_step(str(tmp_path)) == latest_before
    restored, _ = store.restore(str(tmp_path), tree)
    assert restored is not None


def test_async_checkpointer_supersedes(tmp_path, rng):
    tree = _tree(rng)
    ck = store.AsyncCheckpointer(str(tmp_path))
    for s in range(5):
        ck.save(s, jax.tree.map(lambda x: x + s, tree),
                extra={"next_step": s + 1})
    ck.wait()
    # the final state must be restorable and correspond to the last save
    restored, extra = store.restore(str(tmp_path), tree)
    assert extra["next_step"] == 5
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(tree["a"]) + 4)


def test_checkpoint_restore_dtype_cast(tmp_path, rng):
    tree = {"w": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}
    store.save(str(tmp_path), 0, tree)
    target = {"w": jnp.zeros((4,), jnp.bfloat16)}
    restored, _ = store.restore(str(tmp_path), target)
    assert restored["w"].dtype == jnp.bfloat16


# --------------------------------------------------------------------------
# Optimizer
# --------------------------------------------------------------------------
def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=100)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(cfg, params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_adamw_grad_clip_and_lr_schedule():
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=10,
                      total_steps=100)
    assert float(lr_at(cfg, jnp.int32(0))) < float(lr_at(cfg, jnp.int32(10)))
    assert float(lr_at(cfg, jnp.int32(100))) < float(lr_at(cfg, jnp.int32(10)))
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(cfg, params)
    _, _, metrics = apply_updates(cfg, params, {"w": jnp.full(3, 1e6)}, state)
    assert float(metrics["grad_norm"]) > 1e5  # norm reported pre-clip


def test_adamw_bf16_moments():
    cfg = AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones(4, jnp.float32)}
    state = init_opt_state(cfg, params)
    assert state.mu["w"].dtype == jnp.bfloat16
    p2, s2, _ = apply_updates(cfg, params, {"w": jnp.ones(4)}, state)
    assert s2.mu["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == jnp.float32


# --------------------------------------------------------------------------
# Gradient compression
# --------------------------------------------------------------------------
def test_int8_quantization_bounded_error(rng):
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_topk_keeps_largest(rng):
    x = jnp.asarray(rng.normal(size=(100,)).astype(np.float32))
    m = topk_mask(x, 0.1)
    kept = np.asarray(jnp.abs(x))[np.asarray(m) > 0]
    dropped = np.asarray(jnp.abs(x))[np.asarray(m) == 0]
    assert kept.min() >= dropped.max() - 1e-6
    assert 8 <= kept.size <= 12


@pytest.mark.parametrize("scheme", ["int8", "topk", "int8_topk"])
def test_error_feedback_unbiased_accumulation(rng, scheme):
    """Sum of wire grads + final residual == sum of true grads (EF
    conservation), so compression introduces no systematic drift."""
    grads_seq = [jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
                 for _ in range(10)]
    ef = init_ef_state(grads_seq[0])
    total_wire = jnp.zeros(64)
    for g in grads_seq:
        wire, ef = compress_grads(g, ef, scheme=scheme, topk_frac=0.2)
        total_wire = total_wire + wire
    total_true = sum(grads_seq)
    np.testing.assert_allclose(np.asarray(total_wire + ef.residual),
                               np.asarray(total_true), rtol=1e-4, atol=1e-4)


def test_wire_bytes_savings(rng):
    g = jnp.zeros((1000,), jnp.float32)
    assert wire_bytes(g, "int8") == 1000
    assert wire_bytes(g, "topk", 0.1) == 100 * 8
    assert wire_bytes(g, "none") == 4000


# --------------------------------------------------------------------------
# Fault tolerance
# --------------------------------------------------------------------------
def test_watchdog_fires_and_recovers():
    fired = threading.Event()
    dog = Watchdog(0.15, on_timeout=fired.set).start()
    time.sleep(0.05)
    dog.beat()
    assert not fired.is_set()
    time.sleep(0.4)
    assert fired.is_set()
    dog.stop()


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(threshold=2.0, warmup=2)
    for step in range(8):
        mon.record(step, 0.1)
    ev = mon.record(8, 0.5)
    assert ev is not None and ev.ratio > 2.0
    assert mon.record(9, 0.1) is None  # EWMA not poisoned


def test_choose_mesh_shape_elastic():
    assert choose_mesh_shape(256, prefer_model=16) == (16, 16)
    assert choose_mesh_shape(240, prefer_model=16) == (15, 16)
    # coverage-first: (125, 2) uses all 250 survivors
    assert choose_mesh_shape(250, prefer_model=16) == (125, 2)
    assert choose_mesh_shape(7, prefer_model=16) == (7, 1)
    for n in (3, 12, 100, 255):
        d, m = choose_mesh_shape(n, prefer_model=16)
        assert d * m <= n and 16 % m == 0
