"""Fault-tolerance runtime: watchdog lifecycle, straggler detection
(threshold/EWMA flagging, rearm gating, event emission), elastic
re-mesh shapes."""
import time

import pytest

from repro.core.shard import degree_ladder
from repro.obs import EVENTS
from repro.runtime.fault_tolerance import (StragglerMonitor, Watchdog,
                                           choose_mesh_shape, elastic_remesh)


def test_watchdog_fires_on_missed_beats():
    fired = []
    wd = Watchdog(timeout_s=0.05, on_timeout=lambda: fired.append(1)).start()
    deadline = time.monotonic() + 2.0
    while not fired and time.monotonic() < deadline:
        time.sleep(0.01)
    wd.stop()
    assert fired
    assert wd.fired


def test_watchdog_beats_keep_it_quiet():
    fired = []
    wd = Watchdog(timeout_s=0.2, on_timeout=lambda: fired.append(1)).start()
    for _ in range(6):
        wd.beat()
        time.sleep(0.03)
    wd.stop()
    assert not fired


def test_stopped_watchdog_never_fires_afterwards():
    """Regression: stop() must join the monitor thread, and a stopped
    watchdog must not invoke on_timeout later even though its last beat
    is long past the timeout."""
    fired = []
    wd = Watchdog(timeout_s=0.05, on_timeout=lambda: fired.append(1)).start()
    wd.beat()
    wd.stop()                      # before any timeout elapsed
    assert not wd._thread.is_alive()   # stop() joined the monitor
    time.sleep(0.2)                # well past timeout_s
    assert not fired
    assert not wd.fired


def test_watchdog_stop_from_on_timeout_callback():
    """Regression: the fire-once pattern — on_timeout calling stop() —
    must not self-join the monitor thread."""
    fired = []
    holder = {}

    def fire_once():
        fired.append(1)
        holder["wd"].stop()

    holder["wd"] = Watchdog(timeout_s=0.05, on_timeout=fire_once).start()
    deadline = time.monotonic() + 2.0
    while not fired and time.monotonic() < deadline:
        time.sleep(0.01)
    assert fired == [1]
    holder["wd"]._thread.join(timeout=1.0)     # loop exits cleanly
    assert not holder["wd"]._thread.is_alive()
    time.sleep(0.15)
    assert fired == [1]                        # and never fires again


def test_watchdog_stop_is_idempotent_and_safe_before_start():
    wd = Watchdog(timeout_s=0.05, on_timeout=lambda: None)
    wd.stop()                      # never started: no crash
    wd2 = Watchdog(timeout_s=0.05, on_timeout=lambda: None).start()
    wd2.stop()
    wd2.stop()                     # double stop: no crash


def test_straggler_monitor_flags_outliers():
    events = []
    mon = StragglerMonitor(threshold=2.0, warmup=2,
                           on_straggler=events.append)
    for step in range(5):
        mon.record(step, 1.0)
    ev = mon.record(5, 5.0)
    assert ev is not None and ev.ratio > 2.0
    assert events == [ev]
    # the outlier must not poison the EWMA
    assert mon.ewma < 1.5


def test_straggler_quiet_during_warmup_and_below_threshold():
    mon = StragglerMonitor(threshold=2.0, warmup=3)
    assert mon.record(0, 10.0) is None       # first sample seeds the EWMA
    assert mon.record(1, 19.0) is None       # warmup: never flagged
    for step in range(2, 8):
        assert mon.record(step, 1.9) is None  # 1.9x < threshold 2.0x
    assert mon.events == []
    assert mon.hook_fires == 0


def test_straggler_ewma_tracks_drift_not_spikes():
    """A slow *trend* raises the EWMA baseline so later equal steps stop
    flagging; a one-off spike is flagged but excluded from the fold."""
    mon = StragglerMonitor(threshold=2.0, alpha=0.5, warmup=2)
    for step in range(4):
        mon.record(step, 1.0)
    spike = mon.record(4, 3.0)
    assert spike is not None and spike.ratio == pytest.approx(3.0)
    assert mon.ewma == pytest.approx(1.0)    # spike did not poison it
    for step in range(5, 10):
        mon.record(step, 1.8)                # sustained drift folds in
    assert mon.ewma > 1.6
    assert mon.record(10, 1.8) is None       # new normal, not a straggler


def test_straggler_rearm_gates_hook_but_records_every_flag():
    hook = []
    mon = StragglerMonitor(threshold=2.0, warmup=2, rearm=2,
                           on_straggler=hook.append)
    for step in range(4):
        mon.record(step, 1.0)
    mon.record(4, 5.0)                       # fires + arms suppression
    mon.record(5, 5.0)                       # flagged, hook suppressed
    assert len(mon.events) == 2 and len(hook) == 1
    assert mon.hook_fires == 1
    mon.record(6, 1.0)                       # 2 normal steps re-arm...
    mon.record(7, 1.0)
    mon.record(8, 5.0)                       # ...so this fires again
    assert len(hook) == 2 and mon.hook_fires == 2
    assert len(mon.events) == 3              # every flag recorded


def test_straggler_flags_are_logged_as_events():
    EVENTS.clear()
    mon = StragglerMonitor(threshold=2.0, warmup=2, rearm=1)
    for step in range(4):
        mon.record(step, 1.0)
    mon.record(4, 5.0)
    mon.record(5, 5.0)                       # suppressed flag still logs
    evs = EVENTS.recent(kind="straggler.flagged")
    assert len(evs) == 2
    assert evs[0]["suppressed"] is False
    assert evs[1]["suppressed"] is True
    assert evs[0]["ratio"] == pytest.approx(5.0)


def test_straggler_rearm_validation():
    with pytest.raises(ValueError):
        StragglerMonitor(rearm=-1)


def test_watchdog_rearm_clears_the_latch_and_fires_again():
    """Regression: ``fired`` latches after the first timeout, so without
    ``rearm()`` a recovered deployment could never tell a SECOND hang
    from the stale flag."""
    fired = []
    wd = Watchdog(timeout_s=0.05, on_timeout=lambda: fired.append(1)).start()
    deadline = time.monotonic() + 2.0
    while not fired and time.monotonic() < deadline:
        time.sleep(0.01)
    assert fired and wd.fired
    wd.rearm()
    assert not wd.fired                  # latch cleared...
    assert wd._thread.is_alive()         # ...without touching the thread
    n = len(fired)
    deadline = time.monotonic() + 2.0
    while len(fired) <= n and time.monotonic() < deadline:
        time.sleep(0.01)
    wd.stop()
    assert len(fired) > n and wd.fired   # a second silence fires again


def test_watchdog_rearm_restarts_the_beat_window():
    """rearm() must also reset the beat clock: re-arming an idle
    watchdog whose last beat is ancient must not fire instantly."""
    fired = []
    wd = Watchdog(timeout_s=0.2, on_timeout=lambda: fired.append(1))
    wd._last_beat = time.monotonic() - 10.0   # stale beat from a past life
    wd.rearm()
    wd.start()
    time.sleep(0.05)                     # well inside the fresh window
    wd.stop()
    assert not fired


def test_choose_mesh_shape_prefers_model_divisors():
    assert choose_mesh_shape(16, prefer_model=16) == (1, 16)
    assert choose_mesh_shape(12, prefer_model=16) == (3, 4)
    assert choose_mesh_shape(3, prefer_model=16) == (3, 1)


def test_choose_mesh_shape_walks_the_degree_ladder():
    """The model-degree candidates are exactly the degree ladder of the
    pre-loss mesh, so a surviving model degree always divides it."""
    for n_dev in range(1, 20):
        data, model = choose_mesh_shape(n_dev, prefer_model=16)
        assert model in degree_ladder(16)
        assert data * model <= n_dev


def test_elastic_remesh_axis_mode_builds_a_1d_serving_mesh():
    mesh = elastic_remesh(1, axis="batch", offset=0)
    assert mesh.axis_names == ("batch",)
    assert mesh.devices.shape == (1,)


def test_elastic_remesh_axis_mode_refuses_short_pools():
    with pytest.raises(ValueError, match="device_count"):
        elastic_remesh(64, axis="batch")
