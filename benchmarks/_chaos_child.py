"""Forced-multi-device child for ``benchmarks/run.py::table_chaos``.

Launched by the parent bench with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` (JAX fixes its
device count at import, so the mesh arms cannot run in the parent).
Three arms over the same deterministic Poisson-spaced traffic, ONE json
object to stdout for the parent to assert on:

  transparency — the same serving trace twice: injector disarmed vs
      armed with a never-firing schedule.  Outputs, completion times
      and modeled latency percentiles must be bit-identical (the
      off-path contract of ``runtime/faults.py``).
  chaos        — the guarded deployment (SLO scheduler, output
      screening with ``retry_f32``, spare plans pre-warmed) served
      through one fault *phase per kind* — a NaN-poisoned batch, a
      corrupted collective, a kernel-launch exception, a latency
      spike, then a device loss, then a degraded soak.  Must keep
      availability >= the target, re-plan ZERO graphs cold while
      degrading 2 -> 1 devices, keep every plan at f32 (the degree
      ladder descends BEFORE the precision ladder), and bound the
      modeled p95 inflation against the fault-free run of the same
      traffic.
  baseline     — the same phases against an unguarded synchronous
      server: the NaN and collective batches are served poisoned, the
      kernel exception loses its batch, and after the device loss
      EVERY remaining batch dies on the corpse — the availability
      collapse the survival machinery exists to prevent.

Usage: python benchmarks/_chaos_child.py [soak_waves]
"""
from __future__ import annotations

import json
import sys

import jax
import numpy as np

from repro.core.plan import STATS, clear_plan_cache, replan
from repro.core.resources import MeshSpec, ResourceBudget
from repro.models.frontends import init_cnn_frontend
from repro.runtime import (AdaptiveServer, FaultSpec, GuardPolicy, INJECTOR,
                           InjectedFault, SLOScheduler, SLOSpec)

SOAK_WAVES = int(sys.argv[1]) if len(sys.argv) > 1 else 3
DEVICE = ResourceBudget(vpu_ops_budget=15_000_000)
MESH = MeshSpec(devices=2)
MAX_BATCH = 4
WAVE = 8                   # requests per phase
DEADLINE_S = 60.0          # generous: outcomes hinge on faults, not SLOs

# One phase per fault kind, each armed for its own wave of traffic
# (``step=0``: the first poll of the kind's seam in that phase fires).
# The device loss comes last so every earlier seam exercises the
# 2-device sharded path; the soak waves after it serve degraded.
PHASES = [
    ("warmup", None),
    ("nan_output", [FaultSpec("nan_output", step=0)]),
    ("collective_corrupt", [FaultSpec("collective_corrupt", step=0)]),
    ("kernel_exception", [FaultSpec("kernel_exception", step=0)]),
    ("latency_spike", [FaultSpec("latency_spike", step=0, param=4.0)]),
    ("device_loss", [FaultSpec("device_loss", step=0, param=1)]),
] + [(f"soak{i}", None) for i in range(SOAK_WAVES)]

NEVER = [FaultSpec("nan_output", step=10**9)]


def _params():
    return init_cnn_frontend(jax.random.PRNGKey(0), channels=(6, 12),
                             d_model=16)


def _traffic():
    """Deterministic Poisson-spaced single-tenant arrivals, one wave per
    phase: seeded exponential inter-arrival gaps on the est-cycles
    clock, identical across the three arms.  The gap scale is far below
    one batch's service cycles, so the continuous batcher fills batches
    to ``MAX_BATCH`` — full batches tile across the 2-device mesh, which
    is what keeps the sharded (collective) path on the serving floor."""
    rng = np.random.default_rng(0)
    n = WAVE * len(PHASES)
    xs = [rng.normal(size=(12, 12, 6)).astype(np.float32) for _ in range(n)]
    ats = np.cumsum(rng.exponential(scale=1.0, size=n))
    waves = [(xs[i * WAVE:(i + 1) * WAVE], ats[i * WAVE:(i + 1) * WAVE])
             for i in range(len(PHASES))]
    return waves


def _guarded_deployment():
    clear_plan_cache()
    srv = AdaptiveServer(DEVICE, mesh=MESH, max_batch=MAX_BATCH)
    sched = SLOScheduler(srv)
    sched.register("a", _params(), (12, 12, 6),
                   slo=SLOSpec(deadline_s=DEADLINE_S))
    srv.set_guard("a", GuardPolicy(on_nonfinite="retry_f32", max_retries=2,
                                   backoff_base_s=0.001))
    return srv, sched


def _finite(c):
    return c.ok and bool(np.isfinite(np.asarray(c.result)).all())


def _run_guarded(waves, schedule_of):
    """Serve every phase wave through a fresh guarded deployment,
    arming ``schedule_of(phase_name)`` (or nothing) around each."""
    srv, sched = _guarded_deployment()
    comps, fired = [], []
    for (name, _), (xs, ats) in zip(PHASES, waves):
        schedule = schedule_of(name)
        if schedule:
            INJECTOR.arm(schedule)
        try:
            for x, at in zip(xs, ats):
                sched.submit("a", x, at=float(at))
            comps.extend(sched.run())
            fired.extend(f[0] for f in INJECTOR.fired)
        finally:
            INJECTOR.disarm()
    return srv, sorted(comps, key=lambda c: c.rid), fired


def main() -> None:
    waves = _traffic()
    n = WAVE * len(PHASES)
    out = {"devices": len(jax.devices()), "requests": n}

    # -- transparency: disarmed vs armed-but-never-firing ----------------
    srv_off, base, _ = _run_guarded(waves, lambda name: None)
    tel_off = srv_off.telemetry()["a"]
    srv_on, armed, _ = _run_guarded(waves, lambda name: NEVER)
    tel_on = srv_on.telemetry()["a"]
    out["transparent"] = bool(
        len(base) == len(armed) == n
        and all(a.ok and a.finished == b.finished
                and bool((np.asarray(a.result)
                          == np.asarray(b.result)).all())
                for a, b in zip(base, armed))
        and tel_off["p95_cycles"] == tel_on["p95_cycles"])
    p95_healthy = tel_off["p95_cycles"]

    # -- chaos: guarded + pre-warmed spares, one fault phase per kind ----
    # (pre-warm + cold-plan accounting need hooks around the warmup
    # phase, so the loop is inlined rather than reusing _run_guarded)
    srv, sched = _guarded_deployment()
    comps, fired = [], []
    misses0 = spares = None
    for (name, schedule), (xs, ats) in zip(PHASES, waves):
        if schedule:
            INJECTOR.arm(schedule)
        try:
            for x, at in zip(xs, ats):
                sched.submit("a", x, at=float(at))
            comps.extend(sched.run())
            fired.extend(f[0] for f in INJECTOR.fired)
        finally:
            INJECTOR.disarm()
        if name == "warmup":
            # the live-deployment warm ritual: settle grants on clean
            # traffic, warm every healthy batch shape the settled grant
            # serves under, then pre-plan the post-loss spares — after
            # this point NOTHING may plan cold
            t = srv.tenants["a"]
            for b in range(1, MAX_BATCH + 1):
                specs = srv._specs(t.params, (b,) + t.input_shape,
                                   "float32", t.pool_window, t.activation,
                                   t.ladder)
                replan(specs, srv.arbiter.budget_for("a"), fuse=srv.fuse,
                       mesh=srv.arbiter.mesh_for("a"))
            spares = srv.prewarm_spares(losses=1)
            misses0 = STATS.plan_misses
    tel = srv.telemetry()["a"]
    ok = sum(1 for c in comps if _finite(c))
    out["chaos"] = {
        "submitted": n,
        "served_ok": ok,
        "availability": ok / n,
        "cold_plans": STATS.plan_misses - misses0,
        "spares_prewarmed": spares,
        "faults_fired": sorted(fired),
        "devices_after": srv.mesh.devices,
        "degradations": tel["degradations"],
        "guard_retries": tel["guard_retries"],
        "shard_degree_mix": {str(k): v
                             for k, v in tel["shard_degree_mix"].items()},
        "precision_mix": {str(k): v
                          for k, v in tel["precision_mix"].items()},
        "p95_cycles_healthy": p95_healthy,
        "p95_cycles_chaos": tel["p95_cycles"],
        "deadline_miss_rate": tel["deadline_miss_rate"],
    }

    # -- baseline: the same phases, no guards, no degradation ------------
    clear_plan_cache()
    srv = AdaptiveServer(DEVICE, mesh=MESH, max_batch=MAX_BATCH)
    srv.register("a", _params(), (12, 12, 6))
    served, lost_batches = [], 0
    corpse_persists = False
    try:
        for (name, schedule), (xs, ats) in zip(PHASES, waves):
            if schedule and not corpse_persists:
                INJECTOR.arm(schedule)
                # a lost device stays lost: with nobody degrading the
                # mesh, the corpse outlives its phase and every later
                # batch's device slice still overlaps it
                corpse_persists = name == "device_loss"
            for x, at in zip(xs, ats):
                srv.submit("a", x, at=float(at))
            while srv.pending():
                try:
                    served.extend(srv.step())
                except InjectedFault:
                    # the whole batch died; its requests are simply gone
                    lost_batches += 1
            if not corpse_persists:
                INJECTOR.disarm()
    finally:
        INJECTOR.disarm()
    ok = sum(1 for c in served if _finite(c))
    out["baseline"] = {
        "submitted": n,
        "served_ok": ok,
        "availability": ok / n,
        "lost_batches": lost_batches,
    }
    print(json.dumps(out))


if __name__ == "__main__":
    main()
