"""Benchmark harness — one function per paper table + kernel/system benches.

Paper tables (the reproduction targets):
  table1_ip_characteristics  — Table I: capability matrix of the IP library
  table2_resource_utilization — Table II: measured per-IP resource usage
      (FPGA LUT/Reg/CLB/DSP/WNS/Power -> TPU vpu-ops/vmem/mxu-passes/
       est-cycles/us-per-call, from footprints + interpret-mode timing)
  table3_comparison          — Table III: adaptive selection vs fixed-IP
      baselines across resource budgets (the paper's adaptability claim,
      made quantitative)
  table_precision            — the precision ladder: f32-only vs
      ladder-planned networks across the budget ladder (planned cycles,
      measured wall time, and per-site quantization error)
  table_serving              — the serving runtime: static even budget
      split vs demand-arbitrated split across a load ladder (overall
      p95 latency in est-cycles, squeezed-tenant precision mix +
      measured quant error)
  table_calibration          — the measurement-calibrated cost model:
      warmup per-site samples -> affine fits -> the calibrated planner's
      fused-vs-unfused choice must match measured wall-clock on every
      fusion-ladder budget (asserted)
  table_mesh                 — mesh-sharded planning: the 2-device
      planned split must beat the best 1-device plan (modeled AND
      measured), and the planner must refuse to shard when collective
      cost outweighs the split (refusal measured via the forced-shard
      counterfactual); runs under a forced 2-device host mesh
  table_obs                  — cross-layer observability: plan audits
      must name concrete rejection reasons, a traced serving cycle must
      export valid Chrome trace JSON (plan/kernel/arbiter spans) within
      a bounded overhead of the untraced run, and the calibration drift
      monitor must trip on a mis-scaled table while staying quiet on
      the honest fit (recalibration re-arms it)
  table_slo              — the SLO scheduler vs the synchronous round
      loop on shared Poisson traces (async must strictly beat sync on
      p95 wall latency AND deadline-miss rate on every mix), plus the
      plan-preserving kill/recover scenario (snapshot -> simulated
      death -> restore must re-plan ZERO cold graphs)
  table_chaos            — fault injection + degraded-mesh survival:
      guarded serving must hold >=99% availability through a NaN
      batch, a corrupted collective, a kernel exception, a latency
      spike, and a device loss — degrading 2 -> 1 devices with ZERO
      cold re-plans (spares pre-warmed) and bounded p95 inflation —
      while the unguarded baseline collapses on the same schedule;
      armed-but-idle injection must be bit-transparent

System benches:
  bench_kernels     — us/call for every kernel family member
  bench_train_step  — smoke-model train-step wall time
  bench_roofline    — reads experiments/dryrun JSONs -> per-cell terms

Output: ``name,us_per_call,derived`` CSV rows on stdout.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

ROWS = []

# Wall-clock repetitions per measurement (the --repeat flag); every
# timed table reports the MEDIAN of this many post-warmup runs, so a
# single scheduler hiccup cannot skew a row.
REPEAT = 3


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


def _timeit(fn, *args, warmup=1, iters=None) -> float:
    """us/call: ``warmup`` discarded calls, then the median of
    ``iters`` (default: the --repeat setting) timed calls."""
    iters = REPEAT if iters is None else iters
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6


# ---------------------------------------------------------------------------
# Table I — characteristics of the developed IPs (capability matrix)
# ---------------------------------------------------------------------------
def table1_ip_characteristics():
    from repro.core.library import FAMILIES
    print("# Table I — IP library characteristics "
          "(DSP->mxu, logic->vpu, ops/pass, operand ceiling)")
    for fam in FAMILIES.values():
        for ip in fam:
            derived = (f"uses_mxu={int(ip.uses_mxu)};outputs_per_pass="
                       f"{ip.outputs_per_pass};max_bits={ip.max_operand_bits};"
                       f"tags={'|'.join(ip.tags)}")
            emit(f"table1.{ip.name}", 0.0, derived)


# ---------------------------------------------------------------------------
# Table II — resource utilization of the conv IPs (paper's experiment:
# 8-bit fixed point, 3x3 kernel; ZCU104@200MHz -> v5e resource vector)
# ---------------------------------------------------------------------------
def table2_resource_utilization():
    from repro.core.library import CONV2D
    from repro.kernels.conv2d.ops import conv2d, conv2d_dual
    print("# Table II — conv IP resource utilization (paper setup: int8, "
          "3x3 kernel) — vmem/mxu/vpu from footprints, us/call measured "
          "(interpret mode, CPU)")
    rng = np.random.default_rng(0)
    n, h, w, cin, cout = 1, 32, 32, 8, 16
    xa = jnp.asarray(rng.integers(-128, 128, (n, h, w, cin), dtype=np.int8))
    xb = jnp.asarray(rng.integers(-128, 128, (n, h, w, cin), dtype=np.int8))
    wgt = jnp.asarray(rng.integers(-128, 128, (3, 3, cin, cout),
                                   dtype=np.int8))
    for ip in CONV2D:
        fp = ip.footprint(n, h, w, cin, 3, 3, cout, itemsize=1)
        short = ip.name.split(".")[-1]
        if ip.outputs_per_pass == 2:
            us = _timeit(lambda: conv2d_dual(xa, xb, wgt, ip=short))
        else:
            us = _timeit(lambda: conv2d(xa, wgt, ip=short))
        derived = (f"vmem_kib={fp.vmem_bytes/1024:.1f};mxu_passes="
                   f"{fp.mxu_passes};vpu_ops={fp.vpu_ops:.2e};"
                   f"est_cycles={fp.est_cycles:.3e};"
                   f"outputs_per_pass={fp.outputs_per_pass}")
        emit(f"table2.{ip.name}", us, derived)


# ---------------------------------------------------------------------------
# Table III — the PLANNED network vs fixed-IP networks across a budget
# ladder: a 3-layer int8 CNN (conv -> avgpool -> act per layer) is mapped
# by plan_network (one partitioned budget for all 9 sites); each fixed
# baseline runs the same graph with one member per family and is priced
# GENEROUSLY (every site sees the full budget, no partitioning).
# ---------------------------------------------------------------------------
TABLE3_LAYERS = [(8, 16), (16, 32), (32, 32)]   # (cin, cout), 3x3 convs

TABLE3_BASELINES = {
    "fixed_vpu": {"conv2d": "ip1_vpu", "pool2d": "pool_vpu",
                  "activation": "act_vpu"},
    "fixed_mxu": {"conv2d": "ip2_mxu", "pool2d": "pool_im2col",
                  "activation": "act_vpu"},
}


def table3_network_specs(n=2, hw=32):
    # Per-layer sites from the same oracle-derived helper the models use
    # (shapes/dtypes can't drift from what the kernels produce); operands
    # re-enter as int8 each layer (requantized fixed-point network).
    from repro.models.blocks import cnn_block_site_specs
    specs = []
    shape = (n, hw, hw, TABLE3_LAYERS[0][0])
    for li, (cin, cout) in enumerate(TABLE3_LAYERS):
        layer, out = cnn_block_site_specs(
            shape, (3, 3, cin, cout), x_dtype="int8", pool_mode="avg",
            activation="relu6", site=f"layer{li}")
        specs += layer
        shape = out.shape
    return specs


def table3_comparison():
    from repro.core.plan import fixed_network_cost, plan_network
    from repro.core.resources import ResourceBudget
    print("# Table III — resource adaptability, network-level: total est "
          "cycles of the planned network (partitioned budget) vs each "
          "fixed-IP network (full budget per site); x=infeasible")
    budgets = {
        "ample": ResourceBudget(),
        "no_mxu": ResourceBudget(mxu_available=False),
        "vpu_starved": ResourceBudget(vpu_ops_budget=2_000_000),
        "vmem_tight": ResourceBudget(vmem_bytes=2 * 2**20),
        "mxu_modest_vpu_tight": ResourceBudget(vpu_ops_budget=2_000_000,
                                               mxu_passes_budget=12),
    }
    specs = table3_network_specs()
    for bname, budget in budgets.items():
        try:
            # fuse=False: Table III reproduces the paper's per-op
            # selection; the fused-vs-unfused comparison is table_fusion
            plan = plan_network(specs, budget, fuse=False)
            planned = plan.total_cycles
            assign = "|".join(
                f"{s.spec.name.split('.')[0]}.{s.spec.family}:"
                f"{s.ip.name.split('.')[-1]}"
                for s in plan.sites if s.spec.name.startswith("layer0"))
        except ValueError:
            planned, assign = None, "none"
        fixed = {name: fixed_network_cost(specs, members, budget)
                 for name, members in TABLE3_BASELINES.items()}
        beats_all = planned is not None and all(
            v is None or planned < v for v in fixed.values())
        derived = (f"planned={planned:.3e}" if planned is not None
                   else "planned=x")
        for name, v in fixed.items():
            derived += f";{name}={v:.3e}" if v is not None else f";{name}=x"
        derived += (f";planned_best={int(beats_all)};layer0={assign}")
        emit(f"table3.budget_{bname}", 0.0, derived)


# ---------------------------------------------------------------------------
# Table P — the precision ladder, network-level: the same float32 CNN is
# planned twice per budget — once at f32 only, once with a (16, 8) ladder
# on every site — and the ladder plan is EXECUTED end-to-end so every
# lowered site reports its measured error against the family oracles.
# ---------------------------------------------------------------------------
PRECISION_LADDER = (16, 8)


def precision_network_specs(ladder=(), n=2, hw=32):
    from repro.models.blocks import cnn_block_site_specs
    specs = []
    shape = (n, hw, hw, TABLE3_LAYERS[0][0])
    for li, (cin, cout) in enumerate(TABLE3_LAYERS):
        layer, out = cnn_block_site_specs(
            shape, (3, 3, cin, cout), x_dtype="float32", pool_mode="max",
            activation="relu", site=f"layer{li}", ladder=ladder)
        specs += layer
        shape = out.shape
    return specs


def _run_precision_network(weights, x, network, ladder):
    from repro.models.blocks import apply_cnn_block
    report = {}
    y = x
    for li, w in enumerate(weights):
        y = apply_cnn_block({"w": w}, y, pool_mode="max", activation="relu",
                            site=f"layer{li}", network=network,
                            ladder=ladder, quant_report=report)
    return y, report


def table_precision():
    from repro.core.plan import plan_network
    from repro.core.resources import ResourceBudget
    from repro.quant.report import max_rel_error
    print("# Table P — precision ladder: f32-only vs ladder-planned "
          "network per budget; cycles planned, us measured (interpret "
          "mode), err = max per-site rel error of the executed ladder "
          "plan vs the f32 oracles; x=infeasible")
    budgets = {
        # ladder never engages; plans identical
        "ample": ResourceBudget(),
        # partitioned slices push sites down the ladder; the lowered
        # plan is strictly CHEAPER (narrower operands = less traffic)
        # while f32-only still fits
        "vmem_600KiB": ResourceBudget(vmem_bytes=600 * 1024),
        # f32-only is infeasible; only the ladder plan exists
        "vmem_280KiB": ResourceBudget(vmem_bytes=280 * 1024),
        # below every rung: both plans infeasible (honest envelope end)
        "vmem_160KiB": ResourceBudget(vmem_bytes=160 * 1024),
    }
    rng = np.random.default_rng(0)
    weights = [jnp.asarray(rng.normal(0, (3 * 3 * cin) ** -0.5,
                                      (3, 3, cin, cout)).astype(np.float32))
               for cin, cout in TABLE3_LAYERS]
    x = jnp.asarray(rng.normal(size=(2, 32, 32, 8)).astype(np.float32))
    specs_f32 = precision_network_specs()
    specs_lad = precision_network_specs(PRECISION_LADDER)
    for bname, budget in budgets.items():
        # fuse=False keeps this the pure precision-ladder comparison
        # (and the committed trajectory comparable); fusion x ladder
        # interplay is table_fusion's job
        try:
            f32_cycles = plan_network(specs_f32, budget,
                                      fuse=False).total_cycles
        except ValueError:
            f32_cycles = None
        try:
            lad_plan = plan_network(specs_lad, budget, fuse=False)
        except ValueError:
            lad_plan = None
        if lad_plan is None:
            emit(f"table_precision.budget_{bname}", 0.0,
                 ("f32=x;" if f32_cycles is None
                  else f"f32={f32_cycles:.3e};") + "ladder=x")
            continue
        us = _timeit(lambda: _run_precision_network(
            weights, x, lad_plan, PRECISION_LADDER)[0])
        _, report = _run_precision_network(weights, x, lad_plan,
                                           PRECISION_LADDER)
        lowered = lad_plan.lowered_sites()
        bits = "|".join(f"{s.spec.name}:{s.precision_bits}"
                        for s in lowered) or "none"
        err = max_rel_error(report)
        wins = f32_cycles is None or lad_plan.total_cycles < f32_cycles
        derived = (("f32=x" if f32_cycles is None
                    else f"f32={f32_cycles:.3e}")
                   + f";ladder={lad_plan.total_cycles:.3e}"
                   + f";lowered={len(lowered)};bits={bits}"
                   + f";max_rel_err={err:.3e}"
                   + f";err_ok={int(err <= 5e-2)}"
                   + f";ladder_wins={int(wins)}")
        emit(f"table_precision.budget_{bname}", us, derived)


# ---------------------------------------------------------------------------
# Table F — fused CNN blocks vs the unfused three-launch chain: the same
# ladder-equipped float32 CNN is planned twice per budget (plan_network
# with and without fuse=True) and BOTH plans are executed end-to-end, so
# each row reports planned est-cycles (where the counted DMA-byte saving
# lands), launch count (3 -> 1 per fused block), measured wall-clock
# (interpret-mode median of --repeat runs), and the fused sites'
# measured error against the composite f32 oracle.
# ---------------------------------------------------------------------------
def table_fusion():
    from repro.core.plan import clear_plan_cache, plan_network
    from repro.core.resources import ResourceBudget
    from repro.quant.report import max_rel_error
    print("# Table F — fusion: fused conv->pool->act blocks vs the "
          "unfused three-launch chain per budget; cycles planned, "
          "launches counted, us measured (interpret mode, median of "
          f"{REPEAT}), err = max per-site rel error of the executed "
          "fused plan vs the f32 oracles; x=infeasible")
    budgets = {
        "ample": ResourceBudget(),
        "no_mxu": ResourceBudget(mxu_available=False),
        "vmem_600KiB": ResourceBudget(vmem_bytes=600 * 1024),
        "vmem_420KiB": ResourceBudget(vmem_bytes=420 * 1024),
        # tight enough that a fused site descends to the int8 rung (the
        # in-register-rescale path) and must stay within the error bound
        "vmem_240KiB": ResourceBudget(vmem_bytes=240 * 1024),
        "vpu_starved": ResourceBudget(vpu_ops_budget=2_000_000),
    }
    rng = np.random.default_rng(0)
    weights = [jnp.asarray(rng.normal(0, (3 * 3 * cin) ** -0.5,
                                      (3, 3, cin, cout)).astype(np.float32))
               for cin, cout in TABLE3_LAYERS]
    x = jnp.asarray(rng.normal(size=(2, 32, 32, 8)).astype(np.float32))
    specs = precision_network_specs(PRECISION_LADDER)
    for bname, budget in budgets.items():
        clear_plan_cache()
        plans = {}
        for arm, fuse in (("unfused", False), ("fused", True)):
            try:
                plans[arm] = plan_network(specs, budget, fuse=fuse)
            except ValueError:
                plans[arm] = None
        unf, fus = plans["unfused"], plans["fused"]
        if fus is None:
            emit(f"table_fusion.budget_{bname}", 0.0,
                 ("unfused=x;" if unf is None
                  else f"unfused={unf.total_cycles:.3e};") + "fused=x")
            continue
        us_fused = _timeit(lambda: _run_precision_network(
            weights, x, fus, PRECISION_LADDER)[0])
        _, report = _run_precision_network(weights, x, fus,
                                           PRECISION_LADDER)
        us_unfused = (None if unf is None else _timeit(
            lambda: _run_precision_network(weights, x, unf,
                                           PRECISION_LADDER)[0]))
        fused_sites = [s for s in fus.sites
                       if s.spec.family == "cnn_fused"]
        err = max_rel_error(report, lowered_only=False)
        # Modeled and measured verdicts are SEPARATE columns: the old
        # fused_wins/never_worse flags were derived from est-cycles only,
        # so the bench could self-certify a "win" while wall-clock said
        # otherwise (the calibration layer exists because they disagree —
        # see table_calibration).
        modeled = unf is None or fus.total_cycles < unf.total_cycles
        measured = us_unfused is None or us_fused < us_unfused
        bits = "|".join(f"{s.spec.name}:{s.precision_bits}"
                        for s in fused_sites) or "none"
        derived = (("unfused=x" if unf is None
                    else f"unfused={unf.total_cycles:.3e}")
                   + f";fused={fus.total_cycles:.3e}"
                   + (";launches_unfused=x" if unf is None
                      else f";launches_unfused={unf.total_launches}")
                   + f";launches_fused={fus.total_launches}"
                   + f";fused_sites={len(fused_sites)};bits={bits}"
                   + (";us_unfused=x" if us_unfused is None
                      else f";us_unfused={us_unfused:.1f}")
                   + f";us_fused={us_fused:.1f}"
                   + f";max_rel_err={err:.3e}"
                   + f";err_ok={int(err <= 5e-2)}"
                   + f";modeled_wins={int(modeled)}"
                   + f";measured_wins={int(measured)}")
        emit(f"table_fusion.budget_{bname}", us_fused, derived)


# ---------------------------------------------------------------------------
# Table C — the measurement-calibrated cost model closing the loop that
# Table F exposed: fused plans were MODELED cheaper on every budget while
# MEASURED slower on some.  A warmup pass measures every distinct planned
# site standalone (core.calibrate_cost.collect_plan_samples), an affine
# model is fit per executed member, and the planner re-decides fusion
# under calibration=: the calibrated fused-vs-unfused ranking must match
# measured wall-clock on EVERY budget of the fusion ladder, and any
# budget whose stopwatch prefers unfused must now PLAN unfused (both
# asserted; which budgets those are is a property of the host — on the
# seed-trajectory host, vpu_starved and no_mxu measured fused slower).
# ---------------------------------------------------------------------------
def table_calibration(smoke: bool = False):
    from repro.core.calibrate_cost import (CalibrationTable,
                                           collect_plan_samples)
    from repro.core.plan import clear_plan_cache, plan_network
    from repro.core.resources import ResourceBudget
    print("# Table C — calibrated cost model: per-site warmup samples -> "
          "affine fits -> the planner's fused-vs-unfused choice must "
          "match measured wall-clock on every fusion-ladder budget "
          "(interpret mode, median of runs); x=infeasible")
    budgets = {
        "ample": ResourceBudget(),
        "no_mxu": ResourceBudget(mxu_available=False),
        "vmem_600KiB": ResourceBudget(vmem_bytes=600 * 1024),
        "vmem_420KiB": ResourceBudget(vmem_bytes=420 * 1024),
        "vmem_240KiB": ResourceBudget(vmem_bytes=240 * 1024),
        "vpu_starved": ResourceBudget(vpu_ops_budget=2_000_000),
    }
    rng = np.random.default_rng(0)
    weights = [jnp.asarray(rng.normal(0, (3 * 3 * cin) ** -0.5,
                                      (3, 3, cin, cout)).astype(np.float32))
               for cin, cout in TABLE3_LAYERS]
    x = jnp.asarray(rng.normal(size=(2, 32, 32, 8)).astype(np.float32))
    specs = precision_network_specs(PRECISION_LADDER)
    repeat = 2 if smoke else REPEAT
    # Phase 1 — warmup sampling: plan both arms of every budget with the
    # ANALYTICAL model and measure each distinct planned site standalone.
    # Three layer shapes per member give each fit >= 3 footprint points.
    clear_plan_cache()
    arm_plans = {}
    for bname, budget in budgets.items():
        plans = {}
        for arm, fuse in (("unfused", False), ("fused", True)):
            try:
                plans[arm] = plan_network(specs, budget, fuse=fuse)
            except ValueError:
                plans[arm] = None
        arm_plans[bname] = plans
    table = collect_plan_samples(
        [p for plans in arm_plans.values() for p in plans.values()],
        repeat=repeat).fit()
    # Acceptance: the table must round-trip through JSON bit-exactly.
    assert CalibrationTable.from_json(table.to_json()).to_json() \
        == table.to_json(), "CalibrationTable JSON round-trip not bit-exact"
    emit("table_calibration.table", 0.0,
         f"samples={table.sample_count()};members_fit={len(table.fits)};"
         f"fingerprint={table.fingerprint()}")
    # Phase 2 — per budget: measure both arms end-to-end, then ask the
    # CALIBRATED planner; its ranking must agree with the stopwatch.
    mismatches = []
    for bname, budget in budgets.items():
        unf, fus = arm_plans[bname]["unfused"], arm_plans[bname]["fused"]
        if unf is None or fus is None:
            emit(f"table_calibration.budget_{bname}", 0.0,
                 ("unfused=x;" if unf is None else "") +
                 ("fused=x" if fus is None else ""))
            continue
        us_unfused = _timeit(lambda: _run_precision_network(
            weights, x, unf, PRECISION_LADDER)[0], iters=repeat)
        us_fused = _timeit(lambda: _run_precision_network(
            weights, x, fus, PRECISION_LADDER)[0], iters=repeat)
        cal_unf = unf.calibrated_cycles(table)
        cal_fus = fus.calibrated_cycles(table)
        cal_plan = plan_network(specs, budget, fuse=True, calibration=table)
        plans_fused = sum(1 for s in cal_plan.sites
                          if s.spec.family == "cnn_fused")
        modeled_pref = fus.total_cycles < unf.total_cycles
        calibrated_pref = cal_fus < cal_unf
        measured_pref = us_fused < us_unfused
        match = calibrated_pref == measured_pref
        if not match:
            mismatches.append(bname)
        derived = (f"us_unfused={us_unfused:.1f};us_fused={us_fused:.1f}"
                   f";cal_unfused={cal_unf:.3e};cal_fused={cal_fus:.3e}"
                   f";modeled_prefers_fused={int(modeled_pref)}"
                   f";calibrated_prefers_fused={int(calibrated_pref)}"
                   f";measured_prefers_fused={int(measured_pref)}"
                   f";plans_fused_sites={plans_fused}"
                   f";ranking_match={int(match)}")
        emit(f"table_calibration.budget_{bname}", us_fused, derived)
        # The flip the calibration layer exists for: wherever the
        # stopwatch prefers the unfused chain (e.g. vpu_starved on the
        # host that produced the seed BENCH_table_fusion.json), the
        # calibrated planner must actually plan it unfused — the
        # analytical model fused everywhere regardless.
        if not measured_pref:
            assert plans_fused == 0, (
                f"budget_{bname}: measured wall-clock prefers unfused "
                f"but the calibrated planner kept {plans_fused} fused "
                f"sites")
    assert not mismatches, (
        f"calibrated fused-vs-unfused ranking disagrees with measured "
        f"wall-clock on: {mismatches}")


# ---------------------------------------------------------------------------
# Table S — the serving runtime: one constrained device, two tenants,
# skewed load.  The same request trace is replayed against a static even
# budget split and the demand arbiter; the arbiter must buy the heavy
# tenant the fast (VPU-hungry) conv member while the squeezed light
# tenant degrades down the precision ladder instead of failing.  The
# device is constrained on BOTH axes: vpu_ops drives the member choice,
# and vmem forces the squeezed tenant's fused block (serving plans fuse
# by default) below f32 — the per-op tanh squeeze the table originally
# used no longer bites once conv+pool+act share one VMEM-resident tile.
# Latency is est-cycles — the planner's own cost model — so policies
# compare without interpret-mode wall-clock noise.
# ---------------------------------------------------------------------------
SERVING_DEVICE_VPU_OPS = 15_000_000
SERVING_DEVICE_VMEM = 2 * 2**20
SERVING_WAVES = 3


def _serving_tenants():
    import jax
    from repro.models.frontends import init_cnn_frontend
    heavy = init_cnn_frontend(jax.random.PRNGKey(0), channels=(8, 16),
                              d_model=32)
    light = init_cnn_frontend(jax.random.PRNGKey(1), channels=(6, 12),
                              d_model=16)
    return heavy, light


def _run_serving(policy: str, n_heavy: int, n_light: int, *,
                 waves: int = SERVING_WAVES):
    """Replay one skewed trace under one policy; fresh caches so each
    policy models an independent serving process."""
    from repro.core.plan import clear_plan_cache
    from repro.core.resources import ResourceBudget
    from repro.runtime import AdaptiveServer

    clear_plan_cache()
    device = ResourceBudget(vpu_ops_budget=SERVING_DEVICE_VPU_OPS,
                            vmem_bytes=SERVING_DEVICE_VMEM)
    heavy_p, light_p = _serving_tenants()
    srv = AdaptiveServer(device, policy=policy, max_batch=4)
    srv.register("vision-heavy", heavy_p, (32, 32, 8))
    # the squeeze target: the light tenant's ~7% vmem slice cannot hold
    # its fused blocks at f32, so the ladder lowers them
    srv.register("edge-light", light_p, (24, 24, 6), activation="tanh",
                 ladder=(16, 8), measure_quant=True)
    rng = np.random.default_rng(0)
    latencies = []
    t = 0.0
    for _ in range(waves):
        for _ in range(n_heavy):
            srv.submit("vision-heavy",
                       rng.normal(size=(32, 32, 8)).astype(np.float32), at=t)
        for _ in range(n_light):
            srv.submit("edge-light",
                       rng.normal(size=(24, 24, 6)).astype(np.float32), at=t)
        latencies += [c.latency for c in srv.step()]
        t = srv.clock
    return float(np.percentile(latencies, 95)), srv.telemetry()


def table_serving(smoke: bool = False):
    print("# Table S — serving: static even split vs demand-arbitrated "
          "budgets on one constrained device (vpu_ops_budget="
          f"{SERVING_DEVICE_VPU_OPS}, vmem={SERVING_DEVICE_VMEM >> 20}"
          "MiB); p95 in est-cycles; the "
          "squeezed tenant must serve at a lowered rung within the 5e-2 "
          "error bound")
    mixes = {"skew_10to2": (10, 2)}
    if not smoke:
        mixes = {"skew_4to2": (4, 2), **mixes, "skew_16to2": (16, 2)}
    for mname, (nh, nl) in mixes.items():
        per_policy = {}
        for policy in ("static", "demand"):
            per_policy[policy] = _run_serving(policy, nh, nl)
        static_p95, _ = per_policy["static"]
        arb_p95, arb_tel = per_policy["demand"]
        light = arb_tel["edge-light"]
        heavy = arb_tel["vision-heavy"]
        lowered_bits = sorted(b for b in light["precision_mix"] if b < 32)
        err = light["max_quant_rel_err"]
        derived = (f"static_p95={static_p95:.3e};arb_p95={arb_p95:.3e}"
                   f";arb_beats_static={int(arb_p95 < static_p95)}"
                   f";heavy_grant={heavy['granted_fraction']:.3f}"
                   f";light_grant={light['granted_fraction']:.3f}"
                   f";squeezed=edge-light"
                   f";lowered_bits={'|'.join(map(str, lowered_bits)) or 'none'}"
                   f";lowered_frac={light['lowered_fraction']:.2f}"
                   f";max_rel_err={err:.3e};err_ok={int(err <= 5e-2)}"
                   f";occupancy={heavy['batch_occupancy']:.2f}"
                   f";cache_hit_rate={heavy['plan_cache_hit_rate']:.2f}")
        emit(f"table_serving.{mname}", 0.0, derived)


# ---------------------------------------------------------------------------
# Table M — mesh-sharded planning: the collective-priced partitioner must
# (a) WIN where splitting pays: a conv whose single-device plan is gated
#     onto the slow member (mxu_passes_budget=7 forces ip1_vpu); the
#     2-device batch split halves the per-device footprint, the planner
#     flips to ip2_mxu, and the sharded execution must beat the best
#     1-device plan in BOTH modeled est-cycles and measured wall-clock;
# (b) REFUSE where it doesn't: a tiny 1x1 conv whose collectives dwarf
#     its compute must plan at degree=1, and the forced-shard
#     counterfactual must MEASURE slower — the refusal asserted from the
#     stopwatch, not just the model.
# Runs in a subprocess under XLA_FLAGS=--xla_force_host_platform_
# device_count=2 (JAX fixes its device count at import); see
# benchmarks/_mesh_child.py for the workloads.
# ---------------------------------------------------------------------------
def table_mesh(smoke: bool = False):
    import os
    import subprocess
    import sys
    print("# Table M — mesh sharding: 2-device planned split vs best "
          "1-device plan (win case) and degree=1 refusal vs forced "
          "shard (refusal case); modeled cycles AND measured us, both "
          "asserted; host mesh via forced device count")
    child = Path(__file__).resolve().parent / "_mesh_child.py"
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    repeat = 2 if smoke else REPEAT
    proc = subprocess.run(
        [sys.executable, str(child), str(repeat)], env=env,
        capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(f"mesh child failed:\n{proc.stderr[-4000:]}")
    rec = json.loads(proc.stdout.splitlines()[-1])
    assert rec["devices"] == 2, \
        f"forced host mesh did not take: {rec['devices']} device(s)"
    win, ref = rec["win"], rec["refusal"]
    # (a) the split must be chosen, modeled cheaper, measured faster,
    # and numerically exact (batch sharding is bit-identical for f32)
    assert win["shard_degree"] == 2 and win["shard_axis"] == "batch", \
        f"planner did not shard the win case: {win}"
    assert win["est_2dev"] < win["est_1dev"], \
        f"modeled: sharded plan not cheaper: {win}"
    assert win["us_2dev"] < win["us_1dev"], \
        f"measured: sharded plan not faster: {win}"
    assert win["bit_identical"], "sharded result != replicated result"
    emit("table_mesh.split_wins", win["us_2dev"],
         f"ip_1dev={win['ip_1dev'].split('.')[-1]}"
         f";ip_2dev={win['ip_2dev'].split('.')[-1]}"
         f";axis={win['shard_axis']}x{win['shard_degree']}"
         f";est_1dev={win['est_1dev']:.3e};est_2dev={win['est_2dev']:.3e}"
         f";comm={win['comm_2dev']:.3e}"
         f";us_1dev={win['us_1dev']:.1f};us_2dev={win['us_2dev']:.1f}"
         f";modeled_wins=1;measured_wins=1;bit_identical=1")
    # (b) the refusal must hold in the model AND in the stopwatch
    assert ref["shard_degree"] == 1, \
        f"planner sharded the refusal case: {ref}"
    assert ref["comm_forced"] > ref["est_chosen"], \
        f"refusal case does not stress collectives: {ref}"
    assert ref["us_forced"] > ref["us_chosen"], \
        f"measured: forced shard was not slower: {ref}"
    emit("table_mesh.refuses", ref["us_chosen"],
         f"degree=1;est_chosen={ref['est_chosen']:.3e}"
         f";comm_forced={ref['comm_forced']:.3e}"
         f";us_chosen={ref['us_chosen']:.1f}"
         f";us_forced={ref['us_forced']:.1f}"
         f";refusal_right=1")


# ---------------------------------------------------------------------------
# Table O — cross-layer observability (src/repro/obs): four asserted
# phases.
# (a) AUDIT: every site whose constrained-budget choice moved off the
#     ample-budget first choice must carry a concrete, numbered
#     rejection reason in the plan audit (NetworkPlan.explain());
# (b) TRACE: a traced serving cycle must export valid Chrome
#     trace-event JSON containing plan, kernel, and arbiter spans
#     (written to experiments/obs/trace.json — load it in Perfetto);
# (c) OVERHEAD: the same serving trace with the tracer on must stay
#     within a bounded factor of the tracer-off run (the disabled path
#     is allocation-free; the enabled path is one dict per span);
# (d) DRIFT: a calibration table fit on honest measurements must stay
#     quiet under the drift monitor while the same measurements against
#     a mis-scaled copy of the table must trip it — and recalibrate()
#     must refit the bad table (new fingerprint) back to quiet.
# Also writes the Prometheus exposition of the traced serving process
# to experiments/obs/metrics.prom.
# ---------------------------------------------------------------------------
OBS_DRIFT_SCALE = 8.0          # the mis-scaled table's coefficient factor
OBS_OVERHEAD_BOUND = 2.0       # tracer-on / tracer-off wall-clock ceiling


def _obs_serving_cycle(n_heavy=4, n_light=2):
    """One small serving trace (fresh caches, demand policy); returns
    (server, wall-clock seconds)."""
    from repro.core.plan import clear_plan_cache
    from repro.core.resources import ResourceBudget
    from repro.runtime import AdaptiveServer

    clear_plan_cache()
    device = ResourceBudget(vpu_ops_budget=SERVING_DEVICE_VPU_OPS,
                            vmem_bytes=SERVING_DEVICE_VMEM)
    heavy_p, light_p = _serving_tenants()
    srv = AdaptiveServer(device, policy="demand", max_batch=4)
    srv.register("vision-heavy", heavy_p, (32, 32, 8))
    srv.register("edge-light", light_p, (24, 24, 6), activation="tanh",
                 ladder=(16, 8))
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(2):
        for _ in range(n_heavy):
            srv.submit("vision-heavy",
                       rng.normal(size=(32, 32, 8)).astype(np.float32))
        for _ in range(n_light):
            srv.submit("edge-light",
                       rng.normal(size=(24, 24, 6)).astype(np.float32))
        srv.step()
    return srv, time.perf_counter() - t0


def table_obs(smoke: bool = False):
    from repro.core.calibrate_cost import (collect_plan_samples,
                                           measure_planned_site,
                                           member_key)
    from repro.core.plan import clear_plan_cache, plan_network
    from repro.core.resources import ResourceBudget
    from repro.obs import TRACER, DriftMonitor, mis_scaled_table
    print("# Table O — observability: plan audits name concrete "
          "rejection reasons; a traced serving cycle exports valid "
          "Chrome trace JSON with plan/kernel/arbiter spans within "
          f"{OBS_OVERHEAD_BOUND}x of the untraced run; the drift "
          "monitor stays quiet on the honest calibration table and "
          f"trips on a {OBS_DRIFT_SCALE}x mis-scaled copy, and "
          "recalibrate() refits it quiet")
    out_dir = Path(__file__).resolve().parent.parent / "experiments" / "obs"
    out_dir.mkdir(parents=True, exist_ok=True)
    repeat = 2 if smoke else REPEAT

    # -- (a) plan decision audit -------------------------------------------
    clear_plan_cache()
    specs = precision_network_specs(PRECISION_LADDER)
    ample = plan_network(specs, ResourceBudget())
    first_choice = {s.spec.name: (s.ip.name, s.precision_bits)
                    for s in ample.sites}
    budgets = {
        "vmem_600KiB": ResourceBudget(vmem_bytes=600 * 1024),
        "vpu_starved": ResourceBudget(vpu_ops_budget=2_000_000),
        "no_mxu": ResourceBudget(mxu_available=False),
    }
    non_first, explained = 0, 0
    for bname, budget in budgets.items():
        plan = plan_network(specs, budget)
        assert plan.audit is not None, f"{bname}: cold plan has no audit"
        for site in plan.sites:
            choice = (site.ip.name, site.precision_bits)
            was_first = first_choice.get(site.spec.name) == choice
            lowered = site.precision_bits < site.spec.native_bits
            if was_first and not lowered:
                continue
            non_first += 1
            reasons = plan.audit.site(site.spec.name).rejection_reasons()
            assert reasons and any(c.isdigit()
                                   for r in reasons for c in r), (
                f"{bname}/{site.spec.name}: moved off the first choice "
                f"{first_choice.get(site.spec.name)} -> {choice} with no "
                f"concrete rejection reason; explain():\n{plan.explain()}")
            explained += 1
    assert non_first > 0, "constrained budgets moved no site choices"
    emit("table_obs.audit", 0.0,
         f"non_first_choice={non_first};explained={explained};"
         f"audit_ok={int(non_first == explained)}")

    # -- (b) + (c) traced serving cycle, then the overhead bound -----------
    _, base_s = _obs_serving_cycle()          # warm compile, tracer off
    _, off_s = _obs_serving_cycle()
    TRACER.clear()
    TRACER.enable()
    try:
        srv, on_s = _obs_serving_cycle()
        metrics_text = srv.metrics().render()
    finally:
        TRACER.disable()
    doc = json.loads(TRACER.export_chrome_trace())
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    for ev in doc["traceEvents"]:
        assert ev["ph"] in ("X", "i") and ev["name"] and "ts" in ev
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0
    cats = {e["cat"] for e in doc["traceEvents"]}
    missing = {"plan", "kernel", "arbiter"} - cats
    assert not missing, f"trace is missing span categories: {missing}"
    (out_dir / "trace.json").write_text(
        TRACER.export_chrome_trace(indent=None))
    (out_dir / "metrics.prom").write_text(metrics_text)
    spans = sum(1 for e in doc["traceEvents"] if e["ph"] == "X")
    ratio = on_s / max(off_s, 1e-9)
    overhead_ok = ratio < OBS_OVERHEAD_BOUND
    assert overhead_ok, (
        f"tracing overhead {ratio:.2f}x exceeds the "
        f"{OBS_OVERHEAD_BOUND}x bound (off={off_s * 1e6:.0f}us, "
        f"on={on_s * 1e6:.0f}us)")
    emit("table_obs.trace", on_s * 1e6,
         f"trace_valid=1;spans={spans};events={len(doc['traceEvents'])}"
         f";cats={'|'.join(sorted(cats))}"
         f";off_us={off_s * 1e6:.0f};on_us={on_s * 1e6:.0f}"
         f";overhead_x={ratio:.2f};overhead_ok={int(overhead_ok)}")

    # -- (d) calibration drift --------------------------------------------
    clear_plan_cache()
    plan = plan_network(specs, ResourceBudget())
    # discard one warm pass per site first: the fit and the monitor must
    # observe the same warm regime, or still-warming early samples skew
    # the fit and read as honest-table drift
    for site in plan.sites:
        measure_planned_site(site, repeat=1)
    table = collect_plan_samples([plan], repeat=repeat).fit()
    bad = mis_scaled_table(table, OBS_DRIFT_SCALE)
    # threshold sits between interpret-mode timing noise (honest err
    # ~0.3-0.8 on a loaded CI box) and the 8x mis-scale (err ~7)
    honest_mon = DriftMonitor(table, threshold=2.0, min_observations=3)
    bad_mon = DriftMonitor(bad, threshold=2.0, min_observations=3)
    observations = []
    for site in plan.sites:
        member = member_key(site.ip.name, site.precision_bits,
                            site.spec.native_bits)
        us = measure_planned_site(site, repeat=repeat)
        observations.append((member, site.footprint, us))
        honest_mon.observe(member, site.footprint, us)
        bad_mon.observe(member, site.footprint, us)
    assert not honest_mon.drifted, (
        f"honest table tripped the drift monitor: "
        f"{honest_mon.snapshot()}")
    assert bad_mon.drifted, (
        f"{OBS_DRIFT_SCALE}x mis-scaled table did not trip: "
        f"{bad_mon.snapshot()}")
    old_fp = bad.fingerprint()
    new_fp = bad_mon.recalibrate()
    assert new_fp != old_fp, "recalibrate() did not move the fingerprint"
    for member, fp, us in observations:
        bad_mon.observe(member, fp, us)
    recal_ok = not bad_mon.drifted
    assert recal_ok, (
        f"recalibrated table still drifts: {bad_mon.snapshot()}")
    emit("table_obs.drift", 0.0,
         f"drift_honest={int(honest_mon.drifted)}"
         f";drift_perturbed=1;scale={OBS_DRIFT_SCALE}"
         f";honest_err={honest_mon.mean_rel_error:.3f}"
         f";recalibrated_ok={int(recal_ok)}")


# ---------------------------------------------------------------------------
# Kernel microbenches
# ---------------------------------------------------------------------------
def bench_kernels():
    from repro.kernels.matmul.ops import matmul, matmul_dual
    from repro.kernels.attention.flash import flash_attention
    from repro.kernels.attention.decode import flash_decode
    print("# kernel microbenches (interpret mode on CPU — correctness "
          "vehicles; TPU perf comes from the dry-run roofline)")
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-128, 128, (256, 256), dtype=np.int8))
    b = jnp.asarray(rng.integers(-128, 128, (256, 256), dtype=np.int8))
    emit("kernel.mm_mxu_int8_256", _timeit(
        lambda: matmul(a, b, ip="mm_mxu", bm=128, bn=128, bk=128)),
        "m=k=n=256")
    a2 = jnp.asarray(rng.integers(-128, 128, (256, 256), dtype=np.int8))
    emit("kernel.mm_dual_shared_256", _timeit(
        lambda: matmul_dual(a, a2, b, ip="mm_dual_shared",
                            bm=128, bn=128, bk=128)),
        "two streams, one weight fetch")
    q = jnp.asarray(rng.normal(size=(1, 4, 128, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 32)).astype(np.float32))
    emit("kernel.flash_attn_128", _timeit(
        lambda: flash_attention(q, k, v, bq=64, bk=64)), "S=128 GQA2")
    qd = jnp.asarray(rng.normal(size=(1, 4, 1, 32)).astype(np.float32))
    kd = jnp.asarray(rng.normal(size=(1, 2, 512, 32)).astype(np.float32))
    emit("kernel.flash_decode_512", _timeit(
        lambda: flash_decode(qd, kd, kd, bk=128)), "cache=512")


def bench_quantize():
    """Fixed-point (paper discipline) on the LM path: w8a8 accuracy +
    the wire/HBM savings it buys."""
    from repro.quant import (int8_matmul, quantization_error,
                             quantize_weights)
    print("# w8a8 fixed-point path (paper's 8-bit discipline on matmul)")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 512)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(512, 256)).astype(np.float32))
    wq = quantize_weights(w)
    us = _timeit(lambda: int8_matmul(x, wq))
    y_q = int8_matmul(x, wq)
    y_f = jnp.einsum("mk,kn->mn", x, w)
    rel = float(jnp.linalg.norm(y_q - y_f) / jnp.linalg.norm(y_f))
    emit("quantize.w8a8_matmul", us,
         f"rel_err={rel:.4f};weight_bytes=0.25x;werr="
         f"{quantization_error(w):.4f}")


def bench_train_step():
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.models import api
    from repro.models.frontends import make_inputs
    from repro.optim.adamw import AdamWConfig
    print("# train-step wall time (smoke configs, CPU)")
    shape = ShapeConfig("bench", 64, 4, "train")
    opt = AdamWConfig()
    for arch in ("olmo-1b", "dbrx-132b", "rwkv6-3b"):
        cfg = get_config(arch, smoke=True)
        batch = make_inputs(cfg, shape, abstract=False)
        state = api.init_train_state(cfg, opt, jax.random.PRNGKey(0))
        fn = jax.jit(lambda s, bt: api.train_step(cfg, opt, s, bt))
        us = _timeit(fn, state, batch, warmup=1, iters=3)
        emit(f"train_step.{arch}-smoke", us, "batch=4 seq=64")


# ---------------------------------------------------------------------------
# Roofline summary (reads the dry-run artifacts)
# ---------------------------------------------------------------------------
def bench_roofline():
    out = Path("experiments/dryrun")
    if not out.exists():
        print("# roofline: experiments/dryrun missing — run "
              "`python -m repro.launch.dryrun` first")
        return
    print("# roofline per (arch x shape) from the single-pod dry-run "
          "(multi-pod cells are compile-proofs, not calibrated rooflines) "
          "(derived=dominant;fraction;terms in ms)")
    for f in sorted(out.glob("*__single.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok" or rec.get("tag", "baseline") != "baseline":
            continue
        r = rec["roofline"]
        derived = (f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};"
                   f"tc={r['t_compute_s']*1e3:.2f}ms;"
                   f"tm={r['t_memory_s']*1e3:.2f}ms;"
                   f"tcoll={r['t_collective_s']*1e3:.2f}ms;"
                   f"useful={r['useful_flops_ratio']:.2f}")
        emit(f"roofline.{rec['cell']}", 0.0, derived)


# ---------------------------------------------------------------------------
# Table SLO — the SLO scheduler vs the synchronous loop, plus
# plan-preserving recovery.
#
# Both arms replay the SAME Poisson trace (arrival times in est-cycles;
# admission happens when each loop's model clock reaches the arrival,
# so neither arm sleeps) and are judged on the dual-clock rule: the
# modeled clock orders admissions, the monotonic wall clock judges
# deadlines.  The sync arm is round-synchronous — admissions between
# rounds, results stamped at round end (that IS the round-based serving
# contract the scheduler replaces); the async arm stamps per launch.
# Deadlines are calibrated from a measured warm batch wall time
# (host-adaptive), so the assertions hold across machine speeds.
#
# Asserted per mix: async p95 wall latency < sync, async deadline-miss
# rate < sync.  Asserted once: kill/recover replans ZERO cold graphs
# (``STATS.plan_misses`` delta across restore + first post-crash wave).
# ---------------------------------------------------------------------------
# Tight enough that the sync loop's structural light latency (rest of
# the in-flight round + one full heavy round ahead of it in FIFO bucket
# order, ~2.5-3x) sits ABOVE it while the priority scheduler's
# (~1-1.6x) sits well below — the miss-rate comparison then separates
# the policies structurally, not by trace luck.
SLO_LIGHT_DEADLINE_UNITS = 2.0  # x warm-batch wall time (tight)
SLO_HEAVY_DEADLINE_UNITS = 30.0  # x warm-batch wall time (loose)
# Heavy arrivals run slightly past service capacity (~4 per warm-batch
# unit at max_batch=4), so a heavy backlog persists through the trace:
# the sync loop drains the WHOLE heavy bucket before the light one each
# round, making light wait behind the full backlog, while the
# scheduler's per-launch priority pick serves light between heavy
# batches.  Heavy's own deadline is loose enough (30x) that the backlog
# never threatens it in either arm.
SLO_HEAVY_MEAN_IAT_UNITS = 1 / 4.5   # heavy Poisson mean inter-arrival
SLO_LIGHT_MEAN_IAT_UNITS = 1.0       # light Poisson mean inter-arrival


def _slo_deployment(slo_pressure=0.0):
    """The canonical two-tenant constrained device.  Does NOT clear the
    plan cache: the mix comparison benches the steady-state (warm)
    serving regime — the cold-restart cost is exactly what the recovery
    scenario measures separately."""
    from repro.core.resources import ResourceBudget
    from repro.runtime import AdaptiveServer

    device = ResourceBudget(vpu_ops_budget=SERVING_DEVICE_VPU_OPS,
                            vmem_bytes=SERVING_DEVICE_VMEM)
    heavy_p, light_p = _serving_tenants()
    # grant_quantum bounds the budget-slice key space so the warmup
    # replay's plan-cache entries cover the measured replay's grants:
    # without it every EWMA fold mints a fresh fractional budget and the
    # measured runs pay compile stalls that swamp the scheduling signal.
    srv = AdaptiveServer(device, policy="demand", max_batch=4,
                         slo_pressure=slo_pressure, grant_quantum=1 / 16)
    return srv, heavy_p, light_p


def _slo_trace(rng, n_heavy, n_light, unit_s):
    """One Poisson trace in WALL seconds: per-tenant exponential
    inter-arrivals scaled by the measured warm-batch wall time (heavy
    load ~0.75x of its own lane alone — the light tenant and the
    exponential bursts push rounds past one batch).  Both arms replay
    the identical (at_s, tenant, sample) list."""
    shapes = {"vision-heavy": (32, 32, 8), "edge-light": (24, 24, 6)}
    arrivals = []
    t = 0.0
    for _ in range(n_heavy):
        t += float(rng.exponential(SLO_HEAVY_MEAN_IAT_UNITS * unit_s))
        arrivals.append((t, "vision-heavy"))
    t = 0.0
    for _ in range(n_light):
        t += float(rng.exponential(SLO_LIGHT_MEAN_IAT_UNITS * unit_s))
        arrivals.append((t, "edge-light"))
    arrivals.sort(key=lambda pair: pair[0])
    return [(at, name,
             rng.normal(size=shapes[name]).astype(np.float32))
            for at, name in arrivals]


def _slo_unit_seconds():
    """Warm-batch wall time (seconds) of one max-batch heavy round —
    the host-adaptive unit every deadline and inter-arrival time is
    expressed in.  Also warms the process-wide jax caches so neither
    arm pays first-trace overhead."""
    from repro.core.plan import clear_plan_cache
    clear_plan_cache()
    srv, heavy_p, light_p = _slo_deployment()
    srv.register("vision-heavy", heavy_p, (32, 32, 8))
    srv.register("edge-light", light_p, (24, 24, 6), activation="tanh",
                 ladder=(16, 8))
    rng = np.random.default_rng(7)
    times = []
    for _ in range(3):
        for _ in range(4):
            srv.submit("vision-heavy",
                       rng.normal(size=(32, 32, 8)).astype(np.float32))
        for _ in range(2):
            srv.submit("edge-light",
                       rng.normal(size=(24, 24, 6)).astype(np.float32))
        t0 = time.perf_counter()
        srv.step()
        times.append(time.perf_counter() - t0)
    return float(np.median(times[1:]))     # drop the cold round


def _slo_register(sched_or_none, srv, heavy_p, light_p, unit_s):
    """Register the two tenants — through the scheduler (with SLOs,
    light = tight deadline + priority) when given one, else on the bare
    server.  Returns the per-tenant wall deadline budget either way."""
    deadlines = {"vision-heavy": SLO_HEAVY_DEADLINE_UNITS * unit_s,
                 "edge-light": SLO_LIGHT_DEADLINE_UNITS * unit_s}
    if sched_or_none is None:
        srv.register("vision-heavy", heavy_p, (32, 32, 8))
        srv.register("edge-light", light_p, (24, 24, 6),
                     activation="tanh", ladder=(16, 8))
        return deadlines
    from repro.runtime import SLOSpec
    sched_or_none.register(
        "vision-heavy", heavy_p, (32, 32, 8),
        slo=SLOSpec(deadline_s=deadlines["vision-heavy"], priority=0))
    sched_or_none.register(
        "edge-light", light_p, (24, 24, 6), activation="tanh",
        ladder=(16, 8),
        slo=SLOSpec(deadline_s=deadlines["edge-light"], priority=1))
    return deadlines


def _slo_replay(samples, deadlines, submit, pump, pending, outcomes=None):
    """Wall-clock-driven replay shared by both arms: arrivals land on
    the real clock (sleep only when idle), and every request is judged
    from its SCHEDULED arrival instant — identical stamping for both
    arms, so neither admission policy can hide queue wait.  Returns
    per-tenant wall latencies and miss counts (a request that never
    completes — shed/rejected — counts as a miss)."""
    lat = {name: [] for name in deadlines}
    missed = {name: 0 for name in deadlines}
    arrival_s = {}
    tenant_of = {}
    i = 0
    t0 = time.monotonic()
    while i < len(samples) or pending():
        now = time.monotonic() - t0
        while i < len(samples) and samples[i][0] <= now:
            at_s, name, x = samples[i]
            rid = submit(name, x)
            arrival_s[rid] = at_s
            tenant_of[rid] = name
            i += 1
        if pending():
            comps = pump()
            done = time.monotonic() - t0
            for c in comps:
                wall = done - arrival_s[c.rid]
                lat[c.tenant].append(wall)
                if wall > deadlines[c.tenant]:
                    missed[c.tenant] += 1
        elif i < len(samples):
            time.sleep(max(0.0, min(samples[i][0] - now, 0.01)))
    if outcomes is not None:
        for rid, verdict in outcomes().items():
            if verdict in ("shed", "rejected"):
                missed[tenant_of[rid]] += 1
    total = sum(len(v) for v in lat.values())
    dropped = len(arrival_s) - total
    return lat, missed, total, dropped


def _slo_sync_arm(samples, unit_s):
    """Round-synchronous baseline: ``AdaptiveServer.step`` rounds, each
    draining every queued bucket in FIFO bucket order — arrivals during
    a round wait for the next one, and the light tenant drains behind
    the heavy backlog."""
    srv, heavy_p, light_p = _slo_deployment()
    deadlines = _slo_register(None, srv, heavy_p, light_p, unit_s)
    return _slo_replay(samples, deadlines,
                       submit=lambda name, x: srv.submit(name, x),
                       pump=srv.step, pending=srv.pending) + (None,)


def _slo_async_arm(samples, unit_s):
    """The SLO scheduler on the same trace: one launch per pump
    (continuous batching between launches, EDF + priority dispatch,
    shedding, miss-rate-weighted arbitration)."""
    from repro.runtime import SLOScheduler
    srv, heavy_p, light_p = _slo_deployment(slo_pressure=2.0)
    sched = SLOScheduler(srv)
    deadlines = _slo_register(sched, srv, heavy_p, light_p, unit_s)

    def pump():
        return sched.run(max_launches=sched.launches + 1)

    out = _slo_replay(samples, deadlines,
                      submit=lambda name, x: sched.submit(name, x),
                      pump=pump, pending=sched.pending,
                      outcomes=lambda: sched.outcomes)
    return out + (sched,)


def _slo_recovery_scenario():
    """Serve, snapshot, kill, recover, serve again — and count the cold
    plans the restart paid (the gate: ZERO)."""
    import tempfile
    from repro.core.plan import STATS, clear_plan_cache, plan_cache_stats
    from repro.runtime import (SLOScheduler, recover_server,
                               simulate_worker_death, snapshot_server)
    clear_plan_cache()
    srv, heavy_p, light_p = _slo_deployment(slo_pressure=2.0)
    sched = SLOScheduler(srv)
    _slo_register(sched, srv, heavy_p, light_p, unit_s=1.0)
    rng = np.random.default_rng(3)

    def wave(s):
        for _ in range(8):
            s.submit("vision-heavy",
                     rng.normal(size=(32, 32, 8)).astype(np.float32))
        for _ in range(4):
            s.submit("edge-light",
                     rng.normal(size=(24, 24, 6)).astype(np.float32))
        return s.run()

    # two identical waves settle the demand EWMA at the mix's
    # fixed-point ratio, so the post-crash wave re-arbitrates to the
    # SAME grants (ratio-identical targets, zero drift)
    wave(sched)
    wave(sched)
    ckpt = tempfile.mkdtemp(prefix="slo_recovery_")
    snapshot_server(srv, ckpt, 1, scheduler=sched)
    simulate_worker_death()
    misses0, hits0 = STATS.plan_misses, STATS.plan_hits
    srv2, sched2 = recover_server(ckpt)
    comps = wave(sched2)
    cold = STATS.plan_misses - misses0
    hits = STATS.plan_hits - hits0
    assert comps, "recovered scheduler served nothing"
    assert cold == 0, (
        f"plan-preserving restart paid {cold} cold re-plans "
        f"(stats: {plan_cache_stats()})")
    assert hits > 0, "recovered server never hit the imported plan cache"
    return len(comps), cold, hits, len(srv2.tenants)


def table_slo(smoke: bool = False):
    print("# Table SLO — continuous-batching SLO scheduler vs the "
          "synchronous round loop on shared wall-clock Poisson traces "
          f"(light deadline {SLO_LIGHT_DEADLINE_UNITS}x / heavy "
          f"{SLO_HEAVY_DEADLINE_UNITS}x the warm-batch wall time; "
          "p95 = worst tenant's p95 latency / its deadline), plus the "
          "plan-preserving kill/recover scenario (derived=normalized "
          "p95 + miss rate per arm + recovery_cold_plans)")
    unit_s = _slo_unit_seconds()
    # smoke replays the first full mix rather than a shortened one: the
    # strict miss-rate comparison needs the heavy backlog to persist
    # long enough that the sync loop structurally delays the light
    # tenant — a 12x4 trace is short enough for sync to get lucky
    mixes = [(16, 6)] if smoke else [(16, 6), (24, 4), (12, 12)]
    for n_heavy, n_light in mixes:
        rng = np.random.default_rng(1000 + n_heavy * 31 + n_light)
        samples = _slo_trace(rng, n_heavy, n_light, unit_s)
        n = len(samples)
        # discarded warmup replays fill the plan cache with each arm's
        # (batch-shape x slice-budget) keys — repeated until a replay
        # plans entirely from cache (wall jitter shifts batch shapes
        # between replays, so one pass can leave keys unseen).  The
        # measured replays then compare scheduling policy, not
        # cold-planning luck.
        from repro.core.plan import STATS as _PSTATS
        for arm in (_slo_sync_arm, _slo_async_arm):
            for _ in range(6):
                before = _PSTATS.plan_misses
                arm(samples, unit_s)
                if _PSTATS.plan_misses == before:
                    break
        # The SLO-centric percentile: latency only means anything
        # relative to the tenant's own deadline, so each tenant's p95
        # is normalized by its deadline budget and the system scores
        # its WORST tenant.  (Raw worst-tenant p95 would reward
        # ignoring the tight-deadline tenant — the priority scheduler
        # deliberately spends loose heavy headroom on light latency.)
        deadlines = {"vision-heavy": SLO_HEAVY_DEADLINE_UNITS * unit_s,
                     "edge-light": SLO_LIGHT_DEADLINE_UNITS * unit_s}

        def worst_norm_p95(lat):
            return max(float(np.percentile(v, 95)) / deadlines[tn]
                       for tn, v in lat.items() if v)

        # median-of-replays: one replay is a single draw of wall jitter
        # — a lucky trace can hand either arm a zero-miss run, and a
        # one-off host stall (GC, a late compile) can hand either arm a
        # catastrophic p95.  Scoring each replay separately and taking
        # the median across five draws tolerates up to two bad draws
        # per arm, so the strict comparisons measure the policy, not
        # one replay's timing.
        reps = 5

        def measure(arm):
            per_p95, per_miss, dropped, sched = [], [], 0, None
            for _ in range(reps):
                l, m, served, drop, sched = arm(samples, unit_s)
                assert served + drop == n, (served, drop, n)
                per_p95.append(worst_norm_p95(l))
                per_miss.append(sum(m.values()) / n)
                dropped += drop
            return (float(np.median(per_p95)), float(np.median(per_miss)),
                    dropped, sched)

        p95_sync, miss_sync, s_drop, _ = measure(_slo_sync_arm)
        p95_async, miss_async, a_drop, sched = measure(_slo_async_arm)
        assert s_drop == 0, s_drop
        p95_ok = p95_async < p95_sync
        miss_ok = miss_async < miss_sync
        assert p95_ok, (
            f"mix {n_heavy}x{n_light}: async worst-tenant "
            f"deadline-normalized p95 {p95_async:.3f} did not beat "
            f"sync {p95_sync:.3f}")
        assert miss_ok, (
            f"mix {n_heavy}x{n_light}: async miss rate {miss_async:.3f} "
            f"did not beat sync {miss_sync:.3f}")
        st = sched.stats()
        # the headline value is the async arm's worst-tenant p95 as a
        # FRACTION of that tenant's deadline (< 1.0 = inside SLO)
        emit(f"table_slo.mix_{n_heavy}x{n_light}", p95_async,
             f"p95_norm_sync={p95_sync:.3f}"
             f";p95_norm_async={p95_async:.3f}"
             f";miss_sync={miss_sync:.3f};miss_async={miss_async:.3f}"
             f";async_beats_sync_p95={int(p95_ok)}"
             f";async_beats_sync_miss={int(miss_ok)}"
             f";sheds={st['sheds']};preemptions={st['preemptions']}"
             f";launches={st['launches']}")
    served, cold, hits, tenants = _slo_recovery_scenario()
    emit("table_slo.recovery", 0.0,
         f"recovery_cold_plans={cold};post_restore_hits={hits}"
         f";served_after_recover={served};tenants={tenants}"
         f";recovered_ok=1")


# ---------------------------------------------------------------------------
# Table X — chaos: fault injection + degraded-mesh survival.  Three
# asserted arms over the same deterministic Poisson traffic (see
# benchmarks/_chaos_child.py for the workload):
# (a) TRANSPARENCY: a serving trace with the injector armed on a
#     never-firing schedule must be bit-identical (outputs, completion
#     times, modeled percentiles) to the disarmed trace — injection
#     must cost nothing when it does nothing;
# (b) SURVIVAL: the guarded deployment (output screening + retry_f32,
#     bounded deadline-aware retry, spare plans pre-warmed) must hold
#     availability >= 99% through one fault of every scheduled kind —
#     NaN batch, corrupted collective, kernel exception, latency
#     spike, device loss — while degrading 2 -> 1 devices with ZERO
#     cold re-plans, every plan still f32 (the degree ladder descends
#     BEFORE the precision ladder), and modeled p95 inflation bounded;
# (c) BASELINE: the identical schedule against an unguarded server
#     must collapse (poisoned answers served, batches lost, every
#     post-loss batch dead on the corpse) — the failure the survival
#     machinery exists to prevent.
# ``budget_shrink`` is deliberately absent from the chaos schedule: a
# shrunk budget re-keys every plan, so it cannot coexist with the
# zero-cold-replan assertion (its seam is covered by tests/test_faults
# and the on_budget_shrink unit path).
# Runs in a subprocess under XLA_FLAGS=--xla_force_host_platform_
# device_count=2 (JAX fixes its device count at import).
# ---------------------------------------------------------------------------
def table_chaos(smoke: bool = False):
    import os
    import subprocess
    import sys
    print("# Table X — fault injection + degraded-mesh survival: "
          "guarded serving must hold >=99% availability through "
          "nan/collective/kernel/latency/device-loss faults with zero "
          "cold re-plans (spares pre-warmed) vs an unguarded baseline "
          "that collapses; armed-but-idle injection bit-transparent")
    child = Path(__file__).resolve().parent / "_chaos_child.py"
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    soak = 2 if smoke else max(REPEAT, 3)
    proc = subprocess.run(
        [sys.executable, str(child), str(soak)], env=env,
        capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(f"chaos child failed:\n{proc.stderr[-4000:]}")
    rec = json.loads(proc.stdout.splitlines()[-1])
    assert rec["devices"] == 2, \
        f"forced host mesh did not take: {rec['devices']} device(s)"
    # (a) armed-but-never-firing == disarmed, bit for bit
    assert rec["transparent"], "idle injection perturbed the serving trace"
    ch, base = rec["chaos"], rec["baseline"]
    # (b) the guarded arm survives every fault
    assert ch["availability"] >= 0.99, \
        f"guarded availability collapsed: {ch}"
    expected = {"nan_output", "collective_corrupt", "kernel_exception",
                "latency_spike", "device_loss"}
    assert set(ch["faults_fired"]) == expected, \
        f"schedule did not fire every kind: {ch['faults_fired']}"
    assert ch["cold_plans"] == 0, \
        f"degradation planned cold despite pre-warmed spares: {ch}"
    assert ch["devices_after"] == 1 and ch["degradations"] >= 1, \
        f"device loss did not degrade the mesh: {ch}"
    assert set(ch["shard_degree_mix"]) == {"1", "2"}, \
        f"serving never walked the degree ladder 2 -> 1: {ch}"
    assert set(ch["precision_mix"]) == {"32"}, \
        f"degradation moved precision, not (just) degree: {ch}"
    inflation = (ch["p95_cycles_chaos"] / ch["p95_cycles_healthy"]
                 if ch["p95_cycles_healthy"] else float("inf"))
    assert inflation < 5.0, \
        f"modeled p95 inflated {inflation:.2f}x under faults: {ch}"
    assert ch["deadline_miss_rate"] == 0.0, \
        f"generous deadlines still missed: {ch}"
    emit("table_chaos.survives", 0.0,
         f"availability={ch['availability']:.4f};available_ge_target=1"
         f";degraded_cold_plans={ch['cold_plans']}"
         f";spares_prewarmed={ch['spares_prewarmed']}"
         f";faults_fired={len(ch['faults_fired'])}"
         f";guard_retries={ch['guard_retries']}"
         f";devices=2to{ch['devices_after']}"
         f";p95_inflation={inflation:.2f};transparent=1")
    # (c) the unguarded baseline loses what the guards save
    assert base["availability"] < 0.99, \
        f"unguarded baseline did not degrade: {base}"
    assert base["served_ok"] < ch["served_ok"], \
        f"guards did not out-serve the baseline: {base} vs {ch}"
    emit("table_chaos.baseline_dies", 0.0,
         f"availability={base['availability']:.4f};baseline_fails=1"
         f";lost_batches={base['lost_batches']}"
         f";served_ok={base['served_ok']}of{base['submitted']}")


BENCHES = {
    "table1": table1_ip_characteristics,
    "table2": table2_resource_utilization,
    "table3": table3_comparison,
    "table_precision": table_precision,
    "table_fusion": table_fusion,
    "table_calibration": table_calibration,
    "table_serving": table_serving,
    "table_mesh": table_mesh,
    "table_obs": table_obs,
    "table_slo": table_slo,
    "table_chaos": table_chaos,
    "kernels": bench_kernels,
    "quantize": bench_quantize,
    "train_step": bench_train_step,
    "roofline": bench_roofline,
}


def main(argv=None) -> None:
    import argparse
    import inspect
    ap = argparse.ArgumentParser(description="paper-table + system benches")
    ap.add_argument("--only", default="",
                    help=f"comma list of benches to run (default all); "
                         f"have: {','.join(BENCHES)}")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced workloads for CI (benches that "
                         "support it, e.g. table_serving's single mix)")
    ap.add_argument("--repeat", type=int, default=3, metavar="N",
                    help="wall-clock runs per measurement after one "
                         "warmup; timed rows report the median (default 3)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write machine-readable rows "
                         "[{name, us_per_call, derived}] to PATH")
    args = ap.parse_args(argv)
    global REPEAT
    REPEAT = max(1, args.repeat)
    selected = (args.only.split(",") if args.only else list(BENCHES))
    unknown = [s for s in selected if s not in BENCHES]
    if unknown:
        raise SystemExit(f"unknown benches {unknown}; have {list(BENCHES)}")
    repo_root = Path(__file__).resolve().parent.parent
    print("name,us_per_call,derived")
    for name in selected:
        fn = BENCHES[name]
        kwargs = ({"smoke": True} if args.smoke
                  and "smoke" in inspect.signature(fn).parameters else {})
        start = len(ROWS)
        fn(**kwargs)
        # Per-table perf trajectory: full runs persist their rows next
        # to the repo (BENCH_<table>.json) so successive PRs can diff;
        # --smoke runs are reduced workloads and must not overwrite the
        # trajectory.
        if not args.smoke:
            table_rows = [{"name": n, "us_per_call": us, "derived": d}
                          for n, us, d in ROWS[start:]]
            (repo_root / f"BENCH_{name}.json").write_text(
                json.dumps(table_rows, indent=2))
    print(f"# total rows: {len(ROWS)}")
    if args.json:
        rows = [{"name": n, "us_per_call": us, "derived": d}
                for n, us, d in ROWS]
        Path(args.json).write_text(json.dumps(rows, indent=2))
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
