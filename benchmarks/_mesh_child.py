"""Forced-multi-device child for ``benchmarks/run.py::table_mesh``.

Launched by the parent bench with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` in the
environment — JAX fixes its device count at import, so the mesh cases
cannot run in the parent process.  Everything mesh happens here: plan
the two gate workloads with and without a mesh, execute through
``distributed/shard_exec.py``, time both arms, and print ONE json
object to stdout for the parent to assert on.

Gate cases (see table_mesh's docstring for why these shapes):
  win     — a conv whose 1-device plan is budget-forced onto the slow
            member; batch-sharding halves the per-device footprint and
            the planner flips to the fast member.  The 2-device plan
            must be BOTH modeled and measured faster.
  refusal — a tiny 1x1 conv whose collective cost dwarfs its compute;
            the planner must keep degree=1, and the forced-shard
            counterfactual (``core.shard.force_shard_decisions``) must
            measure SLOWER, proving the refusal right.

Usage: python benchmarks/_mesh_child.py [repeat]
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ip import SiteSpec
from repro.core.plan import plan_network
from repro.core.resources import MeshSpec, ResourceBudget
from repro.core.shard import force_shard_decisions
from repro.distributed.shard_exec import (apply_plan_replicated,
                                          apply_plan_sharded)

REPEAT = int(sys.argv[1]) if len(sys.argv) > 1 else 3


def _timeit(fn, *args, repeat=None) -> float:
    """us/call of a JITTED arm: one warmup (compiles), then the MIN of
    REPEAT timed calls.  Jit matters — an un-jitted shard_map re-traces
    per call and its ~0.7 s trace time would drown the collective/
    compute signal this table exists to measure.  Min (not median)
    because the table asserts an ORDERING between two arms: host load
    only ever inflates a sample, so the min is the least-contended
    estimate of each arm's true cost and the ordering it yields is the
    stable one."""
    fn = jax.jit(fn)
    jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeat or REPEAT):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.min(times)) * 1e6


def _conv_spec(x_shape, w_shape) -> SiteSpec:
    return SiteSpec.make("conv", "conv2d", (tuple(x_shape), tuple(w_shape)),
                         "float32", dual=False)


def _force(plan, mesh, axis):
    """The measurement counterfactual: the same planned members with
    every site sharded on ``axis`` at the full mesh degree (the option
    the DP refused)."""
    force_shard_decisions(tuple(s.spec for s in plan.sites), mesh,
                          axis=axis)  # raises if the split is illegal
    sites = tuple(dataclasses.replace(s, shard_axis=axis,
                                      shard_degree=mesh.devices)
                  for s in plan.sites)
    return dataclasses.replace(plan, sites=sites, mesh=mesh)


def main() -> None:
    mesh = MeshSpec(devices=2)
    rng = np.random.default_rng(0)
    out = {"devices": len(jax.devices())}

    # -- win: saturating conv, mxu gated at 1 device --------------------
    budget = ResourceBudget(mxu_passes_budget=7)
    x = jnp.asarray(rng.normal(size=(8, 16, 16, 32)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, (3 * 3 * 32) ** -0.5,
                               (3, 3, 32, 128)).astype(np.float32))
    spec = _conv_spec(x.shape, w.shape)
    p1 = plan_network((spec,), budget)
    p2 = plan_network((spec,), budget, mesh=mesh)
    weights = {"conv": w}
    y_rep = apply_plan_replicated(p2, x, weights)
    y_shd = apply_plan_sharded(p2, x, weights)
    s2 = p2.sites[0]
    out["win"] = {
        "ip_1dev": p1.sites[0].ip.name,
        "ip_2dev": s2.ip.name,
        "shard_axis": s2.shard_axis,
        "shard_degree": s2.shard_degree,
        "est_1dev": p1.total_cycles,
        "est_2dev": p2.total_cycles,
        "comm_2dev": s2.footprint.comm_cycles,
        "us_1dev": _timeit(
            lambda xx, ww: apply_plan_replicated(p1, xx, {"conv": ww}),
            x, w),
        "us_2dev": _timeit(
            lambda xx, ww: apply_plan_sharded(p2, xx, {"conv": ww}),
            x, w),
        "bit_identical": bool((y_rep == y_shd).all()),
    }

    # -- refusal: 1x1 conv, collectives dwarf compute -------------------
    # The counterfactual splits the input CHANNELS: each device saves
    # half the MACs but must all-reduce the FULL 32 MiB output — the
    # collective the model prices at ~11x the whole site's compute.
    # (The payload is deliberately large and the repeat floor higher
    # than the win case's: this row asserts a measured ORDERING whose
    # margin is ~2x, not ~12x, so it needs the extra noise immunity.)
    xr = jnp.asarray(rng.normal(size=(4, 128, 128, 4)).astype(np.float32))
    wr = jnp.asarray(rng.normal(0, 4 ** -0.5,
                                (1, 1, 4, 128)).astype(np.float32))
    rspec = _conv_spec(xr.shape, wr.shape)
    pr = plan_network((rspec,), ResourceBudget(), mesh=mesh)
    forced = _force(pr, mesh, "chan")
    fsh = force_shard_decisions((rspec,), mesh, axis="chan")
    rrep = max(REPEAT, 5)
    out["refusal"] = {
        "shard_degree": pr.sites[0].shard_degree,
        "est_chosen": pr.total_cycles,
        "comm_forced": sum(s.comm_cycles for s in fsh),
        "us_chosen": _timeit(
            lambda xx, ww: apply_plan_replicated(pr, xx, {"conv": ww}),
            xr, wr, repeat=rrep),
        "us_forced": _timeit(
            lambda xx, ww: apply_plan_sharded(forced, xx, {"conv": ww}),
            xr, wr, repeat=rrep),
    }
    print(json.dumps(out))


if __name__ == "__main__":
    main()
