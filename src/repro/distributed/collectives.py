"""Manual collectives for overlap experiments: bucketed gradient
all-reduce and a bidirectional-ring all-reduce built on ppermute.

pjit/XLA already schedules collectives asynchronously; these exist for
(a) the §Perf overlap hillclimb — issuing the grad all-reduce per
bucket *inside* the backward scan so communication overlaps remaining
compute, and (b) explicit cross-pod control (compression hooks attach
here).  All run under shard_map.
"""
from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def ring_all_reduce(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Bidirectional-ring all-reduce via ppermute (reduce-scatter +
    all-gather decomposition), equivalent to lax.psum.

    Exists to make the ring schedule explicit/controllable (chunked
    issue = overlap window); tests assert equality with psum.
    """
    # jax.lax.axis_size doesn't exist on jax<=0.4.x; psum of a literal 1
    # folds to the (static) axis size on every version.
    n = jax.lax.psum(1, axis_name)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    orig_shape = x.shape
    pad = (-x.size) % n
    flat = jnp.pad(x.reshape(-1), (0, pad)).reshape(n, -1)

    # reduce-scatter: after n-1 hops, chunk (idx+1)%n holds the full sum
    def rs_step(i, acc_flat):
        send_chunk = (idx - i) % n
        piece = jax.lax.dynamic_index_in_dim(acc_flat, send_chunk, 0,
                                             keepdims=False)
        recv = jax.lax.ppermute(piece, axis_name,
                                [(j, (j + 1) % n) for j in range(n)])
        tgt = (idx - i - 1) % n
        return acc_flat.at[tgt].add(recv)

    flat = jax.lax.fori_loop(0, n - 1, rs_step, flat)

    # all-gather: rank j owns fully-reduced chunk (j+1)%n; circulate the
    # owned chunk around the ring n-1 times.
    def ag_step(i, acc_flat):
        src_chunk = (idx + 1 - i) % n
        piece = jax.lax.dynamic_index_in_dim(acc_flat, src_chunk, 0,
                                             keepdims=False)
        recv = jax.lax.ppermute(piece, axis_name,
                                [(j, (j + 1) % n) for j in range(n)])
        tgt = (idx - i) % n
        return acc_flat.at[tgt].set(recv)

    flat = jax.lax.fori_loop(0, n - 1, ag_step, flat)
    out = flat.reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(orig_shape)


def bucketed_psum(grads: Any, axis_name: str, *, n_buckets: int = 4):
    """All-reduce a grad pytree in ``n_buckets`` independent psums so
    XLA can overlap them with surrounding compute (vs one fused
    all-reduce at the end of backward)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    order = sorted(range(len(leaves)), key=lambda i: -leaves[i].size)
    buckets = [[] for _ in range(n_buckets)]
    sizes = [0] * n_buckets
    for i in order:  # greedy balance
        b = sizes.index(min(sizes))
        buckets[b].append(i)
        sizes[b] += leaves[i].size
    out = [None] * len(leaves)
    for idxs in buckets:
        if not idxs:
            continue
        reduced = jax.lax.psum(tuple(leaves[i] for i in idxs), axis_name)
        for i, r in zip(idxs, reduced):
            out[i] = r
    return jax.tree_util.tree_unflatten(treedef, out)
