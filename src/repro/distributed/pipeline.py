"""GPipe-style pipeline parallelism via shard_map + ppermute.

Each rank along the ``pipe`` mesh axis owns one stage's params; micro-
batches stream through the ring with a collective_permute handoff per
tick.  Fill+drain schedule: n_micro + n_stages - 1 ticks.  This is the
PP building block referenced in DESIGN.md §5 (usable across pods, where
the pod axis = stage axis and only point-to-point traffic crosses the
inter-pod links).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def gpipe_forward(stage_fn: Callable, mesh: Mesh, *, axis: str = "pipe"):
    """Build a pipelined forward over one mesh axis.

    stage_fn(stage_params, x) -> y, applied by every rank to the
    microbatch currently resident on it.

    Returns pipelined(stage_params_stacked, x_micro) where
      stage_params_stacked: pytree with leading dim n_stages,
      x_micro: (n_micro, micro_batch, ...) input microbatches,
    and the result is (n_micro, micro_batch, ...) outputs of the LAST
    stage, in order.
    """
    n_stages = mesh.shape[axis]

    def per_rank(params_local, x_micro):
        # params_local: stage params with leading dim 1 (this rank's)
        params = jax.tree.map(lambda t: t[0], params_local)
        rank = jax.lax.axis_index(axis)
        n_micro = x_micro.shape[0]
        ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(x_micro[0])
        outs = jnp.zeros((n_micro,) + x_micro.shape[1:], x_micro.dtype)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t during fill; drain ticks read
            # index 0 (any in-bounds index) and select zeros — never a
            # clamped re-read of the last microbatch
            ingesting = t < n_micro
            x_in = jnp.where(ingesting,
                             x_micro[jnp.where(ingesting, t, 0)],
                             jnp.zeros_like(buf))
            my_in = jnp.where(rank == 0, x_in, buf)
            y = stage_fn(params, my_in)
            # rank r's tick-t compute is microbatch (t - r): only the
            # fill+drain window [r, r + n_micro) is real work.  Mask the
            # stale ticks explicitly so whatever stage_fn makes of a
            # zero/garbage buffer (f(0) != 0, NaNs, ...) can never reach
            # the handoff or the emitted outputs.
            valid = jnp.logical_and(rank <= t, t - rank < n_micro)
            y = jnp.where(valid, y, jnp.zeros_like(y))
            # last stage emits microbatch (t - (n_stages-1)) at this tick
            out_idx = t - (n_stages - 1)
            emit = jnp.logical_and(rank == n_stages - 1, out_idx >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: o.at[jnp.maximum(out_idx, 0)].set(y),
                lambda o: o, outs)
            # hand off to the next stage
            buf = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return buf, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # outs only valid on the last rank; broadcast it ring-wise
        outs = jax.lax.ppermute(
            outs, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
        # after one hop, rank 0 holds them; psum-select for simplicity
        outs = jax.lax.psum(
            jnp.where(rank == 0, outs, jnp.zeros_like(outs)), axis)
        return outs

    def wrapper(stage_params, x_micro):
        param_specs = jax.tree.map(lambda _: P(axis), stage_params)
        return shard_map(
            per_rank, mesh=mesh,
            in_specs=(param_specs, P()),
            out_specs=P(),
            check_rep=False)(stage_params, x_micro)

    return wrapper
