"""Sharding rules: DP / TP / EP / SP over the production mesh.

Name-path-based rules produce a PartitionSpec pytree for params (and,
structurally identical, the Adam moments), batches, and decode caches.

Policy highlights (see DESIGN.md §5):
  * TP (Megatron): attention heads + FFN hidden over 'model'
    (column-parallel in, row-parallel out).
  * GQA: KV projections replicated when kv_heads % tp != 0.
  * EP: MoE expert axis over 'model' when n_experts % tp == 0, else
    TP over the expert FFN hidden dim.
  * DP: batch over ('pod','data') / ('data',).
  * SP: decode caches shard the sequence axis when batch doesn't divide
    dp (long_500k, batch=1) — flash-decode's partial-softmax merges via
    the psum XLA inserts.
  * FSDP option: additionally shard the largest param axis over 'data'
    (ZeRO-3 via GSPMD all-gathers) — used by small-dense + rwkv archs
    when replicated-under-TP params would not fit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import dp_axes, mesh_axis_sizes


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    tp_axis: str = "model"
    fsdp: bool = False           # shard big param dims over 'data' too
    seq_shard_caches: bool = True


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(cfg: ModelConfig, mesh: Mesh, path: str, shape,
               policy: ShardingPolicy = ShardingPolicy()) -> P:
    """PartitionSpec for one parameter leaf, by name path."""
    sizes = mesh_axis_sizes(mesh)
    tp = sizes.get(policy.tp_axis, 1)
    dpx = dp_axes(mesh)
    dp = 1
    for a in dpx:
        dp *= sizes[a]
    tpa = policy.tp_axis
    nd = len(shape)
    name = path.rsplit("/", 1)[-1]
    parent = path

    def _fill_fsdp(spec: P) -> P:
        """Shard the largest still-unsharded dim over the dp axes
        (ZeRO-3 via GSPMD); applied on top of the TP spec when
        policy.fsdp — skips tiny leaves (<1 MiB) where the all-gather
        latency would outweigh the memory win."""
        if not policy.fsdp:
            return spec
        n_elems = 1
        for s in shape:
            n_elems *= s
        if n_elems < (1 << 20):
            return spec
        dims = list(spec) + [None] * (nd - len(spec))
        best, best_dim = 0, -1
        for i, (d, s) in enumerate(zip(dims, shape)):
            if d is None and _div(s, dp) and s > best:
                best, best_dim = s, i
        if best_dim >= 0:
            dims[best_dim] = dpx if len(dpx) > 1 else dpx[0]
        return P(*dims)

    def base() -> P:
        # ---- embeddings -------------------------------------------------
        if name == "embed":                       # (V, D)
            return P(tpa, None) if _div(shape[0], tp) else P(None, None)
        if name == "lm_head":                     # (D, V)
            return P(None, tpa) if _div(shape[1], tp) else P(None, None)

        # ---- attention --------------------------------------------------
        if "attn" in parent:
            lead = (None,) * (nd - 2)             # group/layer stack prefix
            if name == "wq":                      # (..., D, Hq*Dh)
                ok = _div(cfg.n_heads, tp)
                return P(*lead, None, tpa) if ok else P(*lead, None, None)
            if name in ("wk", "wv"):              # (..., D, Hkv*Dh)
                ok = _div(cfg.n_kv_heads, tp)
                return P(*lead, None, tpa) if ok else P(*lead, None, None)
            if name == "wo":                      # (..., Hq*Dh, D)
                ok = _div(cfg.n_heads, tp)
                return P(*lead, tpa, None) if ok else P(*lead, None, None)

        # ---- MoE ----------------------------------------------------------
        if "moe" in parent:
            E = cfg.moe.n_experts
            lead = (None,) * (nd - 3)
            if name == "router":                  # (..., D, E)
                return P(*((None,) * nd))
            ep = _div(E, tp)
            if name in ("w_gate", "w_up", "w_in"):    # (..., E, D, F)
                if ep:
                    return P(*lead, tpa, None, None)
                return (P(*lead, None, None, tpa) if _div(shape[-1], tp)
                        else P(*((None,) * nd)))
            if name == "w_down":                  # (..., E, F, D)
                if ep:
                    return P(*lead, tpa, None, None)
                return (P(*lead, None, tpa, None) if _div(shape[-2], tp)
                        else P(*((None,) * nd)))

        # ---- dense FFN (also rwkv channel-mix w_k/w_v) --------------------
        if name in ("w_gate", "w_up", "w_in") or (
                name == "w_k" and "rwkv_cm" in parent):
            lead = (None,) * (nd - 2)             # (..., D, F)
            return (P(*lead, None, tpa) if _div(shape[-1], tp)
                    else P(*((None,) * nd)))
        if name == "w_down" or (name == "w_v" and "rwkv_cm" in parent):
            lead = (None,) * (nd - 2)             # (..., F, D)
            return (P(*lead, tpa, None) if _div(shape[-2], tp)
                    else P(*((None,) * nd)))

        # ---- mamba ---------------------------------------------------------
        if "mamba" in parent:
            di = cfg.d_inner
            lead = (None,) * (nd - 2)
            if name == "in_proj":                 # (..., D, 2*di)
                return (P(*lead, None, tpa) if _div(di, tp)
                        else P(*((None,) * nd)))
            if name in ("x_proj", "out_proj", "A_log"):   # (..., di, *)
                return (P(*lead, tpa, None) if _div(di, tp)
                        else P(*((None,) * nd)))
            if name == "dt_proj":                 # (..., dtr, di)
                return (P(*lead, None, tpa) if _div(di, tp)
                        else P(*((None,) * nd)))
            if name in ("conv_w",):               # (..., d_conv, di)
                return (P(*lead, None, tpa) if _div(di, tp)
                        else P(*((None,) * nd)))
            if name in ("conv_b", "dt_bias", "D"):        # (..., di)
                lead1 = (None,) * (nd - 1)
                return (P(*lead1, tpa) if _div(di, tp)
                        else P(*((None,) * nd)))

        # ---- rwkv time-mix --------------------------------------------------
        if "rwkv_tm" in parent:
            lead = (None,) * (nd - 2)
            if name in ("w_r", "w_k", "w_v", "w_g"):      # (..., D, D)
                return (P(*lead, None, tpa) if _div(shape[-1], tp)
                        else P(*((None,) * nd)))
            if name == "w_o":                     # (..., D, D)
                return (P(*lead, tpa, None) if _div(shape[-2], tp)
                        else P(*((None,) * nd)))
            if name in ("w_lora_a", "w_lora_b"):
                return P(*((None,) * nd))

        # ---- everything else (norms, mixes, biases, u, ...): replicated --
        return P(*((None,) * nd))

    return _fill_fsdp(base())


def params_pspecs(cfg: ModelConfig, mesh: Mesh, params_tree,
                  policy: ShardingPolicy = ShardingPolicy()):
    """PartitionSpec tree matching a (possibly abstract) params tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(cfg, mesh, _path_str(path), leaf.shape,
                                      policy),
        params_tree)


def state_pspecs(cfg: ModelConfig, mesh: Mesh, state_tree,
                 policy: ShardingPolicy = ShardingPolicy()):
    """TrainState(params, OptState(mu, nu, step)) spec tree."""
    from repro.models.api import TrainState
    from repro.optim.adamw import OptState
    p = params_pspecs(cfg, mesh, state_tree.params, policy)
    mu = params_pspecs(cfg, mesh, state_tree.opt.mu, policy)
    nu = params_pspecs(cfg, mesh, state_tree.opt.nu, policy)
    return TrainState(p, OptState(mu, nu, P()))


def batch_pspecs(cfg: ModelConfig, mesh: Mesh, batch_tree):
    """Shard the leading batch dim of every input over the dp axes."""
    dpx = dp_axes(mesh)
    dspec = dpx if len(dpx) > 1 else dpx[0]

    def spec(leaf):
        nd = len(leaf.shape)
        sizes = mesh_axis_sizes(mesh)
        dp = 1
        for a in dpx:
            dp *= sizes[a]
        if leaf.shape[0] % dp == 0:
            return P(dspec, *((None,) * (nd - 1)))
        return P(*((None,) * nd))

    return jax.tree.map(spec, batch_tree)


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache_tree,
                 policy: ShardingPolicy = ShardingPolicy()):
    """Decode caches: batch over dp; SP over sequence when batch==1.

    Attn k/v: (G, B, S, Hkv, Dh)  |  encdec: (L, B, S, Hkv, Dh)
    mamba:    conv (G, B, dc, di), ssm (G, B, di, ds)
    rwkv:     tm_x/cm_x (G, B, D), state (G, B, H, hs, hs)
    """
    sizes = mesh_axis_sizes(mesh)
    tp = sizes.get(policy.tp_axis, 1)
    dpx = dp_axes(mesh)
    dspec = dpx if len(dpx) > 1 else dpx[0]
    dp = 1
    for a in dpx:
        dp *= sizes[a]
    tpa = policy.tp_axis

    def spec_with_path(path, leaf):
        name = _path_str(path).rsplit("/", 1)[-1]
        nd = len(leaf.shape)
        B = leaf.shape[1]
        batch_ok = B % dp == 0
        bspec = dspec if batch_ok else None
        if name in ("k", "v", "xk", "xv"):
            S = leaf.shape[2]
            seq_axes = []
            if not batch_ok and policy.seq_shard_caches and S % dp == 0:
                seq_axes.extend(dpx)    # SP over data (batch=1 long ctx)
            hspec = tpa if _div(cfg.n_kv_heads, tp) else None
            if (hspec is None and policy.seq_shard_caches
                    and S % (tp * max(dp if seq_axes else 1, 1)) == 0):
                # kv heads don't divide tp: shard the SEQUENCE over the
                # model axis instead — flash-decode partial softmax
                # merges with the psum XLA inserts. Without this the
                # cache replicates across tp and blows HBM (grok
                # decode_32k: 66 GiB/chip -> 4.2 GiB/chip).
                seq_axes.append(tpa)
            sspec = (tuple(seq_axes) if len(seq_axes) > 1
                     else (seq_axes[0] if seq_axes else None))
            return P(None, bspec, sspec, hspec, None)
        if name == "conv":
            return P(None, bspec, None,
                     tpa if _div(cfg.d_inner, tp) else None)
        if name == "ssm":
            return P(None, bspec,
                     tpa if _div(cfg.d_inner, tp) else None, None)
        if name in ("tm_x", "cm_x"):
            return P(None, bspec, None)
        if name == "state":
            return P(None, bspec, *((None,) * (nd - 2)))
        return P(*((None,) * nd))

    return jax.tree_util.tree_map_with_path(spec_with_path, cache_tree)


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
