"""Execute a mesh-sharded NetworkPlan — the lowering half of the
"Sharding contract" (docs/adaptive_ips.md).

``core/shard.py`` decides *whether* each site splits; this module makes
the split real: one ``shard_map`` over the whole site chain, inside
which every device

* slices its block of the activation when the incoming layout is
  replicated and the site wants a batch/channel shard (free — the data
  is already everywhere),
* all-gathers when a sharded layout must change (the priced boundary
  transitions),
* runs the site's planned member on its per-device block through the
  family ops entry (the same kernels the replicated path runs — the
  plan picked them, sharding must not change the math), and
* for a channel-split conv, all-reduces the partial outputs
  (``psum`` reference, or the explicit ``ring_all_reduce`` ppermute
  path with ``use_ring=True``).

The network's input arrives replicated and its output returns
replicated, so the caller sees exactly the replicated path's contract;
for float32 plans the batch-sharded result is bit-identical and the
channel-split result differs only by float summation order (tests
assert both).  Lowered (quantized) sites are refused — the sharded
executor is a float-precision path.

Multi-device is real in CI via ``XLA_FLAGS=--xla_force_host_platform_
device_count=N`` (see ``launch/mesh.make_host_mesh``); Pallas interpret
-mode kernels compose with ``shard_map`` on host devices.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.plan import NetworkPlan, PlannedSite
from repro.core.shard import FULL, output_layout, required_input_layout
from repro.obs.trace import NOOP_SPAN, TRACER
from repro.runtime.faults import INJECTOR

_CHAIN_FAMILIES = ("conv2d", "pool2d", "activation", "cnn_fused")


def _check_chain(plan: NetworkPlan) -> None:
    for s in plan.sites:
        if s.spec.family not in _CHAIN_FAMILIES:
            raise ValueError(
                f"site {s.spec.name!r} ({s.spec.family}) is not part of a "
                f"conv/pool/act chain; sharded execution handles "
                f"{_CHAIN_FAMILIES}")
        if s.lowered:
            raise ValueError(
                f"site {s.spec.name!r} was lowered to int"
                f"{s.precision_bits}; sharded execution is float-only — "
                "plan without a ladder or without a mesh")


def _run_site(site: PlannedSite, x: jnp.ndarray, w: Optional[jnp.ndarray],
              *, interpret: bool, reduce_axis: Optional[str] = None,
              use_ring: bool = False) -> jnp.ndarray:
    """One site through its planned member's ops entry — shared by the
    replicated and the per-device walks (the per-device walk passes
    ``reduce_axis`` for channel-split convs)."""
    spec = site.spec
    if spec.family == "conv2d":
        from repro.kernels.conv2d.ops import conv2d
        return conv2d(x, w, ip=site.ip.name, interpret=interpret,
                      reduce_axis=reduce_axis,
                      reduce="ring" if use_ring else "psum")
    if spec.family == "pool2d":
        from repro.kernels.pool2d.ops import pool2d
        return pool2d(x, window=spec.knob("window", (2, 2)),
                      stride=spec.knob("stride"),
                      mode=spec.knob("mode", "max"),
                      ip=site.ip.name, interpret=interpret)
    if spec.family == "activation":
        from repro.kernels.activation.ops import activation
        return activation(x, kind=spec.knob("kind", "relu"),
                          ip=site.ip.name, interpret=interpret)
    # cnn_fused (gated by _check_chain)
    from repro.kernels.fused.ops import fused_cnn_block
    return fused_cnn_block(
        x, w, pool_window=spec.knob("window", (2, 2)),
        pool_stride=spec.knob("stride"), pool_mode=spec.knob("mode", "max"),
        activation=spec.knob("kind", "relu"), ip=site.ip.name,
        interpret=interpret)


def apply_plan_replicated(plan: NetworkPlan, x: jnp.ndarray,
                          weights: Optional[Dict[str, jnp.ndarray]] = None,
                          *, interpret: bool = True) -> jnp.ndarray:
    """The single-device reference walk: every site's planned member on
    the full tensors, no mesh.  ``weights`` maps conv/fused site name ->
    its weight tensor."""
    _check_chain(plan)
    weights = weights or {}
    cur = x
    for site in plan.sites:
        cur = _run_site(site, cur, weights.get(site.spec.name),
                        interpret=interpret)
    return cur


def _slice_block(x: jnp.ndarray, dim: int, degree: int,
                 index) -> jnp.ndarray:
    block = x.shape[dim] // degree
    return jax.lax.dynamic_slice_in_dim(x, index * block, block, axis=dim)


def _relay(x: jnp.ndarray, have, want, axis: str, index) -> jnp.ndarray:
    """Move ``x`` from layout ``have`` to ``want`` inside shard_map.
    Layouts are ``core.shard`` tuples; a sharded source is gathered back
    to replicated first (the priced single-hop model), then slicing is
    free."""
    if have == want:
        return x
    if have != FULL:
        # tiled all-gather along the shard dim restores the global tensor
        dim = 0 if have[0] == "batch" else x.ndim - 1
        x = jax.lax.all_gather(x, axis, axis=dim, tiled=True)
    if want == FULL:
        return x
    dim = 0 if want[0] == "batch" else x.ndim - 1
    return _slice_block(x, dim, want[1], index)


def apply_plan_sharded(plan: NetworkPlan, x: jnp.ndarray,
                       weights: Optional[Dict[str, jnp.ndarray]] = None,
                       *, interpret: bool = True, use_ring: bool = False,
                       devices=None) -> jnp.ndarray:
    """Execute ``plan`` under its mesh: one ``shard_map`` over the whole
    chain, layouts threaded exactly as the planner priced them.

    ``x`` and every weight enter replicated (``in_specs=P()``) and the
    result leaves replicated — identical contract to
    ``apply_plan_replicated``; a plan with no sharded sites (or no mesh)
    simply runs the replicated walk.  ``use_ring=True`` routes the
    channel-split conv's all-reduce through the explicit ppermute ring
    instead of ``lax.psum``.
    """
    _check_chain(plan)
    if (plan.mesh is None or plan.mesh.devices <= 1
            or not plan.sharded_sites()):
        return apply_plan_replicated(plan, x, weights, interpret=interpret)
    weights = weights or {}
    d = plan.mesh.devices
    axis = plan.mesh.axis
    devs = list(devices) if devices is not None else jax.devices()[:d]
    if len(devs) < d:
        raise ValueError(
            f"plan wants {d} devices but only {len(devs)} are available "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count for "
            "host meshes)")
    mesh = Mesh(np.array(devs[:d]), (axis,))
    dplan = plan.device_plan()

    def device_fn(xg, wg):
        index = jax.lax.axis_index(axis)
        cur = xg
        have = FULL
        for gsite, dsite in zip(plan.sites, dplan.sites):
            need = required_input_layout(gsite.spec, gsite.shard_axis,
                                         gsite.shard_degree)
            cur = _relay(cur, have, need, axis, index)
            w = wg.get(gsite.spec.name)
            reduce_axis = None
            if (gsite.sharded and gsite.shard_axis == "chan"
                    and gsite.spec.family == "conv2d"):
                # weights split their input-channel dim with the data
                w = _slice_block(w, 2, gsite.shard_degree, index)
                reduce_axis = axis
            run = dsite if gsite.sharded else gsite
            cur = _run_site(run, cur, w, interpret=interpret,
                            reduce_axis=reduce_axis, use_ring=use_ring)
            have = output_layout(gsite.spec, gsite.shard_axis,
                                 gsite.shard_degree)
        return _relay(cur, have, FULL, axis, index)

    fn = shard_map(device_fn, mesh=mesh, in_specs=(P(), P()),
                   out_specs=P(), check_rep=False)
    with (TRACER.span("shard_exec.apply", "collective",
                      {"devices": d, "axis": axis,
                       "comm_cycles": sum(s.footprint.comm_cycles
                                          for s in plan.sites)})
          if TRACER.enabled else NOOP_SPAN):
        y = fn(x, dict(weights))
    if INJECTOR.enabled:
        # injection seam "collective": corruption lands on the gathered
        # result, after the collectives (inside shard_map is traced
        # code — a host-side perturbation there would be wrong anyway)
        y = INJECTOR.perturb_output("collective", y)
    return y
