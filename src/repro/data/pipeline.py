"""Deterministic, resumable, shard-aware synthetic LM data pipeline.

Stateless-indexable: batch ``i`` is a pure function of (seed, i, shard)
— so restart-from-checkpoint resumes *exactly* by skipping to the saved
step, and every data shard draws disjoint token streams without any
coordination (the property the fault-tolerance layer leans on).

The generator is a counter-mode threefry stream (jax.random) over a
Zipf-ish unigram table — cheap, seekable, and with enough skew that
cross-entropy curves look like language rather than uniform noise.
An optional memmap file source provides the same interface for real
token files.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1            # data-parallel shards
    shard_id: int = 0
    zipf_a: float = 1.2
    token_file: Optional[str] = None   # memmap .bin of int32 tokens


class SyntheticLM:
    """Indexable dataset of (tokens, labels) batches."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_shards == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_shards
        # Zipf-ish unigram distribution, fixed by seed.
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** -cfg.zipf_a
        self._probs = probs / probs.sum()
        self._perm = rng.permutation(cfg.vocab_size)
        self._mm = None
        if cfg.token_file:
            self._mm = np.memmap(cfg.token_file, dtype=np.int32, mode="r")

    def __getitem__(self, step: int) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        if self._mm is not None:
            span = self.local_batch * (cfg.seq_len + 1)
            start = ((step * cfg.n_shards + cfg.shard_id) * span) % max(
                len(self._mm) - span, 1)
            flat = np.asarray(self._mm[start:start + span])
            toks = flat.reshape(self.local_batch, cfg.seq_len + 1)
        else:
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step),
                cfg.shard_id)
            u = jax.random.uniform(key, (self.local_batch, cfg.seq_len + 1))
            cdf = np.cumsum(self._probs)
            toks = self._perm[np.searchsorted(cdf, np.asarray(u))]
            toks = np.clip(toks, 0, cfg.vocab_size - 1)
        return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                "labels": jnp.asarray(toks[:, 1:], jnp.int32)}

    def iter_from(self, step: int) -> Iterator[Dict[str, jnp.ndarray]]:
        while True:
            yield self[step]
            step += 1


def make_pipeline(vocab_size: int, seq_len: int, global_batch: int, *,
                  seed: int = 0, n_shards: int = 1, shard_id: int = 0,
                  token_file: Optional[str] = None) -> SyntheticLM:
    return SyntheticLM(DataConfig(vocab_size, seq_len, global_batch,
                                  seed=seed, n_shards=n_shards,
                                  shard_id=shard_id, token_file=token_file))
