"""Calibration — activation ranges and weight scales from sample batches.

Weight scales are static (per-output-channel, computed once from the
parameters); activation scales must come from *data*.  ``Calibrator``
accumulates running |x|-max ranges per named site over however many
sample batches the caller feeds it, then hands back per-site scales the
quantized execution paths consume via ``quantize_acts(x, scale=...)`` —
so serving quantizes against frozen calibrated ranges instead of
re-deriving them per batch (which would make kernels data-dependent and
decode nondeterministic).

Ranges serialize to/from plain dicts so a calibration can ride along a
``NetworkPlan`` JSON artifact.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from repro.quant.quantize import MIN_SCALE, QuantizedTensor, qmax, quantize_acts


class Calibrator:
    """Running per-site activation ranges (symmetric |x|-max)."""

    def __init__(self, momentum: Optional[float] = None):
        """``momentum=None`` keeps the running max (worst case over all
        observed batches); ``momentum=m`` keeps an EMA
        ``m * old + (1-m) * batch`` (smoother, outlier-tolerant)."""
        self.momentum = momentum
        self._amax: Dict[str, float] = {}
        self._batches: Dict[str, int] = {}

    def observe(self, site: str, x: jnp.ndarray) -> None:
        batch = float(jnp.max(jnp.abs(x.astype(jnp.float32))))
        old = self._amax.get(site)
        if old is None or self.momentum is None:
            new = batch if old is None else max(old, batch)
        else:
            new = self.momentum * old + (1.0 - self.momentum) * batch
        self._amax[site] = new
        self._batches[site] = self._batches.get(site, 0) + 1

    def sites(self):
        return sorted(self._amax)

    def amax(self, site: str) -> float:
        return self._amax[site]

    def scale(self, site: str, *, bits: int = 8) -> float:
        """The frozen quantization scale for ``site`` at ``bits`` width."""
        if site not in self._amax:
            raise KeyError(f"site {site!r} was never observed; "
                           f"have {self.sites()}")
        return max(self._amax[site], MIN_SCALE) / qmax(bits)

    def quantize(self, site: str, x: jnp.ndarray, *,
                 bits: int = 8) -> QuantizedTensor:
        """Quantize against the calibrated (not the batch) range."""
        return quantize_acts(x, bits=bits, scale=self.scale(site, bits=bits))

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        return {"momentum": self.momentum,
                "amax": dict(self._amax),
                "batches": dict(self._batches)}

    @classmethod
    def from_dict(cls, d: dict) -> "Calibrator":
        cal = cls(momentum=d.get("momentum"))
        cal._amax = {k: float(v) for k, v in d.get("amax", {}).items()}
        cal._batches = {k: int(v) for k, v in d.get("batches", {}).items()}
        return cal
