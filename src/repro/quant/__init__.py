"""Fixed-point precision subsystem — quantization as a *planned*,
per-site dimension (promoted from ``core/quantize.py``).

Layout:

* ``quantize``  — symmetric intN quantize/dequantize core + error metric
* ``calibrate`` — activation ranges from sample batches
* ``ops``       — quantized execution per plannable family
* ``report``    — per-site quantization-error reports

The planning half (the precision *ladder*) lives in ``core/plan.py``:
``SiteSpec.ladder`` declares the widths a site may drop to, and the
network planner descends it before declaring a site infeasible.  See
docs/adaptive_ips.md, "Precision contract".
"""
from repro.quant.calibrate import Calibrator
from repro.quant.ops import (quantized_activation, quantized_conv2d,
                             quantized_matmul, quantized_pool2d)
from repro.quant.quantize import (MIN_SCALE, QuantizedTensor, dequantize,
                                  fake_quant, int8_matmul, qmax,
                                  quantization_error, quantize_acts,
                                  quantize_weights)
from repro.quant.report import (SiteQuantReport, max_rel_error,
                                relative_error, summarize)

__all__ = [
    "Calibrator", "MIN_SCALE", "QuantizedTensor", "SiteQuantReport",
    "dequantize", "fake_quant", "int8_matmul", "max_rel_error", "qmax",
    "quantization_error", "quantize_acts", "quantize_weights",
    "quantized_activation", "quantized_conv2d", "quantized_matmul",
    "quantized_pool2d", "relative_error", "summarize",
]
