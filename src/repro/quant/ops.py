"""Per-family quantized execution paths — every plannable compute family
(conv2d, pool2d, activation, matmul) at a planned operand width.

Each function takes float operands, quantizes them to ``bits``, runs the
family's selected kernel IP, and returns a *float* result:

* ``bits == 8`` — the true integer path: int8 codes into the kernel,
  int32 accumulation, f32 rescale (linear families carry the combined
  scale out of the accumulator; per-channel weight scales for conv and
  matmul).
* ``8 < bits < 32`` — *fake-quant*: operands are snapped to the intN
  grid but arithmetic stays float, because int32 lanes cannot accumulate
  true int16 products without overflow (the paper's FPGA DSPs had 48-bit
  accumulators; the TPU adaptation is recorded in the precision
  contract, docs/adaptive_ips.md).  Footprint pricing still credits the
  narrower operands — that is the resource the ladder trades for.

These are the building blocks ``models/blocks.py`` composes into
mixed-precision networks (where quantize/dequantize boundaries are
inserted only where adjacent sites disagree) and that the
``kernels/<family>/ops.py`` wrappers invoke when the planner lowers a
``budget=``-path call site.

``attention`` and ``ssm_scan`` have no integer kernels and are marked
``quantizable=False`` in the library — the planner never lowers them.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.quant.quantize import (QuantizedTensor, dequantize, fake_quant,
                                  quantize_acts, quantize_weights)


def _check_bits(bits: int) -> None:
    if not 2 <= bits < 32:
        raise ValueError(f"quantized execution expects a lowered width "
                         f"(2..31 bits); got {bits}")


def quantized_conv2d(x: jnp.ndarray, w: jnp.ndarray, *, bits: int = 8,
                     ip: Optional[str] = None, interpret: bool = True,
                     act_scale: Optional[jnp.ndarray] = None,
                     return_scale: bool = False):
    """conv2d with operands quantized to ``bits``; f32 result.

    Weights are quantized per output channel (last axis of the
    (KH, KW, Cin, Cout) tensor); activations per-tensor, optionally at a
    calibrated ``act_scale``.

    ``return_scale=True`` returns ``(result, scale)`` instead of
    dequantizing: for the true-int8 path that is the raw int32
    accumulator plus its (1, 1, 1, Cout) scale, letting a caller fuse
    the dequantize into the next fixed-point stage
    (models/blocks.py::apply_cnn_block); fake-quant widths return
    ``(float result, None)``.
    """
    _check_bits(bits)
    from repro.kernels.conv2d.ops import conv2d
    if bits == 8:
        xq = quantize_acts(x, bits=8, scale=act_scale)
        wq = quantize_weights(w, axis=-1, bits=8)
        acc = conv2d(xq.q, wq.q, ip=ip, interpret=interpret)
        scale = xq.scale * wq.scale.reshape(1, 1, 1, -1)
        if return_scale:
            return acc, scale
        return acc.astype(jnp.float32) * scale
    y = conv2d(fake_quant(x, bits=bits), fake_quant(w, bits=bits, axis=-1),
               ip=ip, interpret=interpret)
    return (y, None) if return_scale else y


def quantized_pool2d(x: jnp.ndarray, *, window=(2, 2), stride=None,
                     mode: str = "max", bits: int = 8,
                     ip: Optional[str] = None, interpret: bool = True,
                     act_scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """pool2d over intN codes; f32 result.

    Pooling is scale-equivariant (max exactly; avg up to the integer
    floor-division of the family contract), so the input's quantization
    scale carries straight through the pooled codes.
    """
    _check_bits(bits)
    from repro.kernels.pool2d.ops import pool2d
    if bits == 8:
        xq = quantize_acts(x, bits=8, scale=act_scale)
        y = pool2d(xq.q, window=window, stride=stride, mode=mode, ip=ip,
                   interpret=interpret)
        return y.astype(jnp.float32) * xq.scale
    return pool2d(fake_quant(x, bits=bits), window=window, stride=stride,
                  mode=mode, ip=ip, interpret=interpret)


def quantized_activation(x: jnp.ndarray, *, kind: str = "relu",
                         bits: int = 8, ip: Optional[str] = None,
                         interpret: bool = True,
                         act_scale: Optional[jnp.ndarray] = None
                         ) -> jnp.ndarray:
    """Activation evaluated on the intN-quantized input grid; f32 result.

    The nonlinearity itself runs on dequantized values (a table over at
    most 2^bits distinct inputs); if the selected member is the LUT IP
    it re-quantizes internally to its own 256-level range — both errors
    are bounded and reported per site.
    """
    _check_bits(bits)
    from repro.kernels.activation.ops import activation
    xq = quantize_acts(x, bits=bits, scale=act_scale)
    return activation(dequantize(xq), kind=kind, ip=ip, interpret=interpret)


def quantized_fused_cnn_block(x: jnp.ndarray, w: jnp.ndarray, *,
                              pool_window=(2, 2), pool_stride=None,
                              pool_mode: str = "max",
                              activation: str = "relu", bits: int = 8,
                              ip: Optional[str] = None,
                              interpret: bool = True,
                              act_scale: Optional[jnp.ndarray] = None
                              ) -> jnp.ndarray:
    """Fused conv->pool->act with operands quantized to ``bits``; f32
    result.

    The int8 rung is the fused counterpart of the PR 3 mixed-precision
    chain: int8 codes enter the ONE launch, the int32 conv accumulator
    is rescaled by the combined (activation x per-channel weight) scale
    *in register*, and pooling + activation run on the rescaled tile —
    no intermediate fixed-point codes are materialized and the block
    performs no extra dequantize launch.  Wider lowered widths
    fake-quant the operands and run the float kernel.
    """
    _check_bits(bits)
    from repro.kernels.fused.ops import fused_cnn_block, resolve_member
    if bits == 8:
        xq = quantize_acts(x, bits=8, scale=act_scale)
        wq = quantize_weights(w, axis=-1, bits=8)
        scale = (xq.scale * wq.scale).reshape(1, 1, 1, -1)
        member = resolve_member(ip or "fused_vpu")
        return member(xq.q, wq.q, scale,
                      pool_window=tuple(pool_window),
                      pool_stride=pool_stride,
                      pool_mode=pool_mode, act_kind=activation,
                      interpret=interpret)
    return fused_cnn_block(fake_quant(x, bits=bits),
                           fake_quant(w, bits=bits, axis=-1),
                           pool_window=pool_window, pool_stride=pool_stride,
                           pool_mode=pool_mode, activation=activation,
                           ip=ip, interpret=interpret)


def quantized_matmul(a: jnp.ndarray, b: jnp.ndarray, *, bits: int = 8,
                     ip: Optional[str] = None, interpret: bool = True,
                     act_scale: Optional[jnp.ndarray] = None,
                     **tile_kwargs) -> jnp.ndarray:
    """a @ b with operands quantized to ``bits``; f32 result.

    ``b`` (the weight side) is quantized per output column; int8 runs the
    integer kernel (int32 accumulate), wider lowered widths fake-quant.
    """
    _check_bits(bits)
    from repro.kernels.matmul.ops import matmul
    if bits == 8:
        aq = quantize_acts(a, bits=8, scale=act_scale)
        bq = quantize_weights(b, axis=-1, bits=8)
        acc = matmul(aq.q, bq.q, ip=ip, interpret=interpret, **tile_kwargs)
        scale = aq.scale * bq.scale.reshape(1, -1)
        return acc.astype(jnp.float32) * scale
    return matmul(fake_quant(a, bits=bits), fake_quant(b, bits=bits, axis=-1),
                  ip=ip, interpret=interpret, **tile_kwargs)
