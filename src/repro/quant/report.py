"""Per-site quantization-error reporting.

A lowered site trades precision for resources; this module is where the
trade is *measured*.  ``apply_cnn_block`` (models/blocks.py) threads a
report dict through execution and records, for every site it runs, the
relative error of the (possibly quantized) site output against the
family oracle evaluated in float32 — so a mixed-precision plan ships
with the evidence of what each lowering cost.  ``benchmarks/run.py``'s
``table_precision`` aggregates these into the f32-vs-ladder comparison
columns, and ``summarize`` renders them for the examples.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SiteQuantReport:
    """One site's measured precision outcome."""

    site: str
    precision_bits: int
    rel_error: float        # ||got - ref|| / ||ref|| vs the f32 oracle

    @property
    def lowered(self) -> bool:
        return self.precision_bits < 32


def relative_error(got: jnp.ndarray, ref: jnp.ndarray) -> float:
    """Relative Frobenius error, guarded for an all-zero reference."""
    got = got.astype(jnp.float32)
    ref = ref.astype(jnp.float32)
    return float(jnp.linalg.norm(got - ref) / (jnp.linalg.norm(ref) + 1e-12))


def record(report: Dict[str, SiteQuantReport], site: str, bits: int,
           got: jnp.ndarray, ref: jnp.ndarray) -> None:
    report[site] = SiteQuantReport(site=site, precision_bits=bits,
                                   rel_error=relative_error(got, ref))


def max_rel_error(report: Dict[str, SiteQuantReport], *,
                  lowered_only: bool = True) -> float:
    """Worst per-site error in the report (0.0 when nothing qualifies)."""
    errs = [r.rel_error for r in report.values()
            if r.lowered or not lowered_only]
    return max(errs, default=0.0)


def summarize(report: Dict[str, SiteQuantReport]) -> str:
    lines = []
    for name in sorted(report):
        r = report[name]
        mark = f"int{r.precision_bits}" if r.lowered else "f32"
        lines.append(f"{name:<40s} {mark:<6s} rel_err={r.rel_error:.2e}")
    return "\n".join(lines)
