"""Symmetric fixed-point quantization — the paper's arithmetic discipline
as a first-class subsystem.

The paper's IPs are defined as much by their operand width as by their
compute style: 8-bit fixed-point data is what lets Conv3 pack two
multiplies per DSP slice.  This module is the numeric core of that
discipline, generalized beyond matmul (see ``quant/ops.py`` for the
per-family execution paths and ``core/plan.py`` for the precision
*ladder* that makes operand width a planned, per-site decision):

* ``quantize_weights`` — symmetric per-output-channel intN quantization;
* ``quantize_acts`` — symmetric per-tensor intN quantization, optionally
  against a calibrated scale (``quant/calibrate.py``);
* ``dequantize`` / ``fake_quant`` — the inverse map and the
  quantize-then-dequantize round trip (how 16-bit sites execute: int32
  lanes cannot accumulate true int16 products without overflow, so
  sub-32-bit-but-not-8-bit sites run *fake-quant* — quantized operands,
  float arithmetic — while 8-bit sites run the true integer kernels);
* ``quantization_error`` — relative round-trip error, the per-site
  diagnostic the reports in ``quant/report.py`` aggregate.

All scales are guarded by ``MIN_SCALE``: an all-zero tensor quantizes to
all-zero codes with a tiny-but-finite scale, so dequantization is exact
(zero) instead of NaN.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

# Floor for every quantization scale.  Without it an all-zero tensor
# yields scale == 0 and 0 * inf = NaN on the dequantize side; with it
# zeros round-trip exactly (0 / MIN_SCALE rounds to code 0).
MIN_SCALE = 1e-8

_CODE_DTYPES = {8: jnp.int8, 16: jnp.int16}


def qmax(bits: int) -> int:
    """Largest symmetric code at ``bits`` width (127 for int8)."""
    return (1 << (bits - 1)) - 1


def code_dtype(bits: int):
    if bits not in _CODE_DTYPES:
        raise ValueError(f"unsupported quantization width {bits}; "
                         f"have {sorted(_CODE_DTYPES)}")
    return _CODE_DTYPES[bits]


class QuantizedTensor(NamedTuple):
    q: jnp.ndarray          # intN payload
    scale: jnp.ndarray      # f32; () per-tensor or broadcastable per-channel


def quantize_weights(w: jnp.ndarray, *, axis: int = -1,
                     bits: int = 8) -> QuantizedTensor:
    """Symmetric per-output-channel intN quantization."""
    m = qmax(bits)
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=tuple(
        i for i in range(w.ndim) if i != (axis % w.ndim)), keepdims=True)
    scale = jnp.maximum(amax, MIN_SCALE) / m
    q = jnp.clip(jnp.round(w / scale), -m, m).astype(code_dtype(bits))
    return QuantizedTensor(q, scale.astype(jnp.float32))


def quantize_acts(x: jnp.ndarray, *, bits: int = 8,
                  scale: Optional[jnp.ndarray] = None) -> QuantizedTensor:
    """Symmetric per-tensor intN activation quantization.

    ``scale`` overrides the batch statistic with a calibrated value
    (``quant/calibrate.py``) so serving does not re-derive ranges per
    batch; codes saturate at the calibrated range.
    """
    m = qmax(bits)
    if scale is None:
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
        scale = jnp.maximum(amax, MIN_SCALE) / m
    scale = jnp.asarray(scale, jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -m, m).astype(code_dtype(bits))
    return QuantizedTensor(q, scale)


def dequantize(qt: QuantizedTensor) -> jnp.ndarray:
    return qt.q.astype(jnp.float32) * qt.scale


def fake_quant(x: jnp.ndarray, *, bits: int = 8, axis: Optional[int] = None
               ) -> jnp.ndarray:
    """Quantize-then-dequantize: the float tensor snapped to the intN
    grid.  Per-channel over ``axis`` when given, per-tensor otherwise.
    This is how non-8-bit lowered sites execute (see module docstring)."""
    if axis is None:
        return dequantize(quantize_acts(x, bits=bits))
    return dequantize(quantize_weights(x, axis=axis, bits=bits))


def int8_matmul(x: jnp.ndarray, wq: QuantizedTensor, *,
                use_kernel: bool = False) -> jnp.ndarray:
    """y = x @ dequant(wq): int8 x int8 -> int32 accumulate, f32 rescale.

    ``use_kernel=True`` routes through the Pallas mm_mxu int8 kernel
    (interpret mode on CPU); otherwise the jnp twin lowers the same
    int32-accumulation contraction.
    """
    xq = quantize_acts(x)
    if use_kernel:
        from repro.kernels.matmul.mxu import mm_mxu
        acc = mm_mxu(xq.q.reshape(-1, xq.q.shape[-1]), wq.q)
        acc = acc.reshape(x.shape[:-1] + (wq.q.shape[-1],))
    else:
        acc = jnp.einsum("...k,kn->...n", xq.q.astype(jnp.int32),
                         wq.q.astype(jnp.int32))
    out_scale = xq.scale * wq.scale.reshape(
        (1,) * (acc.ndim - 1) + (-1,))
    return acc.astype(jnp.float32) * out_scale


def quantization_error(x: jnp.ndarray, *, axis: Optional[int] = -1,
                       bits: int = 8) -> float:
    """Relative Frobenius error of the intN round trip (diagnostic).

    ``axis`` selects per-channel scales (weights); ``axis=None`` uses a
    per-tensor scale (activations).
    """
    deq = fake_quant(x, bits=bits, axis=axis)
    x = x.astype(jnp.float32)
    return float(jnp.linalg.norm(deq - x) / (jnp.linalg.norm(x) + 1e-12))
