"""Unified model API over decoder-only and encoder-decoder stacks.

  init_params / init_params_abstract
  loss_fn(cfg, params, batch)
  train_step(cfg, opt_cfg, state, batch)       TrainState -> TrainState
  prefill_step / decode_step
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer
from repro.optim.adamw import (AdamWConfig, OptState, apply_updates,
                               init_opt_state)


def _mod(cfg: ModelConfig):
    return encdec if cfg.family == "encdec" else transformer


def init_params(cfg: ModelConfig, key):
    return _mod(cfg).init_params(cfg, key)


def init_params_abstract(cfg: ModelConfig):
    return _mod(cfg).init_params_abstract(cfg)


def loss_fn(cfg: ModelConfig, params, batch):
    return _mod(cfg).loss_fn(cfg, params, batch)


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, key) -> TrainState:
    params = init_params(cfg, key)
    return TrainState(params, init_opt_state(opt_cfg, params))


def init_train_state_abstract(cfg: ModelConfig, opt_cfg: AdamWConfig):
    return jax.eval_shape(
        lambda: init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0)))


def train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, state: TrainState,
               batch):
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True)(state.params)
    params, opt, opt_metrics = apply_updates(opt_cfg, state.params, grads,
                                             state.opt)
    metrics = dict(metrics, loss=loss, **opt_metrics)
    return TrainState(params, opt), metrics


def prefill_step(cfg: ModelConfig, params, batch, *, pad_to=None):
    return _mod(cfg).prefill(cfg, params, batch, pad_to=pad_to)


def decode_step(cfg: ModelConfig, params, caches, tokens, pos):
    return _mod(cfg).decode_step(cfg, params, caches, tokens, pos)


def init_decode_caches(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family == "encdec":
        L = cfg.n_layers
        cd = cfg.dtype("compute")
        Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
        return {"k": jnp.zeros((L, batch, max_len, Hkv, Dh), cd),
                "v": jnp.zeros((L, batch, max_len, Hkv, Dh), cd),
                "xk": jnp.zeros((L, batch, max_len, Hkv, Dh), cd),
                "xv": jnp.zeros((L, batch, max_len, Hkv, Dh), cd)}
    return transformer.init_decode_caches(cfg, batch, max_len)
