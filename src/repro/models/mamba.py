"""Mamba-1 selective SSM block (jamba's mamba sublayers).

Sequential selective scan (lax.scan over time) carrying h (B, d_inner,
d_state); y_t is produced on the fly so the (d_inner x d_state) state is
never materialized across time — the standard memory-sane JAX
formulation (the fused-kernel trick, expressed with scan).

Decode carries (conv_state, ssm_state) — O(1) in sequence length.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig


def init_mamba(cfg: ModelConfig, key, shape_prefix=()):
    mc = cfg.mamba
    D, di, ds, dtr = cfg.d_model, cfg.d_inner, mc.d_state, cfg.dt_rank
    pd = cfg.dtype("param")
    ks = jax.random.split(key, 6)
    s = D ** -0.5
    return {
        "in_proj": (jax.random.normal(ks[0], shape_prefix + (D, 2 * di)) * s).astype(pd),
        "conv_w": (jax.random.normal(ks[1], shape_prefix + (mc.d_conv, di))
                   * mc.d_conv ** -0.5).astype(pd),
        "conv_b": jnp.zeros(shape_prefix + (di,), pd),
        "x_proj": (jax.random.normal(ks[2], shape_prefix + (di, dtr + 2 * ds))
                   * di ** -0.5).astype(pd),
        "dt_proj": (jax.random.normal(ks[3], shape_prefix + (dtr, di))
                    * dtr ** -0.5).astype(pd),
        "dt_bias": jnp.full(shape_prefix + (di,), -4.6, pd),  # softplus ~ 0.01
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, ds + 1, dtype=jnp.float32)),
            shape_prefix + (di, ds)).astype(pd),
        "D": jnp.ones(shape_prefix + (di,), pd),
        "out_proj": (jax.random.normal(ks[5], shape_prefix + (di, D))
                     * di ** -0.5).astype(pd),
    }


def _ssm_inputs(cfg: ModelConfig, p, x1):
    """x1: (..., di) post-conv activations -> (dt, B, C) selective params."""
    mc = cfg.mamba
    ds, dtr = mc.d_state, cfg.dt_rank
    cd = cfg.dtype("compute")
    xdb = jnp.einsum("...i,ij->...j", x1.astype(cd), p["x_proj"].astype(cd))
    dt, Bp, Cp = jnp.split(xdb, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("...r,ri->...i", dt, p["dt_proj"].astype(cd)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    return dt, Bp.astype(jnp.float32), Cp.astype(jnp.float32)


def _mamba_core(cfg: ModelConfig, p, x):
    mc = cfg.mamba
    di = cfg.d_inner
    cd = cfg.dtype("compute")
    B, S, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x.astype(cd), p["in_proj"].astype(cd))
    x1_raw, z = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv over time
    xpad = jnp.pad(x1_raw, ((0, 0), (mc.d_conv - 1, 0), (0, 0)))
    x1 = sum(xpad[:, i:i + S, :] * p["conv_w"][i].astype(cd)
             for i in range(mc.d_conv)) + p["conv_b"].astype(cd)
    x1 = jax.nn.silu(x1)
    dt, Bp, Cp = _ssm_inputs(cfg, p, x1)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (di, ds)
    x1f = x1.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp                            # (B,di),(B,di),(B,ds),(B,ds)
        dA = jnp.exp(dt_t[..., None] * A[None])              # (B,di,ds)
        dBx = (dt_t * x_t)[..., None] * B_t[:, None, :]      # (B,di,ds)
        h = dA * h + dBx
        y = jnp.einsum("bis,bs->bi", h, C_t)
        return h, y

    h0 = jnp.zeros((B, di, mc.d_state), jnp.float32)
    xs = (x1f.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          Bp.transpose(1, 0, 2), Cp.transpose(1, 0, 2))
    h_final, ys = lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + x1f * p["D"].astype(jnp.float32)
    y = (y.astype(cd) * jax.nn.silu(z))
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(cd))
    return out, x1_raw, h_final


def mamba_forward(cfg: ModelConfig, p, x) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D)."""
    return _mamba_core(cfg, p, x)[0]


def mamba_forward_with_cache(cfg: ModelConfig, p, x):
    """Forward + decode cache (conv tail of raw in-proj acts, final h)."""
    mc = cfg.mamba
    out, x1_raw, h_final = _mamba_core(cfg, p, x)
    tail = x1_raw[:, x.shape[1] - (mc.d_conv - 1):, :]
    return out, {"conv": tail, "ssm": h_final}


# ---------------------------------------------------------------------------
# Decode (single token)
# ---------------------------------------------------------------------------
def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=None):
    mc = cfg.mamba
    dt = dtype or cfg.dtype("compute")
    return {"conv": jnp.zeros((batch, mc.d_conv - 1, cfg.d_inner), dt),
            "ssm": jnp.zeros((batch, cfg.d_inner, mc.d_state), jnp.float32)}


def mamba_step(cfg: ModelConfig, p, x, cache) -> Tuple[jnp.ndarray, dict]:
    """x: (B, 1, D); cache {'conv': (B, d_conv-1, di), 'ssm': (B, di, ds)}."""
    mc = cfg.mamba
    cd = cfg.dtype("compute")
    B = x.shape[0]
    xz = jnp.einsum("bsd,de->bse", x.astype(cd), p["in_proj"].astype(cd))
    x1, z = jnp.split(xz[:, 0], 2, axis=-1)                  # (B, di)
    window = jnp.concatenate([cache["conv"], x1[:, None, :]], axis=1)
    new_conv = window[:, 1:, :]
    x1 = sum(window[:, i, :] * p["conv_w"][i].astype(cd)
             for i in range(mc.d_conv)) + p["conv_b"].astype(cd)
    x1 = jax.nn.silu(x1)
    dt, Bp, Cp = _ssm_inputs(cfg, p, x1)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[..., None] * A[None])
    dBx = (dt * x1.astype(jnp.float32))[..., None] * Bp[:, None, :]
    h = dA * cache["ssm"] + dBx
    y = jnp.einsum("bis,bs->bi", h, Cp) + x1.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y.astype(cd) * jax.nn.silu(z)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"].astype(cd))[:, None, :]
    return out, {"conv": new_conv, "ssm": h}
