"""Shared model blocks: norms, MLPs, embeddings, RoPE.

Functional style: ``init_*`` returns a param pytree (plain dicts of
jnp arrays), ``apply`` functions are pure.  Layer-stacked params carry a
leading group axis for lax.scan (see transformer.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, shape_prefix=()):
    pd = cfg.dtype("param")
    if cfg.norm == "layernorm_nonparam":
        return {}  # OLMo: no learnable scale/bias
    p = {"scale": jnp.ones(shape_prefix + (cfg.d_model,), pd)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros(shape_prefix + (cfg.d_model,), pd)
    return p


def apply_norm(cfg: ModelConfig, p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if cfg.norm == "layernorm":
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------
def init_ffn(cfg: ModelConfig, key, shape_prefix=(), d_in=None, d_ff=None):
    pd = cfg.dtype("param")
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    scale = d ** -0.5
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "w_gate": (jax.random.normal(k1, shape_prefix + (d, f)) * scale).astype(pd),
            "w_up": (jax.random.normal(k2, shape_prefix + (d, f)) * scale).astype(pd),
            "w_down": (jax.random.normal(k3, shape_prefix + (f, d)) * f ** -0.5).astype(pd),
        }
    return {
        "w_in": (jax.random.normal(k1, shape_prefix + (d, f)) * scale).astype(pd),
        "w_down": (jax.random.normal(k3, shape_prefix + (f, d)) * f ** -0.5).astype(pd),
    }


def apply_ffn(cfg: ModelConfig, p, x):
    cd = cfg.dtype("compute")
    x = x.astype(cd)
    if cfg.activation in ("swiglu", "geglu"):
        g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(cd))
        u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(cd))
        act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
        h = act(g) * u
    else:
        h = jnp.einsum("...d,df->...f", x, p["w_in"].astype(cd))
        h = jax.nn.gelu(h) if cfg.activation == "gelu" else jnp.square(jax.nn.relu(h))
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(cd))


# ---------------------------------------------------------------------------
# Embeddings / head
# ---------------------------------------------------------------------------
def init_embed(cfg: ModelConfig, key):
    pd = cfg.dtype("param")
    k1, k2 = jax.random.split(key)
    p = {"embed": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model))
                   * cfg.d_model ** -0.5).astype(pd)}
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(k2, (cfg.d_model, cfg.vocab_size))
                        * cfg.d_model ** -0.5).astype(pd)
    return p


def embed_tokens(cfg: ModelConfig, p, tokens):
    return jnp.take(p["embed"], tokens, axis=0).astype(cfg.dtype("compute"))


def lm_logits(cfg: ModelConfig, p, x):
    cd = cfg.dtype("compute")
    w = (p["embed"].T if cfg.tie_embeddings else p["lm_head"]).astype(cd)
    return jnp.einsum("...d,dv->...v", x.astype(cd), w).astype(cfg.dtype("logit"))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(cfg: ModelConfig, positions):
    """positions: (...,) int32 -> cos/sin (..., rot_dim/2)."""
    rot = cfg.head_dim if cfg.rope_style == "full" else cfg.head_dim // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(cfg: ModelConfig, x, cos, sin):
    """x: (B, S, H, Dh); cos/sin: (B, S, rot/2) or (S, rot/2)."""
    if cfg.rope_style == "none":
        return x
    rot = cfg.head_dim if cfg.rope_style == "full" else cfg.head_dim // 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1 = xr[..., 0::2]
    x2 = xr[..., 1::2]
    while cos.ndim < x1.ndim:  # broadcast over head axis
        cos = cos[..., None, :]
        sin = sin[..., None, :]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1) if rot < cfg.head_dim else out


# ---------------------------------------------------------------------------
# CNN block — conv -> pool -> activation, planned as one NetworkPlan: the
# three sites share ONE ResourceBudget *partitioned* across them (the
# paper's full-layer scenario: a CNN layer whose implementation adapts to
# available resources while its math stays fixed).
# ---------------------------------------------------------------------------
def init_cnn_block(key, cin: int, cout: int, k: int = 3,
                   dtype=jnp.float32):
    scale = (k * k * cin) ** -0.5
    return {"w": (jax.random.normal(key, (k, k, cin, cout)) * scale
                  ).astype(dtype)}


def cnn_block_site_specs(x_shape, w_shape, *, x_dtype, w_dtype=None,
                         pool_window=(2, 2), pool_stride=None,
                         pool_mode: str = "max", activation: str = "relu",
                         site: str = "cnn_block", ladder=()):
    """Declarative op sites of one conv -> pool -> act block.

    Intermediate shapes/dtypes come from the family oracles via
    ``jax.eval_shape`` (abstract, no FLOPs), so the specs always agree
    with what the kernels will actually produce.  Returns
    ``(specs, out_aval)`` — the latter lets a caller chain blocks into a
    single whole-network plan (see models/frontends.py).

    ``ladder`` (e.g. ``(16, 8)``) attaches the same precision ladder to
    all three sites: the planner may quantize any of them below native
    width when the budget demands it (docs/adaptive_ips.md, "Precision
    contract").
    """
    import functools

    from repro.core.ip import SiteSpec
    from repro.kernels.activation.ref import activation_ref
    from repro.kernels.conv2d.ref import conv2d_ref
    from repro.kernels.pool2d.ref import pool2d_ref

    x_aval = jax.ShapeDtypeStruct(tuple(x_shape), jnp.dtype(x_dtype))
    w_aval = jax.ShapeDtypeStruct(tuple(w_shape),
                                  jnp.dtype(w_dtype or x_dtype))
    conv_aval = jax.eval_shape(conv2d_ref, x_aval, w_aval)
    pool_aval = jax.eval_shape(
        functools.partial(pool2d_ref, window=pool_window, stride=pool_stride,
                          mode=pool_mode), conv_aval)
    act_aval = jax.eval_shape(
        functools.partial(activation_ref, kind=activation), pool_aval)
    specs = [
        SiteSpec.make(f"{site}.conv", "conv2d", (x_aval.shape, w_aval.shape),
                      x_aval.dtype, ladder=ladder, dual=False),
        SiteSpec.make(f"{site}.pool", "pool2d", (conv_aval.shape,),
                      conv_aval.dtype, ladder=ladder, window=pool_window,
                      stride=pool_stride, mode=pool_mode),
        SiteSpec.make(f"{site}.act", "activation", (pool_aval.shape,),
                      pool_aval.dtype, ladder=ladder, kind=activation),
    ]
    return specs, act_aval


def _apply_fused_site(fused_s, p, x, *, pool_window, pool_stride, pool_mode,
                      activation, interpret, plan, quant_report,
                      tile_overrides):
    """Execute one planned fused site: the whole conv -> pool -> act
    chain in a single launch.  The lowered rungs run the quantized fused
    kernel (int8: in-register rescale of the int32 accumulator);
    ``quant_report`` measures the one fused output against the composite
    family oracle."""
    if plan is not None:
        plan[fused_s.spec.name] = (fused_s.ip, fused_s.footprint)
    tile_kwargs = dict((tile_overrides or {}).get(fused_s.spec.name, {}))
    if fused_s.lowered:
        from repro.quant.ops import quantized_fused_cnn_block
        y = quantized_fused_cnn_block(
            x, p["w"], pool_window=pool_window, pool_stride=pool_stride,
            pool_mode=pool_mode, activation=activation,
            bits=fused_s.precision_bits, ip=fused_s.ip.name,
            interpret=interpret)
    else:
        from repro.kernels.fused.ops import fused_cnn_block
        y = fused_cnn_block(x, p["w"], pool_window=pool_window,
                            pool_stride=pool_stride, pool_mode=pool_mode,
                            activation=activation, ip=fused_s.ip.name,
                            interpret=interpret, **tile_kwargs)
    if quant_report is not None:
        from repro.core.library import get_family
        from repro.quant.report import record
        ref = get_family("cnn_fused").reference(
            x.astype(jnp.float32), p["w"].astype(jnp.float32),
            window=pool_window, stride=pool_stride, mode=pool_mode,
            kind=activation)
        record(quant_report, fused_s.spec.name, fused_s.precision_bits,
               y, ref)
    return y


def apply_cnn_block(p, x, *, budget=None, pool_window=(2, 2),
                    pool_stride=None, pool_mode: str = "max",
                    activation: str = "relu", interpret: bool = True,
                    plan=None, site: str = "cnn_block", network=None,
                    ladder=(), quant_report=None, tile_overrides=None,
                    fuse: bool = True):
    """One adaptive CNN layer: conv -> pool -> activation.

    The three sites are planned as one ``NetworkPlan`` under a
    partitioned ``budget`` (memoized — re-tracing the same shapes hits
    the plan cache with zero new selector evaluations), then each stage
    runs its planned Pallas kernel.  Pass ``network`` (a NetworkPlan
    containing this block's sites, e.g. one spanning a whole frontend)
    to execute from an outer plan instead.  When ``plan`` (a dict) is
    passed, the three (KernelIP, Footprint) decisions are recorded
    under ``site`` — renderable with ``describe_plan``.

    **Fusion.** ``fuse`` (default True) plans with fusion-aware substitution
    (``core.plan.plan_network(..., fuse=True)``): when the planner maps
    this block onto a single fused site (``<site>.fused``), the whole
    conv -> pool -> activation chain executes as ONE ``pallas_call``
    with no intermediate HBM round-trips — including the lowered rungs,
    where the int8 kernel rescales its int32 accumulator in register.
    Execution is plan-driven: a supplied ``network`` containing
    ``<site>.fused`` runs fused regardless of ``fuse``, and the planner
    falls back to the three-site chain whenever the fused footprint
    does not fit (docs/adaptive_ips.md, "Fusion contract").

    **Mixed precision.** With a ``ladder`` the planner may assign any
    site a lowered operand width; execution honors the plan with
    quantize/dequantize boundaries inserted only where adjacent sites
    disagree: an int8 conv feeds its (requantized) codes straight into
    an int8 pool, and an int8 relu runs on the codes too (relu commutes
    with the positive scale), so a fully-lowered block performs ONE
    dequantize at its egress.  ``quant_report`` (a dict) receives a
    ``SiteQuantReport`` per site — the measured relative error vs the
    family oracles evaluated in float32.

    ``tile_overrides`` maps site name -> tiling kwargs for that site's
    kernel call (e.g. ``{"cnn_block.conv": {"block_cout": 256}}`` from
    ``core.autotune.plan_tile_overrides``); only full-precision sites
    honor them — the quantized wrappers keep their members' defaults.
    """
    from repro.core.plan import plan_network
    from repro.kernels.activation.ops import activation as activation_op
    from repro.kernels.conv2d.ops import conv2d
    from repro.kernels.pool2d.ops import pool2d

    specs, _ = cnn_block_site_specs(
        x.shape, p["w"].shape, x_dtype=x.dtype, w_dtype=p["w"].dtype,
        pool_window=pool_window, pool_stride=pool_stride,
        pool_mode=pool_mode, activation=activation, site=site,
        ladder=ladder)
    if network is None:
        network = plan_network(specs, budget, fuse=fuse)
    else:
        # An outer plan was built from its own view of the graph; its
        # feasibility guarantees are void if that view disagrees with
        # this call's actual shapes/dtypes/knobs.
        from repro.core.library import get_family
        fused_view = get_family("cnn_fused").fuse_sites(tuple(specs))
        if f"{site}.fused" in network and fused_view is None:
            raise ValueError(
                f"plan/site mismatch at '{site}.fused': the supplied "
                f"network fused this block, but this call's sites "
                f"{[s.name for s in specs]} are not fusable")
        check = ([fused_view] if f"{site}.fused" in network else specs)
        for spec in check:
            planned = network.site(spec.name).spec
            if planned != spec:
                raise ValueError(
                    f"plan/site mismatch at {spec.name!r}: the supplied "
                    f"network was planned for {planned}, but this call "
                    f"executes {spec}")

    if f"{site}.fused" in network:
        return _apply_fused_site(
            network.site(f"{site}.fused"), p, x, pool_window=pool_window,
            pool_stride=pool_stride, pool_mode=pool_mode,
            activation=activation, interpret=interpret, plan=plan,
            quant_report=quant_report, tile_overrides=tile_overrides)

    conv_s = network.site(f"{site}.conv")
    pool_s = network.site(f"{site}.pool")
    act_s = network.site(f"{site}.act")
    if plan is not None:
        for s in (conv_s, pool_s, act_s):
            plan[s.spec.name] = (s.ip, s.footprint)

    if quant_report is not None:
        import functools

        from repro.kernels.activation.ref import activation_ref
        from repro.kernels.conv2d.ref import conv2d_ref
        from repro.kernels.pool2d.ref import pool2d_ref
        from repro.quant.report import record
        ref = conv2d_ref(x.astype(jnp.float32),
                         p["w"].astype(jnp.float32))
        pool_ref = functools.partial(pool2d_ref, window=pool_window,
                                     stride=pool_stride, mode=pool_mode)

    # qscale is not None  <=>  y holds fixed-point codes (or an integer
    # accumulator) whose real value is y * qscale.
    qscale = None

    # -- conv ---------------------------------------------------------------
    if conv_s.lowered:
        from repro.quant.ops import quantized_conv2d
        # int8 returns the raw accumulator + scale (the dequantize fuses
        # into the next stage); 16-bit fake-quant returns (float, None).
        y, qscale = quantized_conv2d(x, p["w"], bits=conv_s.precision_bits,
                                     ip=conv_s.ip.name, interpret=interpret,
                                     return_scale=True)
    else:
        y = conv2d(x, p["w"], ip=conv_s.ip.name, interpret=interpret,
                   **dict((tile_overrides or {}).get(conv_s.spec.name, {})))
    if quant_report is not None:
        got = y if qscale is None else y.astype(jnp.float32) * qscale
        record(quant_report, conv_s.spec.name, conv_s.precision_bits,
               got, ref)

    # -- pool ---------------------------------------------------------------
    if qscale is not None and pool_s.precision_bits == 8 and pool_s.lowered:
        # Adjacent int8 sites: requantize the int32 accumulator to int8
        # codes (the standard fixed-point interlayer step) and pool the
        # codes — no float boundary.
        from repro.quant.quantize import quantize_acts
        yq = quantize_acts(y.astype(jnp.float32) * qscale, bits=8)
        y = pool2d(yq.q, window=pool_window, stride=pool_stride,
                   mode=pool_mode, ip=pool_s.ip.name, interpret=interpret)
        qscale = yq.scale
    else:
        if qscale is not None:  # widths disagree: dequantize boundary
            y = y.astype(jnp.float32) * qscale
            qscale = None
        if pool_s.lowered:
            from repro.quant.ops import quantized_pool2d
            y = quantized_pool2d(y, window=pool_window, stride=pool_stride,
                                 mode=pool_mode,
                                 bits=pool_s.precision_bits,
                                 ip=pool_s.ip.name, interpret=interpret)
        else:
            y = pool2d(y, window=pool_window, stride=pool_stride,
                       mode=pool_mode, ip=pool_s.ip.name,
                       interpret=interpret)
    if quant_report is not None:
        ref = pool_ref(ref)
        got = y if qscale is None else y.astype(jnp.float32) * qscale
        record(quant_report, pool_s.spec.name, pool_s.precision_bits,
               got, ref)

    # -- activation ---------------------------------------------------------
    if (qscale is not None and act_s.lowered and activation == "relu"
            and act_s.precision_bits == pool_s.precision_bits):
        # relu(q * s) == relu(q) * s for s > 0: the activation runs on
        # the codes and the whole lowered chain dequantizes ONCE here.
        y = activation_op(y, kind="relu", ip=act_s.ip.name,
                          interpret=interpret)
        y = y * qscale
        qscale = None
    else:
        if qscale is not None:
            y = y.astype(jnp.float32) * qscale
            qscale = None
        if act_s.lowered:
            from repro.quant.ops import quantized_activation
            y = quantized_activation(y, kind=activation,
                                     bits=act_s.precision_bits,
                                     ip=act_s.ip.name, interpret=interpret)
        else:
            y = activation_op(y, kind=activation, ip=act_s.ip.name,
                              interpret=interpret)
    if quant_report is not None:
        from repro.kernels.activation.ref import activation_ref
        ref = activation_ref(ref, kind=activation)
        record(quant_report, act_s.spec.name, act_s.precision_bits, y, ref)
    return y


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def softmax_xent(logits, labels, *, z_loss: float = 1e-4):
    """Token-mean cross entropy (f32 accumulation) + z-loss regularizer."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss
