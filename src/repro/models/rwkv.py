"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

The headline Finch feature — the per-channel, per-token decay
w_t = exp(-exp(w0 + lora(x_t))) — is implemented faithfully.  The
token-shift interpolation uses static learned mix vectors (the LoRA
data-dependent *mixing* of full Finch is folded into the decay LoRA);
recorded as a simplification in DESIGN.md.

State per head is (head_size x head_size); decode is O(1) in sequence
length.  The recurrence runs as lax.scan over time (the chunked Pallas
kernel is a hillclimb candidate, not a baseline requirement).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig


def _heads(cfg: ModelConfig):
    hs = cfg.rwkv.head_size
    assert cfg.d_model % hs == 0
    return cfg.d_model // hs, hs


def init_rwkv_tm(cfg: ModelConfig, key, shape_prefix=()):
    D = cfg.d_model
    H, hs = _heads(cfg)
    r = cfg.rwkv.lora_rank_decay
    pd = cfg.dtype("param")
    ks = jax.random.split(key, 8)
    s = D ** -0.5
    mk = lambda i, shape, sc=s: (jax.random.normal(ks[i], shape_prefix + shape) * sc).astype(pd)
    return {
        "mix_r": jnp.full(shape_prefix + (D,), 0.5, pd),
        "mix_k": jnp.full(shape_prefix + (D,), 0.5, pd),
        "mix_v": jnp.full(shape_prefix + (D,), 0.5, pd),
        "mix_w": jnp.full(shape_prefix + (D,), 0.5, pd),
        "mix_g": jnp.full(shape_prefix + (D,), 0.5, pd),
        "w_r": mk(0, (D, D)), "w_k": mk(1, (D, D)), "w_v": mk(2, (D, D)),
        "w_g": mk(3, (D, D)), "w_o": mk(4, (D, D)),
        "w0": jnp.full(shape_prefix + (D,), -2.0, pd),
        "w_lora_a": mk(5, (D, r), 0.01), "w_lora_b": mk(6, (r, D), 0.01),
        "u": mk(7, (H, hs), 1.0),
    }


def init_rwkv_cm(cfg: ModelConfig, key, shape_prefix=()):
    D, F = cfg.d_model, cfg.d_ff
    pd = cfg.dtype("param")
    ks = jax.random.split(key, 3)
    s = D ** -0.5
    return {
        "mix_k": jnp.full(shape_prefix + (D,), 0.5, pd),
        "mix_r": jnp.full(shape_prefix + (D,), 0.5, pd),
        "w_k": (jax.random.normal(ks[0], shape_prefix + (D, F)) * s).astype(pd),
        "w_v": (jax.random.normal(ks[1], shape_prefix + (F, D)) * F ** -0.5).astype(pd),
        "w_r": (jax.random.normal(ks[2], shape_prefix + (D, D)) * s).astype(pd),
    }


def _shift(x, prev):
    """Token shift: x_{t-1} with `prev` (B, D) as the t=0 predecessor."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _decay(cfg, p, xw):
    lw = jnp.einsum("bsd,dr->bsr", xw, p["w_lora_a"].astype(xw.dtype))
    lw = jnp.einsum("bsr,rd->bsd", jnp.tanh(lw), p["w_lora_b"].astype(xw.dtype))
    return jnp.exp(-jnp.exp(p["w0"].astype(jnp.float32) + lw.astype(jnp.float32)))


def rwkv_time_mix(cfg: ModelConfig, p, x, prev_x, state):
    """x: (B,S,D); prev_x: (B,D); state: (B,H,hs,hs) f32.

    Returns (out, last_x, new_state)."""
    H, hs = _heads(cfg)
    cd = cfg.dtype("compute")
    B, S, D = x.shape
    xs = _shift(x, prev_x)
    mix = lambda m: x * p[m].astype(x.dtype) + xs * (1 - p[m].astype(x.dtype))
    xr, xk, xv, xw, xg = (mix("mix_r"), mix("mix_k"), mix("mix_v"),
                          mix("mix_w"), mix("mix_g"))
    proj = lambda t, w: jnp.einsum("bsd,de->bse", t.astype(cd),
                                   p[w].astype(cd)).reshape(B, S, H, hs)
    r, k, v = proj(xr, "w_r"), proj(xk, "w_k"), proj(xv, "w_v")
    g = jnp.einsum("bsd,de->bse", xg.astype(cd), p["w_g"].astype(cd))
    w = _decay(cfg, p, xw.astype(cd)).reshape(B, S, H, hs)   # (0,1) decay
    u = p["u"].astype(jnp.float32)

    def step(s_state, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,hs) each
        kv = k_t[..., :, None] * v_t[..., None, :]            # (B,H,hs,hs)
        y = jnp.einsum("bhi,bhij->bhj", r_t, u[None, :, :, None] * kv + s_state)
        s_state = w_t[..., :, None] * s_state + kv
        return s_state, y

    seq = lambda t: t.astype(jnp.float32).transpose(1, 0, 2, 3)
    state, ys = lax.scan(step, state, (seq(r), seq(k), seq(v), seq(w)))
    y = ys.transpose(1, 0, 2, 3)                              # (B,S,H,hs)
    # per-head group norm
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 1e-5)
    y = y.reshape(B, S, D).astype(cd) * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", y, p["w_o"].astype(cd))
    return out, x[:, -1, :], state


def rwkv_channel_mix(cfg: ModelConfig, p, x, prev_x):
    cd = cfg.dtype("compute")
    xs = _shift(x, prev_x)
    mix = lambda m: x * p[m].astype(x.dtype) + xs * (1 - p[m].astype(x.dtype))
    xk, xr = mix("mix_k"), mix("mix_r")
    k = jnp.square(jax.nn.relu(
        jnp.einsum("bsd,df->bsf", xk.astype(cd), p["w_k"].astype(cd))))
    kv = jnp.einsum("bsf,fd->bsd", k, p["w_v"].astype(cd))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr.astype(cd),
                                  p["w_r"].astype(cd)))
    return r * kv, x[:, -1, :]


def init_rwkv_state(cfg: ModelConfig, batch: int):
    H, hs = _heads(cfg)
    D = cfg.d_model
    cd = cfg.dtype("compute")
    return {"tm_x": jnp.zeros((batch, D), cd),
            "cm_x": jnp.zeros((batch, D), cd),
            "state": jnp.zeros((batch, H, hs, hs), jnp.float32)}
