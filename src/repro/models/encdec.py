"""Encoder-decoder stack (seamless-m4t backbone).

Encoder: non-causal attention blocks over precomputed frame embeddings
(the modality frontend is a stub per the assignment).  Decoder: causal
self-attention + cross-attention to the encoder output + FFN.
Cross-attention K/V are computed once at prefill and frozen.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.blocks import (apply_ffn, apply_norm, embed_tokens,
                                 init_embed, init_ffn, init_norm, lm_logits,
                                 softmax_xent)
from repro.models.transformer import _sinusoidal


def _init_enc_block(cfg, key, prefix):
    k1, k2 = jax.random.split(key)
    return {"ln1": init_norm(cfg, prefix),
            "attn": attn_mod.init_attn(cfg, k1, prefix),
            "ln2": init_norm(cfg, prefix),
            "ffn": init_ffn(cfg, k2, prefix)}


def _init_dec_block(cfg, key, prefix):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": init_norm(cfg, prefix),
            "self_attn": attn_mod.init_attn(cfg, k1, prefix),
            "ln_x": init_norm(cfg, prefix),
            "cross_attn": attn_mod.init_attn(cfg, k2, prefix),
            "ln2": init_norm(cfg, prefix),
            "ffn": init_ffn(cfg, k3, prefix)}


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ke, kd, kemb = jax.random.split(key, 3)
    params = init_embed(cfg, kemb)
    params["enc_blocks"] = _init_enc_block(cfg, ke, (cfg.enc_layers,))
    params["dec_blocks"] = _init_dec_block(cfg, kd, (cfg.n_layers,))
    params["enc_norm"] = init_norm(cfg)
    params["final_norm"] = init_norm(cfg)
    return params


def init_params_abstract(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def encode(cfg: ModelConfig, params, embeds):
    """embeds: (B, S_enc, D) precomputed frame embeddings (stub frontend)."""
    B, S, _ = embeds.shape
    x = embeds.astype(cfg.dtype("compute"))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.pos_embed == "sinusoidal":
        x = x + _sinusoidal(cfg, positions)

    def body(x, p):
        h = apply_norm(cfg, p["ln1"], x)
        out, _ = attn_mod.attn_block(cfg, p["attn"], h, positions,
                                     causal=False)
        x = x + out.astype(x.dtype)
        h2 = apply_norm(cfg, p["ln2"], x)
        x = x + apply_ffn(cfg, p["ffn"], h2).astype(x.dtype)
        return x, None

    if cfg.remat in ("block", "block_dots"):
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat == "block"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        body = jax.checkpoint(body, policy=policy)
    if cfg.scan_layers:
        x, _ = lax.scan(body, x, params["enc_blocks"])
    else:
        for g in range(cfg.enc_layers):
            x, _ = body(x, jax.tree.map(lambda t: t[g], params["enc_blocks"]))
    return apply_norm(cfg, params["enc_norm"], x)


def _cross_attn(cfg, p, x, enc_out):
    """Full (non-cached) cross-attention: q from x, k/v from enc_out."""
    cd = cfg.dtype("compute")
    B, S, _ = x.shape
    Se = enc_out.shape[1]
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x.astype(cd),
                   p["wq"].astype(cd)).reshape(B, S, Hq, Dh)
    k = jnp.einsum("bsd,dh->bsh", enc_out.astype(cd),
                   p["wk"].astype(cd)).reshape(B, Se, Hkv, Dh)
    v = jnp.einsum("bsd,dh->bsh", enc_out.astype(cd),
                   p["wv"].astype(cd)).reshape(B, Se, Hkv, Dh)
    o = attn_mod.full_attention(cfg, q, k, v, causal=False)
    return attn_mod._merge_heads(cfg, p, o), k, v


def decode_full(cfg: ModelConfig, params, enc_out, tokens,
                collect_cache: bool = False):
    """Teacher-forced decoder pass. tokens: (B, S_dec)."""
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.pos_embed == "sinusoidal":
        x = x + _sinusoidal(cfg, positions)

    def body(x, p):
        h = apply_norm(cfg, p["ln1"], x)
        out, (k, v) = attn_mod.attn_block(cfg, p["self_attn"], h, positions,
                                          causal=True)
        x = x + out.astype(x.dtype)
        hx = apply_norm(cfg, p["ln_x"], x)
        out, ck, cv = _cross_attn(cfg, p["cross_attn"], hx, enc_out)
        x = x + out.astype(x.dtype)
        h2 = apply_norm(cfg, p["ln2"], x)
        x = x + apply_ffn(cfg, p["ffn"], h2).astype(x.dtype)
        cache = ({"k": k, "v": v, "xk": ck, "xv": cv}
                 if collect_cache else {})
        return x, cache

    if cfg.remat in ("block", "block_dots") and not collect_cache:
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat == "block"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        body = jax.checkpoint(body, policy=policy)
    if cfg.scan_layers:
        x, caches = lax.scan(body, x, params["dec_blocks"])
    else:
        outs = []
        for g in range(cfg.n_layers):
            x, c = body(x, jax.tree.map(lambda t: t[g], params["dec_blocks"]))
            outs.append(c)
        caches = (jax.tree.map(lambda *ts: jnp.stack(ts), *outs)
                  if collect_cache else None)
    x = apply_norm(cfg, params["final_norm"], x)
    return x, (caches if collect_cache else None)


def loss_fn(cfg: ModelConfig, params, batch):
    enc_out = encode(cfg, params, batch["embeds"])
    x, _ = decode_full(cfg, params, enc_out, batch["tokens"])
    logits = lm_logits(cfg, params, x)
    loss = softmax_xent(logits, batch["labels"])
    return loss, {"xent": loss, "aux": jnp.zeros((), jnp.float32)}


def prefill(cfg: ModelConfig, params, batch, *, pad_to=None):
    enc_out = encode(cfg, params, batch["embeds"])
    x, caches = decode_full(cfg, params, enc_out, batch["tokens"],
                            collect_cache=True)
    logits = lm_logits(cfg, params, x[:, -1:, :])[:, 0]
    S = batch["tokens"].shape[1]
    if pad_to and pad_to > S:
        pad = pad_to - S
        caches = dict(caches)
        for key in ("k", "v"):
            caches[key] = jnp.pad(caches[key],
                                  ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return logits, caches, S


def decode_step(cfg: ModelConfig, params, caches, tokens, pos):
    """One decoder token. caches: {'k','v' (L,B,S,Hkv,Dh), 'xk','xv'}."""
    B = tokens.shape[0]
    x = embed_tokens(cfg, params, tokens)
    if cfg.pos_embed == "sinusoidal":
        x = x + _sinusoidal(cfg, attn_mod.positions_b1(pos, B))

    def body(x, inp):
        p, c = inp
        h = apply_norm(cfg, p["ln1"], x)
        out, ck, cv = attn_mod.decode_attn(cfg, p["self_attn"], h,
                                           c["k"], c["v"], pos)
        x = x + out.astype(x.dtype)
        hx = apply_norm(cfg, p["ln_x"], x)
        out = _cached_cross_attn(cfg, p["cross_attn"], hx, c["xk"], c["xv"])
        x = x + out.astype(x.dtype)
        h2 = apply_norm(cfg, p["ln2"], x)
        x = x + apply_ffn(cfg, p["ffn"], h2).astype(x.dtype)
        return x, {"k": ck, "v": cv, "xk": c["xk"], "xv": c["xv"]}

    if cfg.scan_layers:
        x, new_caches = lax.scan(body, x, (params["dec_blocks"], caches))
    else:
        outs = []
        for g in range(cfg.n_layers):
            gp = jax.tree.map(lambda t: t[g], params["dec_blocks"])
            gc = jax.tree.map(lambda t: t[g], caches)
            x, nc = body(x, (gp, gc))
            outs.append(nc)
        new_caches = jax.tree.map(lambda *ts: jnp.stack(ts), *outs)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params, x)[:, 0]
    return logits, new_caches


def _cached_cross_attn(cfg, p, x, k, v):
    cd = cfg.dtype("compute")
    B = x.shape[0]
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = Hq // Hkv
    q = jnp.einsum("bsd,dh->bsh", x.astype(cd),
                   p["wq"].astype(cd)).reshape(B, Hkv, g, Dh)
    qf = q.astype(jnp.float32) * Dh ** -0.5
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k.astype(jnp.float32))
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v.astype(jnp.float32))
    o = o.reshape(B, 1, Hq, Dh).astype(x.dtype)
    return attn_mod._merge_heads(cfg, p, o)
