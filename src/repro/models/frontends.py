"""Modality frontend STUBS + input spec providers.

Per the assignment, [audio]/[vlm] archs specify the transformer
backbone only: ``input_specs()`` provides precomputed frame/patch
embeddings.  This module is the single source of truth for what each
(arch x shape x step-kind) consumes — used identically by the dry-run
(abstract ShapeDtypeStructs) and by tests/examples (concrete sampled
arrays via ``make_inputs(..., abstract=False)``).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract inputs for the step implied by shape.kind."""
    B, S = shape.global_batch, shape.seq_len
    cd = cfg.dtype("compute")
    if shape.kind == "train":
        if cfg.family == "encdec":
            return {"embeds": _spec((B, S, cfg.d_model), cd),
                    "tokens": _spec((B, S), jnp.int32),
                    "labels": _spec((B, S), jnp.int32)}
        if cfg.embed_inputs:
            return {"embeds": _spec((B, S, cfg.d_model), cd),
                    "labels": _spec((B, S), jnp.int32)}
        return {"tokens": _spec((B, S), jnp.int32),
                "labels": _spec((B, S), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {"embeds": _spec((B, S, cfg.d_model), cd),
                    "tokens": _spec((B, S), jnp.int32)}
        if cfg.embed_inputs:
            return {"embeds": _spec((B, S, cfg.d_model), cd)}
        return {"tokens": _spec((B, S), jnp.int32)}
    # decode: one new token against a cache of S (caches built separately)
    return {"tokens": _spec((B, 1), jnp.int32)}


def make_inputs(cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
                abstract: bool = True) -> Dict[str, Any]:
    specs = input_specs(cfg, shape)
    if abstract:
        return specs
    rng = np.random.default_rng(seed)
    out = {}
    for name, s in specs.items():
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, s.shape, dtype=np.int32))
        else:
            out[name] = jnp.asarray(
                rng.normal(0, 1, s.shape).astype(np.float32)).astype(s.dtype)
    return out
