"""Modality frontends + input spec providers.

Per the assignment, [audio]/[vlm] archs specify the transformer
backbone only: ``input_specs()`` provides precomputed frame/patch
embeddings.  The CNN vision frontend below is the exception — a real
adaptive-IP image stem (conv -> pool -> activation per block, all
selector-dispatched) that produces those patch embeddings itself.  This module is the single source of truth for what each
(arch x shape x step-kind) consumes — used identically by the dry-run
(abstract ShapeDtypeStructs) and by tests/examples (concrete sampled
arrays via ``make_inputs(..., abstract=False)``).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract inputs for the step implied by shape.kind."""
    B, S = shape.global_batch, shape.seq_len
    cd = cfg.dtype("compute")
    if shape.kind == "train":
        if cfg.family == "encdec":
            return {"embeds": _spec((B, S, cfg.d_model), cd),
                    "tokens": _spec((B, S), jnp.int32),
                    "labels": _spec((B, S), jnp.int32)}
        if cfg.embed_inputs:
            return {"embeds": _spec((B, S, cfg.d_model), cd),
                    "labels": _spec((B, S), jnp.int32)}
        return {"tokens": _spec((B, S), jnp.int32),
                "labels": _spec((B, S), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {"embeds": _spec((B, S, cfg.d_model), cd),
                    "tokens": _spec((B, S), jnp.int32)}
        if cfg.embed_inputs:
            return {"embeds": _spec((B, S, cfg.d_model), cd)}
        return {"tokens": _spec((B, S), jnp.int32)}
    # decode: one new token against a cache of S (caches built separately)
    return {"tokens": _spec((B, 1), jnp.int32)}


# ---------------------------------------------------------------------------
# CNN vision frontend — a real (non-stub) image stem built from adaptive
# cnn_blocks: every conv/pool/activation inside is dispatched through the
# resource-driven selector, and the pooled feature map is flattened to the
# (B, S, d_model) patch-embedding contract `embed_inputs` models consume.
# ---------------------------------------------------------------------------
def init_cnn_frontend(key, *, channels=(3, 16, 32), k: int = 3,
                      d_model: int = 64, dtype=jnp.float32):
    from repro.models.blocks import init_cnn_block
    keys = jax.random.split(key, len(channels))
    blocks = [init_cnn_block(kb, cin, cout, k, dtype=dtype)
              for kb, cin, cout in zip(keys, channels[:-1], channels[1:])]
    proj = (jax.random.normal(keys[-1], (channels[-1], d_model))
            * channels[-1] ** -0.5).astype(dtype)
    return {"blocks": blocks, "proj": proj}


def cnn_frontend_site_specs(p, image_shape, image_dtype, *,
                            pool_window=(2, 2), activation: str = "relu",
                            ladder=()):
    """All op sites of the frontend stack, chained by abstract shapes —
    the whole-network graph the planner partitions one budget across.
    ``ladder`` attaches the same precision ladder to every site."""
    from repro.models.blocks import cnn_block_site_specs
    specs = []
    shape, dtype = tuple(image_shape), image_dtype
    for li, bp in enumerate(p["blocks"]):
        block_specs, out_aval = cnn_block_site_specs(
            shape, bp["w"].shape, x_dtype=dtype, w_dtype=bp["w"].dtype,
            pool_window=pool_window, activation=activation,
            site=f"frontend.block{li}", ladder=ladder)
        specs.extend(block_specs)
        shape, dtype = out_aval.shape, out_aval.dtype
    return specs


def apply_cnn_frontend(p, images, *, budget=None, pool_window=(2, 2),
                       activation: str = "relu", interpret: bool = True,
                       plan=None, ladder=(), quant_report=None,
                       network=None, tile_overrides=None,
                       fuse: bool = True):
    """images: (B, H, W, Cin) -> patch embeddings (B, S, d_model).

    The entire stack (every conv/pool/act of every block) is planned as
    ONE NetworkPlan: the budget is partitioned across all sites at once
    rather than each block competing for the full envelope.  With a
    ``ladder`` the plan may be mixed-precision; each block executes its
    planned widths (see ``apply_cnn_block``) and ``quant_report``
    collects the per-site measured error across the whole stack.

    ``network`` executes from an externally built/arbitrated plan
    instead of planning here (the serving runtime's entry point —
    it re-plans tenants under moving budget slices via
    ``core.plan.replan`` and hands the result in); every block still
    validates its sites against the supplied plan.  ``tile_overrides``
    threads per-site tiling kwargs down to the kernels
    (``core.autotune.plan_tile_overrides``).

    NOTE the lowered blocks dequantize at their egress, so the ladder
    never changes this function's output dtype — only its accuracy,
    which the report quantifies.

    ``fuse`` (default True) plans the stack fusion-aware: every block
    the planner can map onto a fused conv->pool->act site executes as
    ONE launch (see ``apply_cnn_block``); blocks whose fused footprint
    does not fit keep the three-launch chain.  ``fuse=False`` opts out.
    """
    from repro.core.plan import plan_network
    from repro.models.blocks import apply_cnn_block
    if network is None:
        network = plan_network(
            cnn_frontend_site_specs(p, images.shape, images.dtype,
                                    pool_window=pool_window,
                                    activation=activation, ladder=ladder),
            budget, fuse=fuse)
    x = images
    for li, bp in enumerate(p["blocks"]):
        x = apply_cnn_block(bp, x, pool_window=pool_window,
                            activation=activation, interpret=interpret,
                            plan=plan, site=f"frontend.block{li}",
                            network=network, ladder=ladder,
                            quant_report=quant_report,
                            tile_overrides=tile_overrides)
    b, h, w, c = x.shape
    tokens = x.reshape(b, h * w, c)
    return jnp.einsum("bsc,cd->bsd", tokens, p["proj"].astype(x.dtype))


def make_inputs(cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
                abstract: bool = True) -> Dict[str, Any]:
    specs = input_specs(cfg, shape)
    if abstract:
        return specs
    rng = np.random.default_rng(seed)
    out = {}
    for name, s in specs.items():
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, s.shape, dtype=np.int32))
        else:
            out[name] = jnp.asarray(
                rng.normal(0, 1, s.shape).astype(np.float32)).astype(s.dtype)
    return out
