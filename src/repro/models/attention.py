"""GQA attention (pure-JAX twin of the Pallas attention IPs) + KV cache.

Three members mirroring the attention IP family (the selector decides
which the deployment uses; on CPU dry-runs the jnp twin lowers):

  * ``naive``   — materialized scores; only for smoke-scale S.
  * ``chunked`` — online-softmax over kv chunks with jax.checkpoint per
                  q-chunk: peak memory O(bq*bk) per head, backward
                  recomputes scores (flash-attention-via-remat).
  * decode      — single-token attention over a (possibly sequence-
                  sharded) cache; the psum-mergeable softmax form.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.blocks import apply_rope, rope_freqs

NEG_INF = -1e30


def init_attn(cfg: ModelConfig, key, shape_prefix=()):
    pd = cfg.dtype("param")
    D, Hq, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = D ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], shape_prefix + (D, Hq * Dh)) * s).astype(pd),
        "wk": (jax.random.normal(ks[1], shape_prefix + (D, Hkv * Dh)) * s).astype(pd),
        "wv": (jax.random.normal(ks[2], shape_prefix + (D, Hkv * Dh)) * s).astype(pd),
        "wo": (jax.random.normal(ks[3], shape_prefix + (Hq * Dh, D))
               * (Hq * Dh) ** -0.5).astype(pd),
    }


def _qkv(cfg: ModelConfig, p, x, positions):
    cd = cfg.dtype("compute")
    B, S, _ = x.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    x = x.astype(cd)
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(cd)).reshape(B, S, Hq, Dh)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(cd)).reshape(B, S, Hkv, Dh)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(cd)).reshape(B, S, Hkv, Dh)
    if cfg.rope_style != "none":
        cos, sin = rope_freqs(cfg, positions)
        q = apply_rope(cfg, q, cos, sin)
        k = apply_rope(cfg, k, cos, sin)
    return q, k, v


def _merge_heads(cfg: ModelConfig, p, o):
    B, S = o.shape[:2]
    cd = cfg.dtype("compute")
    o = o.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bsh,hd->bsd", o.astype(cd), p["wo"].astype(cd))


# ---------------------------------------------------------------------------
# Full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------
def _naive_attn(cfg, q, k, v, causal: bool):
    B, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    sd = cfg.dtype("attn_score")
    qf = q.astype(sd).reshape(B, Sq, Hkv, g, Dh) * jnp.asarray(Dh ** -0.5, sd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(sd),
                   preferred_element_type=sd)
    if causal:
        Skv = k.shape[1]
        mask = jnp.arange(Skv)[None, :] <= jnp.arange(Sq)[:, None] + (Skv - Sq)
        s = jnp.where(mask[None, None, None], s, jnp.asarray(NEG_INF, sd))
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(sd)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(sd))
    return o.reshape(B, Sq, Hq, Dh).astype(q.dtype)


def _chunked_attn(cfg, q, k, v, causal: bool, bq: int, bk: int,
                  unroll: bool = False):
    """Online-softmax flash form in pure jnp; remat per q-chunk.

    ``unroll=True`` replaces the q-map and kv-scan with python loops so
    HLO cost analysis counts every chunk (while-loop bodies are counted
    once) — used by the dry-run's cost-calibration graphs.
    """
    B, Sq, Hq, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    offs = Skv - Sq
    nq, nk = Sq // bq, Skv // bk
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    # (nk, B, bk, Hkv, Dh)
    ks = k.reshape(B, nk, bk, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, bk, Hkv, Dh).transpose(1, 0, 2, 3, 4)

    sd = cfg.dtype("attn_score")

    def q_chunk(qc, qi0):
        qf = (qc.astype(sd).reshape(B, bq, Hkv, g, Dh)
              * jnp.asarray(Dh ** -0.5, sd))

        def kv_step(carry, inp):
            m, l, acc, kj0 = carry
            kc, vc = inp
            # ALL (bq, bk)-sized tensors live in sd (bf16 halves the
            # dominant S^2 HBM term); only O(bq)-sized stats are f32.
            # No f32 round-trips on chunk-sized buffers — that was
            # hillclimb iteration 1's refuted variant.
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kc.astype(sd),
                           preferred_element_type=sd)
            if causal:
                qpos = qi0 + jnp.arange(bq)[:, None]
                kpos = kj0 + jnp.arange(bk)[None, :]
                s = jnp.where((kpos <= qpos + offs)[None, None, None], s,
                              jnp.asarray(NEG_INF, sd))
            m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(s - m_new[..., None].astype(sd))       # sd chunk
            l = l * alpha + pexp.sum(axis=-1, dtype=jnp.float32)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", pexp, vc.astype(sd),
                preferred_element_type=jnp.float32)
            return (m_new, l, acc, kj0 + bk), None

        def kv_step_skip(carry, inp):
            """Causal block skip: chunks fully above the diagonal are
            passed through with lax.cond — the graph twin of the Pallas
            kernel's pl.when skip (halves S^2 compute+traffic)."""
            kj0 = carry[3]
            visible = kj0 <= qi0 + bq - 1 + offs
            def live(c):
                return kv_step(c, inp)[0]
            def dead(c):
                m, l, acc, kj0 = c
                return (m, l, acc, kj0 + bk)
            return jax.lax.cond(visible, live, dead, carry), None

        m0 = jnp.full((B, Hkv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, bq, Dh), jnp.float32)
        carry = (m0, l0, a0, 0)
        skip = causal and cfg.causal_skip
        if unroll:
            for j in range(nk):
                if skip and j * bk > qi0 + bq - 1 + offs:
                    continue  # calibration graphs skip in python
                carry, _ = kv_step(carry, (ks[j], vs[j]))
        else:
            step = kv_step_skip if skip else kv_step
            carry, _ = jax.lax.scan(step, carry, (ks, vs))
        m, l, acc, _ = carry
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return o.transpose(0, 3, 1, 2, 4).reshape(B, bq, Hq, Dh).astype(q.dtype)

    qs = q.reshape(B, nq, bq, Hq, Dh).transpose(1, 0, 2, 3, 4)
    policy = jax.checkpoint_policies.nothing_saveable
    if unroll:
        # qi0 static so the python-level causal chunk skip stays python
        chunk = jax.checkpoint(q_chunk, policy=policy, static_argnums=(1,))
        outs = jnp.stack([chunk(qs[i], i * bq) for i in range(nq)])
    else:
        chunk = jax.checkpoint(q_chunk, policy=policy)
        outs = jax.lax.map(lambda t: chunk(t[0], t[1]),
                           (qs, jnp.arange(nq) * bq))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, Hq, Dh)


def full_attention(cfg: ModelConfig, q, k, v, *, causal: bool = True,
                   bq: int = 512, bk: int = 1024):
    """Dispatch naive vs chunked on working-set size (the selector rule)."""
    B, Sq = q.shape[:2]
    Skv = k.shape[1]
    if Sq * Skv <= 4096 * 4096 // 8 or Sq % min(bq, Sq) or Skv % min(bk, Skv):
        return _naive_attn(cfg, q, k, v, causal)
    unroll = not cfg.scan_layers
    if unroll:
        # fewer, larger chunks so the unrolled graph stays compilable;
        # total score traffic (S^2) and FLOPs are chunking-invariant.
        bq = min(Sq, max(512, Sq // 8))
        bk = min(Skv, max(1024, Skv // 4))
    return _chunked_attn(cfg, q, k, v, causal, min(bq, Sq), min(bk, Skv),
                         unroll=unroll)


# ---------------------------------------------------------------------------
# Cached attention (decode)
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                  dtype=None):
    dt = dtype or cfg.dtype("compute")
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    shape = (n_layers, batch, max_len, Hkv, Dh)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def positions_b1(pos, B: int):
    """Normalize a scalar or (B,) position arg to (B, 1) int32."""
    p = jnp.asarray(pos, jnp.int32)
    if p.ndim == 0:
        return jnp.full((B, 1), p, jnp.int32)
    return p.reshape(B, 1)


def decode_attn(cfg: ModelConfig, p, x, cache_k, cache_v, pos):
    """One-token step. x: (B, 1, D); cache: (B, S, Hkv, Dh);
    pos: scalar or (B,) per-slot positions (continuous batching).

    Scores over the full cache with position masking — the softmax is in
    max/sum-mergeable form so a sequence-sharded cache reduces with psum
    (XLA inserts it under pjit when the cache's S axis is sharded).
    """
    B = x.shape[0]
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = Hq // Hkv
    pos_b1 = positions_b1(pos, B)
    q, k_new, v_new = _qkv(cfg, p, x, positions=pos_b1)
    rows = jnp.arange(B)
    ck = cache_k.at[rows, pos_b1[:, 0]].set(
        k_new[:, 0].astype(cache_k.dtype))
    cv = cache_v.at[rows, pos_b1[:, 0]].set(
        v_new[:, 0].astype(cache_v.dtype))
    S = ck.shape[1]
    sd = cfg.dtype("attn_score")
    qf = q.astype(sd).reshape(B, Hkv, g, Dh) * jnp.asarray(Dh ** -0.5, sd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, ck.astype(sd),
                   preferred_element_type=sd)
    valid = (jnp.arange(S)[None, None, None, :]
             <= pos_b1[:, 0][:, None, None, None])
    s = jnp.where(valid, s, jnp.asarray(NEG_INF, sd))
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(sd)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, cv.astype(sd),
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, Hq, Dh).astype(x.dtype)
    return _merge_heads(cfg, p, o), ck, cv


def attn_block(cfg: ModelConfig, p, x, positions, *, causal=True):
    """Full attention sub-block for train/prefill: returns (out, (k, v))."""
    q, k, v = _qkv(cfg, p, x, positions)
    o = full_attention(cfg, q, k, v, causal=causal)
    return _merge_heads(cfg, p, o), (k, v)
