"""GShard-style top-k MoE with capacity-factor dispatch.

Einsum-based dense dispatch (the pjit-native formulation): tokens are
grouped (group axis = the data-parallel shards), each group computes
its own expert capacity, and the two dispatch/combine einsums bracket
the expert FFN whose expert axis is sharded over 'model' (EP) when
divisible — pjit inserts the all-to-alls.  Aux load-balancing loss per
GShard/Switch.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.blocks import init_ffn


def init_moe(cfg: ModelConfig, key, shape_prefix=()):
    assert cfg.moe is not None
    E = cfg.moe.n_experts
    pd = cfg.dtype("param")
    k_r, k_e = jax.random.split(key)
    router = (jax.random.normal(k_r, shape_prefix + (cfg.d_model, E))
              * cfg.d_model ** -0.5).astype(pd)
    experts = init_ffn(cfg, k_e, shape_prefix=shape_prefix + (E,))
    return {"router": router, "experts": experts}


def _expert_ffn(cfg: ModelConfig, p, x):
    """x: (G, E, C, D); expert-stacked weights (E, D, F)."""
    cd = cfg.dtype("compute")
    x = x.astype(cd)
    if cfg.activation in ("swiglu", "geglu"):
        g = jnp.einsum("gecd,edf->gecf", x, p["w_gate"].astype(cd))
        u = jnp.einsum("gecd,edf->gecf", x, p["w_up"].astype(cd))
        act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
        h = act(g) * u
    else:
        h = jnp.einsum("gecd,edf->gecf", x, p["w_in"].astype(cd))
        h = (jax.nn.gelu(h) if cfg.activation == "gelu"
             else jnp.square(jax.nn.relu(h)))
    return jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(cd))


def _top_k_gating(logits, k: int):
    """Iterative top-1 x k (GShard): returns per-slot (index, prob)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (G, N, E)
    masked = probs
    idxs, gates = [], []
    for _ in range(k):
        idx = jnp.argmax(masked, axis=-1)                          # (G, N)
        gate = jnp.take_along_axis(masked, idx[..., None], axis=-1)[..., 0]
        idxs.append(idx)
        gates.append(gate)
        masked = masked * (1.0 - jax.nn.one_hot(idx, probs.shape[-1],
                                                dtype=probs.dtype))
    idx = jnp.stack(idxs, axis=-1)            # (G, N, k)
    gate = jnp.stack(gates, axis=-1)          # (G, N, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    return idx, gate, probs


def apply_moe(cfg: ModelConfig, p, x, *, num_groups: int = 1
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).

    Groups = data shards: capacity is computed per group so dispatch is
    local until the expert all-to-all.
    """
    mc = cfg.moe
    E, K = mc.n_experts, mc.top_k
    B, S, D = x.shape
    N = B * S
    G = num_groups if N % num_groups == 0 else 1
    Ng = N // G
    cap = max(int(mc.capacity_factor * K * Ng / E), 1)
    xg = x.reshape(G, Ng, D)
    cd = cfg.dtype("compute")

    logits = jnp.einsum("gnd,de->gne", xg.astype(cd), p["router"].astype(cd))
    idx, gate, probs = _top_k_gating(logits, K)                  # (G,N,k)

    # Aux load-balance loss (Switch): E * sum(frac_tokens * frac_prob).
    me = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32), axis=1)
    ce = jnp.mean(probs, axis=1)
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))

    # Capacity assignment: position of each (token, slot) within its expert.
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)           # (G,N,k,E)
    flat = onehot.reshape(G, Ng * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                        # (G,N*k,E)
    pos = jnp.einsum("gme,gme->gm", pos, flat).reshape(G, Ng, K)
    pos = pos.astype(jnp.int32)
    keep = pos < cap
    gate = gate * keep

    if cfg.moe_dispatch == "scatter":
        # Indexed dispatch: scatter-add tokens into their (expert, slot)
        # and gather back — zero E*C one-hot traffic/FLOPs (the einsum
        # formulation is O(N·E·C·D); this is O(N·k·D)).  §Perf knob.
        gi = jnp.arange(G)[:, None, None]                        # (G,1,1)
        pos_c = jnp.minimum(pos, cap - 1)                        # (G,N,k)
        contrib = (xg[:, :, None, :] * keep[..., None]).astype(cd)
        expert_in = jnp.zeros((G, E, cap, D), cd)
        expert_in = expert_in.at[gi, idx, pos_c].add(contrib)
        expert_out = _expert_ffn(cfg, p["experts"], expert_in)   # (G,E,C,D)
        back = expert_out[gi, idx, pos_c]                        # (G,N,k,D)
        out = jnp.einsum("gnkd,gnk->gnd", back, gate.astype(cd))
    else:
        # GShard dense dispatch: (G,N,k,E/cap) one-hot contractions;
        # contract keeping (E, cap) as output axes only.
        pos_oh = jax.nn.one_hot(pos, cap, dtype=cd) * keep[..., None]  # (G,N,k,cap)
        disp = jnp.einsum("gnke,gnkc->gnec", onehot.astype(cd), pos_oh)
        expert_in = jnp.einsum("gnec,gnd->gecd", disp, xg.astype(cd))

        # Expert FFN: expert axis 'e' sharded (EP) when divisible.
        expert_out = _expert_ffn(cfg, p["experts"], expert_in)   # (G,E,C,D)

        comb = jnp.einsum("gnke,gnkc,gnk->gnec", onehot.astype(cd), pos_oh,
                          gate.astype(cd))
        out = jnp.einsum("gnec,gecd->gnd", comb, expert_out)
    return out.reshape(B, S, D).astype(x.dtype), aux.astype(jnp.float32)
