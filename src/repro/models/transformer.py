"""Decoder-only stack covering dense / MoE / hybrid (mamba) / ssm (rwkv).

Layers are grouped into a repeating *period* P (1 for homogeneous
stacks; 8 for jamba's 1-attn:7-mamba; lcm with moe_every for MoE
alternation) and the stack runs as ``lax.scan`` over n_layers/P groups
— one compiled group body regardless of depth, which keeps both compile
time and HLO size flat for the 512-device dry-run.

Three public step graphs (what dryrun.py lowers):
  loss_and_aux  — train forward (+xent, +MoE aux)
  prefill       — forward returning per-layer caches + last-pos logits
  decode_step   — one token through cached layers
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import rwkv as rwkv_mod
from repro.models.blocks import (apply_ffn, apply_norm, embed_tokens,
                                 init_embed, init_ffn, init_norm, lm_logits,
                                 softmax_xent)
from repro.models.moe import apply_moe, init_moe


# ---------------------------------------------------------------------------
# Layer layout
# ---------------------------------------------------------------------------
def block_period(cfg: ModelConfig) -> int:
    p = cfg.attn_every if cfg.attn_every > 1 else 1
    if cfg.moe:
        p = math.lcm(p, cfg.moe.moe_every)
    return p


def period_pattern(cfg: ModelConfig):
    """[(kind, use_moe)] for one period of the stack."""
    p = block_period(cfg)
    kinds = cfg.attn_layout[:p]
    out = []
    for i, kind in enumerate(kinds):
        use_moe = bool(cfg.moe) and (i % cfg.moe.moe_every == 0) and kind != "rwkv"
        out.append((kind, use_moe))
    return out


def moe_num_groups(n_tokens: int) -> int:
    if n_tokens >= 16_384:
        return n_tokens // 1_024
    if n_tokens >= 16 and n_tokens % 16 == 0:
        return 16
    return 1


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_sub(cfg: ModelConfig, key, kind: str, use_moe: bool, prefix):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": init_norm(cfg, prefix)}
    if kind == "attn":
        p["attn"] = attn_mod.init_attn(cfg, ks[0], prefix)
    elif kind == "mamba":
        p["mamba"] = mamba_mod.init_mamba(cfg, ks[0], prefix)
    else:  # rwkv
        p["rwkv_tm"] = rwkv_mod.init_rwkv_tm(cfg, ks[0], prefix)
    p["ln2"] = init_norm(cfg, prefix)
    if kind == "rwkv":
        p["rwkv_cm"] = rwkv_mod.init_rwkv_cm(cfg, ks[1], prefix)
    elif use_moe:
        p["moe"] = init_moe(cfg, ks[1], prefix)
    else:
        p["ffn"] = init_ffn(cfg, ks[1], prefix)
    return p


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    period = period_pattern(cfg)
    n_groups = cfg.n_layers // len(period)
    assert cfg.n_layers % len(period) == 0, (cfg.n_layers, len(period))
    k_embed, k_blocks, k_final = jax.random.split(key, 3)
    sub_keys = jax.random.split(k_blocks, len(period))
    params = init_embed(cfg, k_embed)
    params["blocks"] = {
        f"sub{i}": _init_sub(cfg, sub_keys[i], kind, use_moe, (n_groups,))
        for i, (kind, use_moe) in enumerate(period)}
    params["final_norm"] = init_norm(cfg)
    return params


def init_params_abstract(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def _sinusoidal(cfg: ModelConfig, positions):
    D = cfg.d_model
    inv = 1.0 / (10_000 ** (jnp.arange(0, D, 2, dtype=jnp.float32) / D))
    ang = positions.astype(jnp.float32)[..., None] * inv
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe.astype(cfg.dtype("compute"))


def _embed_inputs(cfg: ModelConfig, params, batch):
    if cfg.embed_inputs:
        x = batch["embeds"].astype(cfg.dtype("compute"))
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed_tokens(cfg, params, tokens)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.pos_embed == "sinusoidal":
        x = x + _sinusoidal(cfg, positions)
    return x, positions


def _apply_sub(cfg: ModelConfig, p, x, positions, kind: str, use_moe: bool,
               collect_cache: bool, causal: bool = True):
    """One sub-block. Returns (x, aux, cache)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, p["ln1"], x)
    cache = {}
    if kind == "attn":
        out, (k, v) = attn_mod.attn_block(cfg, p["attn"], h, positions,
                                          causal=causal)
        if collect_cache:
            cache = {"k": k.astype(cfg.dtype("compute")),
                     "v": v.astype(cfg.dtype("compute"))}
    elif kind == "mamba":
        if collect_cache:
            out, cache = mamba_mod.mamba_forward_with_cache(cfg, p["mamba"], h)
        else:
            out = mamba_mod.mamba_forward(cfg, p["mamba"], h)
    else:  # rwkv
        B = x.shape[0]
        st = rwkv_mod.init_rwkv_state(cfg, B)
        out, _, state = rwkv_mod.rwkv_time_mix(cfg, p["rwkv_tm"], h,
                                               st["tm_x"], st["state"])
        if collect_cache:
            cache["tm_x"] = h[:, -1, :]
            cache["state"] = state
    x = x + out.astype(x.dtype)
    h2 = apply_norm(cfg, p["ln2"], x)
    if kind == "rwkv":
        B = x.shape[0]
        out2, _ = rwkv_mod.rwkv_channel_mix(
            cfg, p["rwkv_cm"], h2, jnp.zeros((B, cfg.d_model), h2.dtype))
        if collect_cache:
            cache["cm_x"] = h2[:, -1, :]
    elif use_moe:
        n_tokens = x.shape[0] * x.shape[1]
        out2, aux = apply_moe(cfg, p["moe"], h2,
                              num_groups=moe_num_groups(n_tokens))
    else:
        out2 = apply_ffn(cfg, p["ffn"], h2)
    x = x + out2.astype(x.dtype)
    return x, aux, cache


def forward(cfg: ModelConfig, params, batch, *, collect_cache: bool = False,
            causal: bool = True):
    """Returns (hidden (B,S,D), aux_loss, caches | None)."""
    period = period_pattern(cfg)
    x, positions = _embed_inputs(cfg, params, batch)

    def group_body(carry, gp):
        x, aux = carry
        caches = {}
        for i, (kind, use_moe) in enumerate(period):
            x, a, cache = _apply_sub(cfg, gp[f"sub{i}"], x, positions, kind,
                                     use_moe, collect_cache, causal)
            aux = aux + a
            caches[f"sub{i}"] = cache
        return (x, aux), caches

    if cfg.remat in ("block", "block_dots"):
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat == "block"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        group_body = jax.checkpoint(group_body, policy=policy)

    carry = (x, jnp.zeros((), jnp.float32))
    if cfg.scan_layers:
        (x, aux), caches = lax.scan(group_body, carry, params["blocks"])
    else:  # unrolled (cost-calibration graphs; also small models)
        n_groups = cfg.n_layers // len(period)
        cache_list = []
        for g in range(n_groups):
            gp = jax.tree.map(lambda t: t[g], params["blocks"])
            carry, cache_g = group_body(carry, gp)
            cache_list.append(cache_g)
        (x, aux) = carry
        caches = (jax.tree.map(lambda *ts: jnp.stack(ts), *cache_list)
                  if collect_cache else None)
    x = apply_norm(cfg, params["final_norm"], x)
    return x, aux, (caches if collect_cache else None)


def loss_fn(cfg: ModelConfig, params, batch):
    x, aux, _ = forward(cfg, params, batch)
    logits = lm_logits(cfg, params, x)
    loss = softmax_xent(logits, batch["labels"])
    return loss + 0.01 * aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------
def prefill(cfg: ModelConfig, params, batch, *, pad_to: Optional[int] = None):
    """Run the prompt; return (last_logits, caches, next_pos).

    ``pad_to``: allocate attention KV caches at this length (>= S) so
    decode can append in place.
    """
    x, _, caches = forward(cfg, params, batch, collect_cache=True)
    last = x[:, -1:, :]
    logits = lm_logits(cfg, params, last)[:, 0]
    S = (batch["embeds"] if cfg.embed_inputs else batch["tokens"]).shape[1]
    if pad_to and pad_to > S:
        pad = pad_to - S

        def grow(path_leaf):
            return path_leaf

        def pad_kv(c):
            out = dict(c)
            for key in ("k", "v"):
                if key in c:
                    arr = c[key]  # (G, B, S, Hkv, Dh)
                    out[key] = jnp.pad(arr, ((0, 0), (0, 0), (0, pad),
                                             (0, 0), (0, 0)))
            return out

        caches = {name: pad_kv(c) for name, c in caches.items()}
    return logits, caches, S


def decode_step(cfg: ModelConfig, params, caches, tokens, pos):
    """One token step. tokens: (B, 1) (or embeds (B,1,D)); pos: scalar int32.

    caches: pytree with leading group axis (as produced by prefill or
    ``init_decode_caches``).  Returns (logits (B, V), new_caches).
    """
    period = period_pattern(cfg)
    batch = ({"embeds": tokens} if cfg.embed_inputs and tokens.ndim == 3
             else {"tokens": tokens})
    B = tokens.shape[0]
    x = (batch["embeds"].astype(cfg.dtype("compute"))
         if "embeds" in batch else embed_tokens(cfg, params, batch["tokens"]))
    if cfg.pos_embed == "sinusoidal":
        x = x + _sinusoidal(cfg, attn_mod.positions_b1(pos, B))

    def group_body(x, inp):
        gp, gcache = inp
        new_cache = {}
        for i, (kind, use_moe) in enumerate(period):
            p = gp[f"sub{i}"]
            c = gcache[f"sub{i}"]
            h = apply_norm(cfg, p["ln1"], x)
            nc = {}
            if kind == "attn":
                out, ck, cv = attn_mod.decode_attn(cfg, p["attn"], h,
                                                   c["k"], c["v"], pos)
                nc = {"k": ck, "v": cv}
            elif kind == "mamba":
                out, nc = mamba_mod.mamba_step(cfg, p["mamba"], h, c)
            else:  # rwkv
                out, _, state = rwkv_mod.rwkv_time_mix(
                    cfg, p["rwkv_tm"], h, c["tm_x"], c["state"])
                nc = {"tm_x": h[:, -1, :], "state": state}
            x = x + out.astype(x.dtype)
            h2 = apply_norm(cfg, p["ln2"], x)
            if kind == "rwkv":
                out2, _ = rwkv_mod.rwkv_channel_mix(cfg, p["rwkv_cm"], h2,
                                                    c["cm_x"])
                nc["cm_x"] = h2[:, -1, :]
            elif use_moe:
                out2, _ = apply_moe(cfg, p["moe"], h2,
                                    num_groups=moe_num_groups(B))
            else:
                out2 = apply_ffn(cfg, p["ffn"], h2)
            x = x + out2.astype(x.dtype)
            new_cache[f"sub{i}"] = nc
        return x, new_cache

    if cfg.scan_layers:
        x, new_caches = lax.scan(group_body, x, (params["blocks"], caches))
    else:
        n_groups = cfg.n_layers // len(period)
        outs = []
        for g in range(n_groups):
            gp = jax.tree.map(lambda t: t[g], params["blocks"])
            gc = jax.tree.map(lambda t: t[g], caches)
            x, nc = group_body(x, (gp, gc))
            outs.append(nc)
        new_caches = jax.tree.map(lambda *ts: jnp.stack(ts), *outs)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params, x)[:, 0]
    return logits, new_caches


def init_decode_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Zero caches with leading group axis (for decode-only dry-runs)."""
    period = period_pattern(cfg)
    n_groups = cfg.n_layers // len(period)
    cd = cfg.dtype("compute")
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim

    def one(kind):
        if kind == "attn":
            shape = (n_groups, batch, max_len, Hkv, Dh)
            return {"k": jnp.zeros(shape, cd), "v": jnp.zeros(shape, cd)}
        if kind == "mamba":
            mc = cfg.mamba
            return {"conv": jnp.zeros((n_groups, batch, mc.d_conv - 1,
                                       cfg.d_inner), cd),
                    "ssm": jnp.zeros((n_groups, batch, cfg.d_inner,
                                      mc.d_state), jnp.float32)}
        H, hs = cfg.d_model // cfg.rwkv.head_size, cfg.rwkv.head_size
        return {"tm_x": jnp.zeros((n_groups, batch, cfg.d_model), cd),
                "cm_x": jnp.zeros((n_groups, batch, cfg.d_model), cd),
                "state": jnp.zeros((n_groups, batch, H, hs, hs), jnp.float32)}

    return {f"sub{i}": one(kind)
            for i, (kind, _) in enumerate(period_pattern(cfg))}
