"""Sharded checkpointing: atomic-commit manifests, async save, and
reshard-on-restore (the elastic-scaling primitive).

Layout:
  <dir>/step_000123/
    manifest.json    tree structure, dtypes/shapes, mesh, step, data state
    arr_00000.npy …  one file per leaf (per-host shard in multihost; the
                     whole leaf on this single-host runtime)
  <dir>/LATEST       committed step pointer — written LAST (atomic rename),
                     so a crash mid-save never corrupts the restore point.

Restore takes a *target* mesh/sharding that may differ from the saved
one: leaves are loaded on host and device_put with the new sharding —
i.e. checkpoint-reshard-restart is the elastic-scaling path.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class _LeafRef:
    """Placeholder marking leaf ``i`` inside the structure spec."""

    __slots__ = ("i",)

    def __init__(self, i: int):
        self.i = i


def _encode_structure(node):
    """JSON-able spec of a dict/list/tuple pytree with ``_LeafRef``
    placeholders at leaf positions; raises TypeError on any node the
    spec cannot represent (custom pytree nodes, non-str dict keys)."""
    if isinstance(node, _LeafRef):
        return {"t": "leaf", "i": node.i}
    if isinstance(node, dict):
        if any(not isinstance(k, str) for k in node):
            raise TypeError("structure spec needs str dict keys")
        return {"t": "dict",
                "items": {k: _encode_structure(v) for k, v in node.items()}}
    if isinstance(node, (list, tuple)):
        return {"t": "tuple" if isinstance(node, tuple) else "list",
                "items": [_encode_structure(v) for v in node]}
    if node is None:
        return {"t": "none"}
    raise TypeError(f"cannot encode pytree node of type {type(node)!r}")


def _decode_structure(spec, load: Callable[[int], Any]):
    t = spec["t"]
    if t == "leaf":
        return load(spec["i"])
    if t == "dict":
        return {k: _decode_structure(v, load)
                for k, v in spec["items"].items()}
    if t == "list":
        return [_decode_structure(v, load) for v in spec["items"]]
    if t == "tuple":
        return tuple(_decode_structure(v, load) for v in spec["items"])
    if t == "none":
        return None
    raise ValueError(f"unknown structure node {t!r}")


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Synchronous sharded save with atomic commit."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    step_name = f"step_{step:09d}"
    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=f".{step_name}."))
    try:
        leaves, treedef = _flatten(tree)
        # Self-describing structure spec (dict/list/tuple trees only):
        # lets ``restore_blind`` rebuild the tree with NO target skeleton
        # — the recovery path, where the restarted process knows nothing
        # about the params structure it is about to inherit.
        try:
            structure = _encode_structure(jax.tree_util.tree_unflatten(
                treedef, [_LeafRef(i) for i in range(len(leaves))]))
        except TypeError:
            structure = None
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "leaves": [],
            "structure": structure,
            "extra": extra or {},
        }
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            np.save(tmp / f"arr_{i:05d}.npy", arr)
            manifest["leaves"].append(
                {"shape": list(arr.shape), "dtype": str(arr.dtype)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = ckpt_dir / step_name
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic on same fs
        latest_tmp = ckpt_dir / ".LATEST.tmp"
        latest_tmp.write_text(step_name)
        os.replace(latest_tmp, ckpt_dir / "LATEST")  # commit point
    finally:
        if tmp.exists():
            shutil.rmtree(tmp, ignore_errors=True)
    _gc(ckpt_dir, keep)
    return str(ckpt_dir / step_name)


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(p for p in ckpt_dir.iterdir()
                   if p.is_dir() and p.name.startswith("step_"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread; at most one in flight
    (a newer snapshot supersedes a queued older one)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._lock = threading.Lock()
        self._pending: Optional[tuple] = None
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: list = []

    def save(self, step: int, tree, extra: Optional[Dict] = None):
        # Pull to host *now* (the device buffers may be donated next step).
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        with self._lock:
            self._pending = (step, host_tree, extra)
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(target=self._drain,
                                                daemon=True)
                self._thread.start()

    def _drain(self):
        while True:
            with self._lock:
                item, self._pending = self._pending, None
            if item is None:
                return
            step, tree, extra = item
            save(self.ckpt_dir, step, tree, extra=extra, keep=self.keep)
            self.saved_steps.append(step)

    def wait(self):
        t = self._thread
        if t is not None:
            t.join()


def latest_step(ckpt_dir: str) -> Optional[int]:
    latest = Path(ckpt_dir) / "LATEST"
    if not latest.exists():
        return None
    return int(latest.read_text().strip().split("_")[-1])


def restore_blind(ckpt_dir: str, *, step: Optional[int] = None
                  ) -> Tuple[Any, Dict]:
    """Rebuild the saved tree with no target skeleton, from the
    manifest's structure spec — the crash-recovery entry point
    (``runtime/recovery.py``): a restarted process inherits params whose
    structure only the checkpoint knows.  Raises ValueError for
    checkpoints of non-dict/list/tuple pytrees (use ``restore`` with an
    explicit target there)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    structure = manifest.get("structure")
    if structure is None:
        raise ValueError(
            "checkpoint carries no structure spec (custom pytree nodes); "
            "restore() with a target tree is required")

    def _load(i: int):
        return jax.numpy.asarray(np.load(d / f"arr_{i:05d}.npy"))

    return _decode_structure(structure, _load), manifest["extra"]


def restore(ckpt_dir: str, target_tree, *, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target_tree``; optionally reshard.

    ``shardings``: a matching pytree of jax.sharding.Sharding — leaves
    are device_put with the *target* sharding, which may correspond to a
    different mesh than the one the checkpoint was written under
    (elastic restart).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten(target_tree)
    assert manifest["n_leaves"] == len(leaves), \
        f"checkpoint has {manifest['n_leaves']} leaves, target {len(leaves)}"
    new_leaves = []
    shard_leaves = (jax.tree_util.tree_flatten(
        shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))[0]
        if shardings is not None else [None] * len(leaves))
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(d / f"arr_{i:05d}.npy")
        assert list(arr.shape) == list(ref.shape), (arr.shape, ref.shape)
        arr = arr.astype(ref.dtype)
        new_leaves.append(jax.device_put(arr, shd) if shd is not None
                          else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["extra"]
