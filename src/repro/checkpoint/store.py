"""Sharded checkpointing: atomic-commit manifests, async save, and
reshard-on-restore (the elastic-scaling primitive).

Layout:
  <dir>/step_000123/
    manifest.json    tree structure, dtypes/shapes, mesh, step, data state
    arr_00000.npy …  one file per leaf (per-host shard in multihost; the
                     whole leaf on this single-host runtime)
  <dir>/LATEST       committed step pointer — written LAST (atomic rename),
                     so a crash mid-save never corrupts the restore point.

Restore takes a *target* mesh/sharding that may differ from the saved
one: leaves are loaded on host and device_put with the new sharding —
i.e. checkpoint-reshard-restart is the elastic-scaling path.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Synchronous sharded save with atomic commit."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    step_name = f"step_{step:09d}"
    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=f".{step_name}."))
    try:
        leaves, treedef = _flatten(tree)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "leaves": [],
            "extra": extra or {},
        }
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            np.save(tmp / f"arr_{i:05d}.npy", arr)
            manifest["leaves"].append(
                {"shape": list(arr.shape), "dtype": str(arr.dtype)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = ckpt_dir / step_name
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic on same fs
        latest_tmp = ckpt_dir / ".LATEST.tmp"
        latest_tmp.write_text(step_name)
        os.replace(latest_tmp, ckpt_dir / "LATEST")  # commit point
    finally:
        if tmp.exists():
            shutil.rmtree(tmp, ignore_errors=True)
    _gc(ckpt_dir, keep)
    return str(ckpt_dir / step_name)


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(p for p in ckpt_dir.iterdir()
                   if p.is_dir() and p.name.startswith("step_"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread; at most one in flight
    (a newer snapshot supersedes a queued older one)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._lock = threading.Lock()
        self._pending: Optional[tuple] = None
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: list = []

    def save(self, step: int, tree, extra: Optional[Dict] = None):
        # Pull to host *now* (the device buffers may be donated next step).
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        with self._lock:
            self._pending = (step, host_tree, extra)
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(target=self._drain,
                                                daemon=True)
                self._thread.start()

    def _drain(self):
        while True:
            with self._lock:
                item, self._pending = self._pending, None
            if item is None:
                return
            step, tree, extra = item
            save(self.ckpt_dir, step, tree, extra=extra, keep=self.keep)
            self.saved_steps.append(step)

    def wait(self):
        t = self._thread
        if t is not None:
            t.join()


def latest_step(ckpt_dir: str) -> Optional[int]:
    latest = Path(ckpt_dir) / "LATEST"
    if not latest.exists():
        return None
    return int(latest.read_text().strip().split("_")[-1])


def restore(ckpt_dir: str, target_tree, *, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target_tree``; optionally reshard.

    ``shardings``: a matching pytree of jax.sharding.Sharding — leaves
    are device_put with the *target* sharding, which may correspond to a
    different mesh than the one the checkpoint was written under
    (elastic restart).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten(target_tree)
    assert manifest["n_leaves"] == len(leaves), \
        f"checkpoint has {manifest['n_leaves']} leaves, target {len(leaves)}"
    new_leaves = []
    shard_leaves = (jax.tree_util.tree_flatten(
        shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))[0]
        if shardings is not None else [None] * len(leaves))
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(d / f"arr_{i:05d}.npy")
        assert list(arr.shape) == list(ref.shape), (arr.shape, ref.shape)
        arr = arr.astype(ref.dtype)
        new_leaves.append(jax.device_put(arr, shd) if shd is not None
                          else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["extra"]
