"""Plan decision audit — why the planner chose what it chose.

``plan_network`` makes four kinds of decisions that were previously
write-only: per-site member selection (with rejections), precision-
ladder descent, fusion-group substitution/fallback, and mesh shard
refusal.  This module is the record of those decisions:

* ``CandidateRecord`` — one (member, width) candidacy: chosen, feasible
  -but-outranked, or rejected with a **concrete** reason (the exact
  budget axis that failed, with numbers — ``unfit_reason`` mirrors
  ``Footprint.fits`` clause by clause).
* ``SiteAudit`` — one site's full candidate set across every ladder
  rung it tried, plus the fraction the partitioner granted it.
* ``PlanAudit`` — the per-site audits plus plan-level events (fusion
  decisions, partition repair, shard decisions/refusals).

The audit rides on ``NetworkPlan.audit`` (``core/plan.py``), renders
through ``NetworkPlan.explain()``, and round-trips through the plan's
JSON.  Recording happens on **cold plans only** — cache hits return the
memoized plan, audit included — so the amortized cost is zero on the
serving path.

Nothing here imports ``repro.core``: reason helpers duck-type on the
footprint/budget attributes, keeping the obs package import-cycle-free.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


def unfit_reason(fp, budget) -> str:
    """The first budget axis ``fp`` fails, with numbers — mirrors
    ``Footprint.fits`` (core/resources.py) clause by clause so the
    reported reason is exactly why ``fits`` said no."""
    if fp.vmem_bytes > budget.vmem_bytes:
        return (f"vmem {fp.vmem_bytes / 1024:.0f}KiB > "
                f"budget {budget.vmem_bytes / 1024:.0f}KiB")
    if fp.hbm_bytes > budget.hbm_bytes:
        return (f"hbm {fp.hbm_bytes / 2**20:.1f}MiB > "
                f"budget {budget.hbm_bytes / 2**20:.1f}MiB")
    if fp.mxu_passes > 0 and not budget.mxu_available:
        return f"needs {fp.mxu_passes} MXU passes but mxu_available=False"
    if (budget.mxu_passes_budget is not None
            and fp.mxu_passes > budget.mxu_passes_budget):
        return (f"mxu_passes {fp.mxu_passes} > "
                f"budget {budget.mxu_passes_budget}")
    if (budget.vpu_ops_budget is not None
            and fp.vpu_ops > budget.vpu_ops_budget):
        return (f"vpu_ops {fp.vpu_ops:.2e} > "
                f"budget {budget.vpu_ops_budget:.2e}")
    if budget.precision_bits > fp.max_operand_bits:
        return (f"deployment needs {budget.precision_bits}-bit operands, "
                f"member ceiling is {fp.max_operand_bits}-bit")
    return "fits"       # defensive: caller only asks after fits() failed


@dataclasses.dataclass(frozen=True)
class CandidateRecord:
    """One (member, operand-width) candidacy at one selection."""

    member: str
    bits: int
    status: str                       # "chosen" | "feasible" | "rejected"
    reason: str = ""                  # non-empty iff rejected
    cost: Optional[float] = None      # ranking cycles when feasible

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "CandidateRecord":
        return cls(member=d["member"], bits=int(d["bits"]),
                   status=d["status"], reason=d.get("reason", ""),
                   cost=d.get("cost"))


@dataclasses.dataclass(frozen=True)
class SiteAudit:
    """One site's selection record: every candidate tried at every
    ladder rung, the winner, and the budget fraction granted."""

    site: str
    family: str
    chosen: str
    chosen_bits: int
    native_bits: int
    fraction: float
    candidates: Tuple[CandidateRecord, ...] = ()
    notes: Tuple[str, ...] = ()

    @property
    def lowered(self) -> bool:
        return self.chosen_bits < self.native_bits

    def rejected(self) -> Tuple[CandidateRecord, ...]:
        return tuple(c for c in self.candidates if c.status == "rejected")

    def rejection_reasons(self) -> Tuple[str, ...]:
        """The distinct concrete reasons recorded against candidates of
        this site (order preserved)."""
        seen, out = set(), []
        for c in self.candidates:
            if c.status == "rejected" and c.reason and c.reason not in seen:
                seen.add(c.reason)
                out.append(f"{c.member}@{c.bits}b: {c.reason}")
        return tuple(out)

    def to_dict(self) -> dict:
        return {
            "site": self.site, "family": self.family,
            "chosen": self.chosen, "chosen_bits": self.chosen_bits,
            "native_bits": self.native_bits, "fraction": self.fraction,
            "candidates": [c.to_dict() for c in self.candidates],
            "notes": list(self.notes),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "SiteAudit":
        return cls(
            site=d["site"], family=d["family"], chosen=d["chosen"],
            chosen_bits=int(d["chosen_bits"]),
            native_bits=int(d["native_bits"]),
            fraction=float(d["fraction"]),
            candidates=tuple(CandidateRecord.from_dict(c)
                             for c in d.get("candidates", ())),
            notes=tuple(d.get("notes", ())))


@dataclasses.dataclass(frozen=True)
class PlanAudit:
    """The whole plan's decision record: per-site audits + plan-level
    events (fusion substitutions/fallbacks, partition repair, shard
    decisions) in the order they happened."""

    sites: Tuple[SiteAudit, ...] = ()
    events: Tuple[str, ...] = ()

    def site(self, name: str) -> SiteAudit:
        for s in self.sites:
            if s.site == name:
                return s
        raise KeyError(f"no audit for site {name!r}; "
                       f"have {[s.site for s in self.sites]}")

    def with_events(self, *events: str) -> "PlanAudit":
        return dataclasses.replace(self,
                                   events=self.events + tuple(events))

    def to_dict(self) -> dict:
        return {"sites": [s.to_dict() for s in self.sites],
                "events": list(self.events)}

    @classmethod
    def from_dict(cls, d: dict) -> "PlanAudit":
        return cls(sites=tuple(SiteAudit.from_dict(s)
                               for s in d.get("sites", ())),
                   events=tuple(d.get("events", ())))

    def render(self) -> str:
        lines = []
        for ev in self.events:
            lines.append(f"[plan] {ev}")
        for s in self.sites:
            low = (f" (lowered from {s.native_bits}b)" if s.lowered else "")
            lines.append(f"{s.site}: chose {s.chosen} @{s.chosen_bits}b"
                         f"{low}, fraction {s.fraction:.3f}")
            for note in s.notes:
                lines.append(f"  - {note}")
            for c in s.candidates:
                if c.status == "chosen":
                    continue
                if c.status == "rejected":
                    lines.append(f"  x {c.member}@{c.bits}b rejected: "
                                 f"{c.reason}")
                else:
                    cost = ("" if c.cost is None
                            else f" (cost {c.cost:.3e})")
                    lines.append(f"  ~ {c.member}@{c.bits}b feasible but "
                                 f"outranked{cost}")
        return "\n".join(lines)


class SiteAuditRecorder:
    """Mutable scratch one ``_select_site`` call writes into; frozen
    into a ``SiteAudit`` once the partitioner settles the fraction.

    The recorder watches the ladder descend: when a site settles below
    its native width, a note names every rung that failed above it —
    the "precision-ladder descent" rejection reason the audit contract
    requires."""

    def __init__(self, site: str, family: str, native_bits: int):
        self.site = site
        self.family = family
        self.native_bits = native_bits
        self.records: List[CandidateRecord] = []
        self.notes: List[str] = []

    def candidate(self, member: str, bits: int, status: str,
                  reason: str = "", cost: Optional[float] = None) -> None:
        self.records.append(CandidateRecord(
            member=member, bits=bits, status=status, reason=reason,
            cost=cost))

    def chose(self, member: str, bits: int) -> None:
        """Promote the winning feasible record to "chosen"."""
        for i, r in enumerate(self.records):
            if (r.member == member and r.bits == bits
                    and r.status == "feasible"):
                self.records[i] = dataclasses.replace(r, status="chosen")
                return

    def note(self, text: str) -> None:
        self.notes.append(text)

    def finish(self, chosen: str, chosen_bits: int,
               fraction: float) -> SiteAudit:
        if chosen_bits < self.native_bits:
            failed = sorted({r.bits for r in self.records
                             if r.bits > chosen_bits}, reverse=True)
            if failed:
                self.notes.append(
                    "precision-ladder descent: no feasible member at "
                    + "/".join(f"{b}b" for b in failed)
                    + f"; settled at {chosen_bits}b")
        return SiteAudit(
            site=self.site, family=self.family, chosen=chosen,
            chosen_bits=chosen_bits, native_bits=self.native_bits,
            fraction=fraction, candidates=tuple(self.records),
            notes=tuple(self.notes))
