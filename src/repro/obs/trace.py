"""Span tracer + event log — the timing half of the observability layer.

Two instruments with different always-on contracts:

* ``SpanTracer`` (singleton ``TRACER``) records *spans* — named,
  categorized wall-clock intervals — and instant markers, exportable as
  Chrome trace-event JSON (``export_chrome_trace``) loadable in
  Perfetto or chrome://tracing.  It is **off by default**, and the
  disabled path is allocation-free: ``TRACER.span(...)`` is only ever
  called behind an ``if TRACER.enabled`` guard at hot call sites (the
  serving loop), with the shared ``NOOP_SPAN`` singleton taken on the
  else branch — no argument dict, no context-manager object, nothing
  for the GC.  The idiom::

      with (TRACER.span("serve.execute", "serving", {...})
            if TRACER.enabled else NOOP_SPAN):
          ...

  costs one attribute read and one branch when tracing is off.

* ``EventLog`` (singleton ``EVENTS``) is **always on**: a small bounded
  ring of operator-relevant events (watchdog timeouts, plan-cache
  evictions, arbiter rebalances, calibration drift trips) that would
  otherwise be invisible.  Events mirror into the tracer as instant
  markers when it is enabled, so a trace shows them on the timeline.

Thread safety: both instruments take a lock per record; spans carry the
recording thread's id so multi-threaded traces lay out per-thread in
Perfetto.  Buffers are bounded (drops are counted, never silent).
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional

# Bounded buffers: a serving process must not grow without limit just
# because someone left tracing on.
TRACE_BUFFER_MAX = 100_000
EVENT_LOG_MAX = 1024

_PID = 1    # one process; Chrome's pid slot is a display group here


class _NoopSpan:
    """The shared disabled-path context manager: enter/exit do nothing,
    and the single module-level instance (``NOOP_SPAN``) means the
    disabled hot path allocates nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP_SPAN = _NoopSpan()


class _Span:
    """One live span: records a Chrome 'X' (complete) event on exit."""

    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer: "SpanTracer", name: str, cat: str,
                 args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = time.perf_counter_ns()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        self._tracer._record({
            "name": self.name,
            "cat": self.cat or "default",
            "ph": "X",
            "ts": self._t0 / 1e3,           # Chrome wants microseconds
            "dur": (t1 - self._t0) / 1e3,
            "pid": _PID,
            "tid": threading.get_ident(),
            **({"args": self.args} if self.args else {}),
        })
        return False


class SpanTracer:
    """Span recorder; see module docstring.  Use the ``TRACER``
    singleton — one process, one timeline."""

    def __init__(self, max_events: int = TRACE_BUFFER_MAX):
        self.enabled = False
        self.max_events = max_events
        self.dropped = 0
        self._events: List[dict] = []
        self._lock = threading.Lock()

    # -- control ------------------------------------------------------------
    def enable(self) -> "SpanTracer":
        self.enabled = True
        return self

    def disable(self) -> "SpanTracer":
        self.enabled = False
        return self

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    # -- recording ----------------------------------------------------------
    def span(self, name: str, cat: str = "",
             args: Optional[dict] = None):
        """A context manager timing one span.  Hot call sites must guard
        with ``if TRACER.enabled`` and take ``NOOP_SPAN`` otherwise (the
        allocation-free contract); calling this while disabled still
        returns ``NOOP_SPAN`` so un-guarded cold sites stay correct."""
        if not self.enabled:
            return NOOP_SPAN
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "",
                args: Optional[dict] = None) -> None:
        """A zero-duration marker (Chrome 'i' event)."""
        if not self.enabled:
            return
        self._record({
            "name": name,
            "cat": cat or "default",
            "ph": "i",
            "s": "t",                       # thread-scoped marker
            "ts": time.perf_counter_ns() / 1e3,
            "pid": _PID,
            "tid": threading.get_ident(),
            **({"args": args} if args else {}),
        })

    def _record(self, event: dict) -> None:
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append(event)

    # -- export -------------------------------------------------------------
    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def export_chrome_trace(self, indent: Optional[int] = None) -> str:
        """The buffered spans as Chrome trace-event JSON (the
        ``traceEvents`` array-of-objects form Perfetto and
        chrome://tracing both load)."""
        return json.dumps({
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }, indent=indent)

    def stats(self) -> dict:
        with self._lock:
            return {"enabled": self.enabled, "events": len(self._events),
                    "dropped": self.dropped, "capacity": self.max_events}


TRACER = SpanTracer()


class EventLog:
    """Always-on bounded ring of operator events; see module docstring."""

    def __init__(self, max_events: int = EVENT_LOG_MAX):
        self.max_events = max_events
        self.total = 0
        self._events: List[Dict[str, Any]] = []
        self._lock = threading.Lock()

    def log(self, kind: str, **fields) -> None:
        """Record one event.  ``kind`` is a dotted taxonomy name
        (``"watchdog.timeout"``, ``"plan_cache.evict"``); fields are
        free-form JSON-able payload.  Mirrors into the tracer as an
        instant marker when tracing is on."""
        event = {"kind": kind, "t": time.time(), **fields}
        with self._lock:
            self.total += 1
            self._events.append(event)
            if len(self._events) > self.max_events:
                del self._events[:len(self._events) - self.max_events]
        if TRACER.enabled:
            TRACER.instant(kind, "events", fields or None)

    def recent(self, n: int = 50, kind: Optional[str] = None) -> List[dict]:
        with self._lock:
            events = list(self._events)
        if kind is not None:
            events = [e for e in events if e["kind"] == kind]
        return events[-n:]

    def counts(self) -> Dict[str, int]:
        """Events per kind currently in the ring (bounded window)."""
        out: Dict[str, int] = {}
        with self._lock:
            for e in self._events:
                out[e["kind"]] = out.get(e["kind"], 0) + 1
        return out

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.total = 0


EVENTS = EventLog()


def log_event(kind: str, **fields) -> None:
    """Module-level shorthand for ``EVENTS.log`` — what the planner,
    watchdog, arbiter and drift monitor call."""
    EVENTS.log(kind, **fields)
