"""Metrics registry — one snapshot for the scattered stats.

The system already counts plenty (``core.plan.PlannerStats``, the LRU
cache's ``plan_cache_stats``, ``BudgetArbiter.rebalances``, per-tenant
``TenantTelemetry``, the tracer/event-log buffers) but each behind its
own ad-hoc dict.  This module unifies them:

* ``Counter`` / ``Gauge`` / ``Histogram`` — the three metric kinds,
  labeled, registered in a ``MetricsRegistry``.
* ``MetricsRegistry.snapshot()`` — one nested dict of everything.
* ``MetricsRegistry.render()`` — Prometheus-style text exposition
  (``# HELP`` / ``# TYPE``; histograms render summary-style with
  quantile labels, ``_sum`` and ``_count``).
* ``system_metrics(server=None)`` — the collector: walks the planner
  stats, plan cache, event log, tracer, and (when given a server) the
  arbiter + per-tenant telemetry into a fresh registry.
* ``percentile(values, q)`` — THE percentile estimator.
  ``TenantTelemetry.latency_percentile`` and ``Histogram.quantile``
  both delegate here, so serving telemetry and metrics exposition can
  never disagree about what "p95" means (sorted linear interpolation,
  the same rule ``numpy.percentile(..., method="linear")`` applies).

Import discipline: lazy imports inside ``system_metrics`` only — the
registry itself depends on nothing from ``repro.core``/``repro.runtime``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Iterable, List, Optional, Sequence, Tuple

HISTOGRAM_WINDOW = 4096
_QUANTILES = (0.5, 0.9, 0.95, 0.99)


def percentile(values: Sequence[float], q: float) -> float:
    """q-th percentile (0..100) by sorted linear interpolation — the
    single estimator shared by ``Histogram`` and
    ``TenantTelemetry.latency_percentile``.  Empty input returns 0.0
    (a gauge that has seen nothing reads zero, not NaN)."""
    xs = sorted(values)
    if not xs:
        return 0.0
    if len(xs) == 1:
        return float(xs[0])
    q = min(max(float(q), 0.0), 100.0)
    pos = (q / 100.0) * (len(xs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    return float(xs[lo] + (xs[hi] - xs[lo]) * (pos - lo))


@dataclasses.dataclass
class Counter:
    """Monotone event count."""

    value: float = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        self.value += amount


@dataclasses.dataclass
class Gauge:
    """A point-in-time value that can move both ways."""

    value: float = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class Histogram:
    """Windowed distribution: total count/sum are exact over the full
    history; quantiles are estimated over the most recent ``window``
    observations (the same bounded-memory treatment the telemetry
    latency deque gets)."""

    def __init__(self, window: int = HISTOGRAM_WINDOW):
        self.count = 0
        self.sum = 0.0
        self._recent: Deque[float] = deque(maxlen=window)

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.sum += value
        self._recent.append(value)

    def observe_many(self, values: Iterable[float]) -> None:
        for v in values:
            self.observe(v)

    def quantile(self, q01: float) -> float:
        """Quantile in [0, 1] (Prometheus summary convention)."""
        return percentile(self._recent, q01 * 100.0)

    def snapshot(self) -> dict:
        return {"count": self.count, "sum": self.sum,
                "quantiles": {q: self.quantile(q) for q in _QUANTILES}}


_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> _LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _render_labels(key: _LabelKey, extra: Tuple[Tuple[str, str], ...] = ()
                   ) -> str:
    items = key + extra
    if not items:
        return ""
    body = ",".join(f'{k}="{_escape(v)}"' for k, v in items)
    return "{" + body + "}"


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class MetricsRegistry:
    """Named, labeled metrics behind one snapshot + text exposition.

    A metric name registers with one kind; re-registering the same
    (name, labels) returns the existing instrument (so collectors are
    idempotent), while re-registering a name as a different kind
    raises — the exposition format cannot express that."""

    def __init__(self, namespace: str = "repro"):
        self.namespace = namespace
        self._kinds: Dict[str, str] = {}
        self._help: Dict[str, str] = {}
        self._metrics: Dict[Tuple[str, _LabelKey], object] = {}

    # positional-only parameters: label names like kind= / name= must
    # never collide with the registration arguments
    def _get(self, kind: str, name: str, help_: str, factory, /, **labels):
        have = self._kinds.get(name)
        if have is not None and have != kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{have}, not {kind}")
        self._kinds[name] = kind
        if help_:
            self._help[name] = help_
        key = (name, _label_key(labels))
        metric = self._metrics.get(key)
        if metric is None:
            metric = factory()
            self._metrics[key] = metric
        return metric

    def counter(self, name: str, help_: str = "", /, **labels) -> Counter:
        return self._get("counter", name, help_, Counter, **labels)

    def gauge(self, name: str, help_: str = "", /, **labels) -> Gauge:
        return self._get("gauge", name, help_, Gauge, **labels)

    def histogram(self, name: str, help_: str = "", /,
                  window: int = HISTOGRAM_WINDOW, **labels) -> Histogram:
        return self._get("summary", name, help_,
                         lambda: Histogram(window), **labels)

    # -- output -------------------------------------------------------------
    def snapshot(self) -> dict:
        """Everything, as ``{name: [{labels, ...value(s)}]}``."""
        out: Dict[str, List[dict]] = {}
        for (name, key), metric in sorted(self._metrics.items()):
            row: dict = {"labels": dict(key)}
            if isinstance(metric, Histogram):
                row.update(metric.snapshot())
            else:
                row["value"] = metric.value
            out.setdefault(name, []).append(row)
        return out

    def render(self) -> str:
        """Prometheus-style text exposition."""
        by_name: Dict[str, List[Tuple[_LabelKey, object]]] = {}
        for (name, key), metric in sorted(self._metrics.items()):
            by_name.setdefault(name, []).append((key, metric))
        lines: List[str] = []
        for name, rows in by_name.items():
            full = f"{self.namespace}_{name}"
            kind = self._kinds[name]
            if name in self._help:
                lines.append(f"# HELP {full} {self._help[name]}")
            lines.append(f"# TYPE {full} {kind}")
            for key, metric in rows:
                if isinstance(metric, Histogram):
                    for q in _QUANTILES:
                        lab = _render_labels(key, (("quantile", str(q)),))
                        lines.append(f"{full}{lab} {metric.quantile(q):g}")
                    lab = _render_labels(key)
                    lines.append(f"{full}_sum{lab} {metric.sum:g}")
                    lines.append(f"{full}_count{lab} {metric.count}")
                else:
                    lab = _render_labels(key)
                    lines.append(f"{full}{lab} {metric.value:g}")
        return "\n".join(lines) + "\n"


def system_metrics(server=None,
                   registry: Optional[MetricsRegistry] = None,
                   scheduler=None) -> MetricsRegistry:
    """Collect the system's scattered stats into one registry: planner
    counters + plan cache, event log, tracer buffer — and, when given
    an ``AdaptiveServer``, its arbiter, queue, and per-tenant telemetry
    (shard degree, comm share, and SLO outcome columns included).
    ``scheduler=`` (an ``SLOScheduler``) adds per-tenant queue-depth
    gauges and the scheduler-level shed/preemption counters; its server
    is collected automatically when ``server`` is omitted."""
    reg = registry if registry is not None else MetricsRegistry()
    if server is None and scheduler is not None:
        server = scheduler.server

    from repro.core.plan import STATS, plan_cache_stats
    cache = plan_cache_stats()
    reg.gauge("plan_cache_size", "entries in the LRU plan cache").set(
        cache["size"])
    reg.gauge("plan_cache_capacity").set(cache["capacity"])
    reg.gauge("plan_cache_hit_rate", "hits / lookups since start").set(
        cache["hit_rate"])
    for field, value in STATS.snapshot().items():
        reg.counter(f"planner_{field}_total",
                    "planner counter (core.plan.PlannerStats)").inc(value)

    from repro.obs.trace import EVENTS, TRACER
    for kind, n in sorted(EVENTS.counts().items()):
        reg.counter("events_total", "event-log entries in window",
                    kind=kind).inc(n)
    tstats = TRACER.stats()
    reg.gauge("tracer_enabled").set(1.0 if tstats["enabled"] else 0.0)
    reg.gauge("tracer_buffered_events").set(tstats["events"])
    reg.counter("tracer_dropped_events_total").inc(tstats["dropped"])

    if server is not None:
        reg.gauge("server_pending_requests",
                  "requests waiting in the shape-bucket queue").set(
            server.pending())
        reg.counter("arbiter_rebalances_total",
                    "grant moves past hysteresis").inc(
            server.arbiter.rebalances)
        for name, snap in server.telemetry().items():
            reg.counter("tenant_requests_total", "served requests",
                        tenant=name).inc(snap["requests"])
            reg.counter("tenant_batches_total", "executed batches",
                        tenant=name).inc(snap["batches"])
            reg.counter("tenant_replans_total",
                        "grant moves that forced a re-plan",
                        tenant=name).inc(snap["replans"])
            reg.gauge("tenant_granted_fraction",
                      "current device fraction", tenant=name).set(
                snap["granted_fraction"])
            reg.gauge("tenant_batch_occupancy", tenant=name).set(
                snap["batch_occupancy"])
            reg.gauge("tenant_lowered_fraction",
                      "site executions below native width",
                      tenant=name).set(snap["lowered_fraction"])
            reg.gauge("tenant_shard_degree",
                      "max shard degree served (1 = replicated)",
                      tenant=name).set(snap["shard_degree"])
            reg.gauge("tenant_comm_cycles_share",
                      "collective cycles / total est cycles",
                      tenant=name).set(snap["comm_cycles_share"])
            # SLO outcome columns (dual clock: the latency summary
            # below stays est-cycles; wall seconds get their own one)
            reg.gauge("tenant_deadline_miss_rate",
                      "(late completions + shed) / SLO-tracked",
                      tenant=name).set(snap["deadline_miss_rate"])
            reg.counter("tenant_deadline_misses_total",
                        "late completions + shed", tenant=name).inc(
                snap["deadline_misses"])
            reg.counter("tenant_shed_total",
                        "requests dropped as already-hopeless",
                        tenant=name).inc(snap["shed"])
            reg.counter("tenant_preemptions_total",
                        "priority dispatches past a queued bucket",
                        tenant=name).inc(snap["preemptions"])
            hist = reg.histogram("tenant_latency_cycles",
                                 "request latency in est-cycles",
                                 tenant=name)
            tenant = server.tenants[name]
            hist.observe_many(tenant.telemetry.latencies)
            whist = reg.histogram("tenant_wall_latency_seconds",
                                  "measured wall latency of SLO-tracked "
                                  "requests", tenant=name)
            whist.observe_many(tenant.telemetry.wall_latencies)
    if scheduler is not None:
        for name, depth in scheduler.stats()["queue_depths"].items():
            reg.gauge("scheduler_queue_depth",
                      "admitted-but-unlaunched requests",
                      tenant=name).set(depth)
        reg.gauge("scheduler_pending_requests",
                  "queued + deferred requests awaiting a verdict").set(
            scheduler.pending())
        reg.counter("scheduler_launches_total").inc(scheduler.launches)
        reg.counter("scheduler_sheds_total").inc(scheduler.sheds)
        reg.counter("scheduler_rejections_total",
                    "admissions past max_queue_depth").inc(
            scheduler.rejections)
        reg.counter("scheduler_preemptions_total").inc(
            scheduler.preemptions)
    return reg
