"""Calibration drift monitor — is the cost model still telling the truth?

PR 6's ``CalibrationTable`` fits predicted wall-clock from footprint
axes; every planning decision then optimizes those predictions.  But a
fit is a snapshot of one host at one moment — thermal state, co-tenant
load, a library upgrade, or simply serving shapes the warmup never
measured all move the truth out from under the table, and a planner
optimizing a silently-drifted objective caps the whole system (ROADMAP).

``DriftMonitor`` closes the loop online:

* ``observe(member, footprint, measured_us)`` — compare the table's
  prediction for the executed variant against what the stopwatch just
  said; relative errors accumulate in a rolling window.
* **Drift rule**: once at least ``min_observations`` predictions are in
  the window, the monitor flags when their *mean relative error*
  exceeds ``threshold``.  The flag fires once per excursion (an
  ``on_drift`` callback plus a ``calibration.drift`` event in the
  event log), not once per observation.
* ``recalibrate()`` — the hook back into ``core/calibrate_cost.py``:
  every buffered observation is recorded as a calibration sample and
  the table refit, which moves its fingerprint (so the planner's
  memoized plans invalidate, per the calibration contract), clears the
  window, and re-arms the monitor.

Observations for members the table has no fit for (``predict_us`` is
None) are buffered for recalibration but produce no verdict — you
cannot drift from a prediction that was never made.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional, Tuple

from repro.obs.trace import log_event

DRIFT_THRESHOLD = 0.5       # mean relative error that flags drift
DRIFT_WINDOW = 64           # observations the rolling mean covers
MIN_OBSERVATIONS = 4        # no verdict on fewer predictions
_BUFFER_MAX = 512           # recalibration samples kept


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """One drift excursion: the window statistics at the moment the
    monitor flagged."""

    mean_rel_error: float
    threshold: float
    n_observations: int
    worst_member: str
    worst_rel_error: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class DriftMonitor:
    """Online predicted-vs-measured comparison; see module docstring."""

    def __init__(self, table, *, threshold: float = DRIFT_THRESHOLD,
                 window: int = DRIFT_WINDOW,
                 min_observations: int = MIN_OBSERVATIONS,
                 on_drift: Optional[Callable[[DriftReport], None]] = None):
        if threshold <= 0:
            raise ValueError("threshold must be positive")
        self.table = table
        self.threshold = float(threshold)
        self.min_observations = int(min_observations)
        self.on_drift = on_drift
        self.drifted = False
        self.reports: List[DriftReport] = []
        self.observations = 0           # total observe() calls
        self.predictions = 0            # observations the table covered
        # (member, rel_error) pairs the rolling mean covers
        self._window: Deque[Tuple[str, float]] = deque(maxlen=window)
        # (member, footprint, measured_us) buffered for recalibrate()
        self._buffer: List[tuple] = []

    # -- observation --------------------------------------------------------
    def observe(self, member: str, footprint,
                measured_us: float) -> Optional[DriftReport]:
        """Fold one measurement in.  ``member`` is the executed-variant
        key (``member_key(ip, bits, native)`` for lowered rungs).
        Returns the ``DriftReport`` when this observation trips the
        monitor, else None."""
        measured_us = float(measured_us)
        self.observations += 1
        self._buffer.append((member, footprint, measured_us))
        if len(self._buffer) > _BUFFER_MAX:
            del self._buffer[:len(self._buffer) - _BUFFER_MAX]
        predicted = self.table.predict_us(
            member, footprint.compute_cycles, footprint.hbm_bytes,
            footprint.comm_cycles)
        if predicted is None:
            return None                 # no fit -> no verdict
        self.predictions += 1
        rel = abs(predicted - measured_us) / max(measured_us, 1e-9)
        self._window.append((member, rel))
        if self.drifted or len(self._window) < self.min_observations:
            return None
        mean = sum(r for _, r in self._window) / len(self._window)
        if mean <= self.threshold:
            return None
        worst_member, worst = max(self._window, key=lambda t: t[1])
        report = DriftReport(
            mean_rel_error=mean, threshold=self.threshold,
            n_observations=len(self._window),
            worst_member=worst_member, worst_rel_error=worst)
        self.drifted = True
        self.reports.append(report)
        log_event("calibration.drift", mean_rel_error=mean,
                  threshold=self.threshold, n=len(self._window),
                  worst_member=worst_member)
        if self.on_drift is not None:
            self.on_drift(report)
        return report

    @property
    def mean_rel_error(self) -> float:
        if not self._window:
            return 0.0
        return sum(r for _, r in self._window) / len(self._window)

    def snapshot(self) -> dict:
        return {
            "drifted": self.drifted,
            "mean_rel_error": self.mean_rel_error,
            "threshold": self.threshold,
            "window": len(self._window),
            "observations": self.observations,
            "predictions": self.predictions,
            "excursions": len(self.reports),
            "table_fingerprint": self.table.fingerprint(),
        }

    # -- the recalibration hook --------------------------------------------
    def recalibrate(self) -> str:
        """Fold every buffered observation into the table as calibration
        samples (``CalibrationTable.record``), refit, clear the window,
        and re-arm.  Returns the table's new fingerprint — refitting
        moves it, so memoized plans keyed on the old identity invalidate
        exactly as the calibration contract requires."""
        for member, footprint, measured_us in self._buffer:
            self.table.record(member, footprint, measured_us)
        self.table.fit()
        self._buffer.clear()
        self._window.clear()
        self.drifted = False
        fp = self.table.fingerprint()
        log_event("calibration.refit", fingerprint=fp,
                  samples=self.table.sample_count())
        return fp


def mis_scaled_table(table, scale: float):
    """A copy of ``table`` with every fit's coefficients multiplied by
    ``scale`` — the synthetic "this table is lying" counterfactual the
    drift bench and tests feed the monitor (the honest table must stay
    quiet on the same measurements; the mis-scaled one must trip)."""
    import dataclasses as dc

    from repro.core.calibrate_cost import CalibrationTable

    def scaled(fit):
        return dc.replace(
            fit,
            us_per_compute_cycle=fit.us_per_compute_cycle * scale,
            us_per_hbm_byte=fit.us_per_hbm_byte * scale,
            us_per_comm_cycle=fit.us_per_comm_cycle * scale,
            overhead_us=fit.overhead_us * scale)

    return CalibrationTable(
        samples=list(table.samples),
        fits={m: scaled(f) for m, f in table.fits.items()},
        global_fit=(scaled(table.global_fit)
                    if table.global_fit is not None else None),
        min_samples=table.min_samples)
