"""Cross-layer observability: span tracing, plan decision audit,
metrics exposition, calibration-drift detection.

The planner picks members, the arbiter moves grants, the mesh pass
prices collectives, and the calibration table claims to predict
wall-clock — this package is how an operator *sees* any of it:

* ``obs.trace``   — low-overhead span tracer (Chrome trace-event JSON,
  Perfetto-loadable) + the always-on bounded event log for operator
  events (watchdog firings, plan-cache evictions, drift trips).
* ``obs.audit``   — the plan decision audit: per-site candidate sets
  with concrete rejection reasons, surfaced via
  ``NetworkPlan.explain()``.
* ``obs.metrics`` — one registry unifying the scattered stats (plan
  cache, arbiter, tenant telemetry, queue depth) behind a snapshot and
  Prometheus-style text exposition; owns the shared percentile
  estimator ``telemetry.latency_percentile`` delegates to.
* ``obs.drift``   — online comparison of calibrated predictions vs
  measured wall-clock, flagging when the table has drifted, with a
  recalibration hook back into ``core/calibrate_cost.py``.

Import discipline: these modules import nothing from ``repro.core`` or
``repro.runtime`` at module level (collector functions import lazily),
so the planner and the runtime can import obs without cycles.  See
docs/adaptive_ips.md, "Observability contract".
"""
from repro.obs.audit import (CandidateRecord, PlanAudit, SiteAudit,
                             SiteAuditRecorder, unfit_reason)
from repro.obs.drift import DriftMonitor, DriftReport, mis_scaled_table
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               percentile, system_metrics)
from repro.obs.trace import (EVENTS, NOOP_SPAN, TRACER, EventLog, SpanTracer,
                             log_event)

__all__ = [
    "CandidateRecord", "PlanAudit", "SiteAudit", "SiteAuditRecorder",
    "unfit_reason",
    "DriftMonitor", "DriftReport", "mis_scaled_table",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "percentile",
    "system_metrics",
    "EVENTS", "NOOP_SPAN", "TRACER", "EventLog", "SpanTracer", "log_event",
]
