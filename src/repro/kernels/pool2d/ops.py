"""Public jit'd wrappers for the pool2d IP family.

`pool2d` takes an explicit ``ip=`` name or a ``budget=``
(ResourceBudget) and defers to the resource-driven selector, mirroring
`kernels/conv2d/ops.py`.  ``ladder=`` allows the planner to lower the
call's operand width; lowered plans execute through
``repro.quant.ops.quantized_pool2d`` and return float.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.resources import ResourceBudget
from repro.kernels.pool2d.mxu_im2col import pool2d_im2col
from repro.kernels.pool2d.ref import check_pool_geometry
from repro.kernels.pool2d.vpu_window import pool2d_window

_MEMBERS = {"pool_vpu": pool2d_window, "pool_im2col": pool2d_im2col}


def pool2d(x: jnp.ndarray, *, window=(2, 2), stride=None, mode: str = "max",
           ip: Optional[str] = None,
           budget: Optional[ResourceBudget] = None, ladder=(),
           interpret: bool = True) -> jnp.ndarray:
    """Max/avg pooling through a selected IP (Pool1/Pool2)."""
    if mode not in ("max", "avg"):
        raise ValueError(f"unknown pool mode {mode!r}; have ('max', 'avg')")
    window, stride = check_pool_geometry(x.shape, window, stride)
    if ip is None:
        from repro.core.ip import SiteSpec
        from repro.core.plan import plan_single
        spec = SiteSpec.make("pool2d", "pool2d", (x.shape,), x.dtype,
                             ladder=ladder, window=window, stride=stride,
                             mode=mode)
        planned = plan_single(spec, budget)
        if planned.lowered:
            from repro.quant.ops import quantized_pool2d
            return quantized_pool2d(x, window=window, stride=stride,
                                    mode=mode, bits=planned.precision_bits,
                                    ip=planned.ip.name, interpret=interpret)
        ip = planned.ip.name
    ip = ip.split(".")[-1]
    if ip not in _MEMBERS:
        raise KeyError(f"{ip!r} is not a pool2d IP (have {sorted(_MEMBERS)})")
    return _MEMBERS[ip](x, window=window, stride=stride, mode=mode,
                        interpret=interpret)
