"""Pool2 — im2col pooling (Conv2-style IP: patch matrix built in VMEM).

The KHxKW taps are stacked into a patch tensor inside VMEM, then reduced
in one shot: for ``avg`` the reduction collapses into a single MXU pass
(a ones-vector contraction over the tap axis, int32/f32 accumulation,
matching the oracle's fixed-point floor division); for ``max`` the
stacked tensor is reduced with one vectorized max over the tap axis.
Minimal per-tap vector logic at the cost of a KH*KW-times-larger VMEM
working set — the paper's "ideal for FPGAs with DSP availability and
limited logic resources", pooling edition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.resources import (Footprint, cost_cycles, hbm_cycles,
                                  mxu_pass_cycles, vpu_op_cycles)
from repro.kernels.pool2d.ref import norm_window_stride, pool_dtypes


def _kernel(x_ref, o_ref, *, kh, kw, sh, sw, mode, acc_dtype):
    ho, wo = o_ref.shape[1], o_ref.shape[2]
    bc = o_ref.shape[3]
    x = x_ref[0]
    taps = []
    for i in range(kh):
        for j in range(kw):
            taps.append(x[i:i + (ho - 1) * sh + 1:sh,
                          j:j + (wo - 1) * sw + 1:sw, :])
    patches = jnp.stack(taps, axis=0)                 # (KH*KW, Ho, Wo, bc)
    if mode == "max":
        o_ref[0] = jnp.max(patches, axis=0)
        return
    # THE single MXU pass: ones(1, KH*KW) @ patches(KH*KW, Ho*Wo*bc).
    mat = patches.astype(acc_dtype).reshape(kh * kw, ho * wo * bc)
    ones = jnp.ones((1, kh * kw), acc_dtype)
    acc = jnp.dot(ones, mat, preferred_element_type=acc_dtype)
    count = kh * kw
    if jnp.issubdtype(acc_dtype, jnp.integer):
        acc = acc // count
    else:
        acc = acc / count
    o_ref[0] = acc.reshape(ho, wo, bc)


@functools.partial(jax.jit,
                   static_argnames=("window", "stride", "mode", "block_c",
                                    "interpret"))
def pool2d_im2col(x: jnp.ndarray, *, window=(2, 2), stride=None,
                  mode: str = "max", block_c: int = 128,
                  interpret: bool = True) -> jnp.ndarray:
    (kh, kw), (sh, sw) = norm_window_stride(window, stride)
    n, h, w, c = x.shape
    ho, wo = (h - kh) // sh + 1, (w - kw) // sw + 1
    acc_dtype, out_dtype = pool_dtypes(x.dtype, mode)
    bc = min(block_c, c)
    grid = (n, pl.cdiv(c, bc))
    return pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, sh=sh, sw=sw, mode=mode,
                          acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((1, h, w, bc), lambda b, ci: (b, 0, 0, ci))],
        out_specs=pl.BlockSpec((1, ho, wo, bc), lambda b, ci: (b, 0, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, c), out_dtype),
        interpret=interpret,
    )(x)


def footprint(n, h, w, c, kh, kw, sh, sw, *, itemsize=1, mode="max",
              block_c: int = 128) -> Footprint:
    ho, wo = (h - kh) // sh + 1, (w - kw) // sw + 1
    bc = min(block_c, c)
    out_item = itemsize if mode == "max" else 4
    taps = kh * kw
    # avg materializes a second, 4-byte-accumulator copy of the patches.
    patch_item = itemsize if mode == "max" else itemsize + 4
    vmem = (h * w * bc * itemsize
            + taps * ho * wo * bc * patch_item    # stacked patch tensor
            + ho * wo * bc * out_item)
    hbm = n * h * w * c * itemsize + n * ho * wo * c * out_item
    grid_steps = n * ((c + bc - 1) // bc)
    # Patch construction is pure data movement: one op per tap element.
    move = n * ho * wo * c * taps
    if mode == "avg":
        passes = grid_steps
        cyc = grid_steps * mxu_pass_cycles(1, taps, ho * wo * bc)
        vpu = move
    else:
        passes = 0
        cyc = 0.0
        vpu = 2 * move          # movement + the vectorized max reduce
    return Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=passes,
                     vpu_ops=vpu,
                     est_cycles=cost_cycles(max(cyc, vpu_op_cycles(vpu)), hbm),
                     outputs_per_pass=1, max_operand_bits=32)
