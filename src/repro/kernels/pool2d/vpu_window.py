"""Pool1 — windowed-reduce pooling on the VPU (Conv1-style logic-only IP).

The kernel body issues no dot op: the KHxKW window reduction runs as an
unrolled chain of strided-slice compares (max) or adds (avg) over the
image plane — one VPU op per tap per output element, zero MXU passes.
This is the member the selector picks when the MXU is spoken for,
mirroring the paper's "suitable for FPGAs with limited DSPs".

Tiling: grid over (batch, channel tiles).  Each grid step holds one
input plane (H, W, bc) and one output plane (Ho, Wo, bc) in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.resources import (Footprint, cost_cycles, hbm_cycles,
                                  vpu_op_cycles)
from repro.kernels.pool2d.ref import norm_window_stride, pool_dtypes


def window_reduce(x, *, ho, wo, kh, kw, sh, sw, mode, acc_dtype):
    """The family's windowed reduce on an already-resident (H, W, C)
    tile: an unrolled chain of strided-slice compares (max) or adds
    (avg), returning (Ho, Wo, C).  Shared verbatim by the standalone
    kernel below and the fused conv->pool->act members
    (``kernels/fused/cnn_block.py``) so the two paths cannot drift."""
    if mode == "avg":
        x = x.astype(acc_dtype)
    acc = None
    for i in range(kh):
        for j in range(kw):
            win = x[i:i + (ho - 1) * sh + 1:sh,
                    j:j + (wo - 1) * sw + 1:sw, :]       # (Ho, Wo, bc)
            if acc is None:
                acc = win
            elif mode == "max":
                acc = jnp.maximum(acc, win)
            else:
                acc = acc + win
    if mode == "avg":
        count = kh * kw
        if jnp.issubdtype(acc_dtype, jnp.integer):
            acc = acc // count
        else:
            acc = acc / count
    return acc


def _kernel(x_ref, o_ref, *, kh, kw, sh, sw, mode, acc_dtype):
    o_ref[0] = window_reduce(x_ref[0], ho=o_ref.shape[1], wo=o_ref.shape[2],
                             kh=kh, kw=kw, sh=sh, sw=sw, mode=mode,
                             acc_dtype=acc_dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "stride", "mode", "block_c",
                                    "interpret"))
def pool2d_window(x: jnp.ndarray, *, window=(2, 2), stride=None,
                  mode: str = "max", block_c: int = 128,
                  interpret: bool = True) -> jnp.ndarray:
    (kh, kw), (sh, sw) = norm_window_stride(window, stride)
    n, h, w, c = x.shape
    ho, wo = (h - kh) // sh + 1, (w - kw) // sw + 1
    acc_dtype, out_dtype = pool_dtypes(x.dtype, mode)
    bc = min(block_c, c)
    grid = (n, pl.cdiv(c, bc))
    return pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, sh=sh, sw=sw, mode=mode,
                          acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((1, h, w, bc), lambda b, ci: (b, 0, 0, ci))],
        out_specs=pl.BlockSpec((1, ho, wo, bc), lambda b, ci: (b, 0, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, c), out_dtype),
        interpret=interpret,
    )(x)


def footprint(n, h, w, c, kh, kw, sh, sw, *, itemsize=1, mode="max",
              block_c: int = 128) -> Footprint:
    ho, wo = (h - kh) // sh + 1, (w - kw) // sw + 1
    bc = min(block_c, c)
    out_item = itemsize if mode == "max" else 4
    # avg casts the plane to the 4-byte accumulator dtype inside VMEM.
    cast_plane = 0 if mode == "max" else h * w * bc * 4
    vmem = (h * w * bc * itemsize                 # input plane
            + cast_plane
            + ho * wo * bc * out_item)            # output plane
    hbm = n * h * w * c * itemsize + n * ho * wo * c * out_item
    # One compare/add per tap, plus the strided gather for each window.
    vpu = 2 * n * ho * wo * c * kh * kw
    return Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=0,
                     vpu_ops=vpu,
                     est_cycles=cost_cycles(vpu_op_cycles(vpu), hbm),
                     outputs_per_pass=1, max_operand_bits=32)
