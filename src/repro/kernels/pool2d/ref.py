"""Pure-jnp oracle for the pool2d IP family.

Contract shared by all pool IPs:
  x      : (N, H, W, C)   activations (int8/int32 fixed-point or float)
  window : (KH, KW)       pooling window
  stride : (SH, SW)       defaults to the window (non-overlapping)
  y      : (N, (H-KH)//SH+1, (W-KW)//SW+1, C)   VALID padding

``mode="max"`` preserves the input dtype (no accumulation happens).
``mode="avg"`` accumulates integers exactly in int32 and divides by the
window size with floor division (the paper's fixed-point contract);
float inputs accumulate in float32 and divide exactly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
from jax import lax


def norm_window_stride(window, stride) -> Tuple[Tuple[int, int],
                                                Tuple[int, int]]:
    """Single source of truth for window/stride normalization: scalars
    broadcast to both axes, stride defaults to the window."""
    kh, kw = (window, window) if isinstance(window, int) else window
    if stride is None:
        sh, sw = kh, kw
    else:
        sh, sw = (stride, stride) if isinstance(stride, int) else stride
    return (kh, kw), (sh, sw)


def pool_dtypes(x_dtype, mode: str):
    """Single source of truth for the family's dtype promotion rule:
    max preserves the input dtype (no accumulation); avg accumulates
    integers in int32 (floor division) and floats in float32."""
    if mode == "max":
        return x_dtype, x_dtype
    acc = (jnp.int32 if jnp.issubdtype(jnp.dtype(x_dtype), jnp.integer)
           else jnp.float32)
    return acc, acc


def check_pool_geometry(x_shape, window, stride):
    """Normalize and validate: raises if the window exceeds the plane."""
    (kh, kw), (sh, sw) = norm_window_stride(window, stride)
    _, h, w, _ = x_shape
    if kh > h or kw > w:
        raise ValueError(f"pool window {(kh, kw)} exceeds the input plane "
                         f"({h}, {w}) of {tuple(x_shape)}")
    return (kh, kw), (sh, sw)


def pool2d_ref(x: jnp.ndarray, *, window=(2, 2),
               stride: Optional[Tuple[int, int]] = None,
               mode: str = "max") -> jnp.ndarray:
    (kh, kw), (sh, sw) = norm_window_stride(window, stride)
    dims = (1, kh, kw, 1)
    strides = (1, sh, sw, 1)
    if mode == "max":
        init = (jnp.iinfo(x.dtype).min
                if jnp.issubdtype(x.dtype, jnp.integer) else -jnp.inf)
        return lax.reduce_window(x, jnp.asarray(init, x.dtype), lax.max,
                                 dims, strides, "VALID")
    if mode != "avg":
        raise ValueError(f"unknown pool mode {mode!r}")
    acc_dtype, _ = pool_dtypes(x.dtype, mode)
    acc = lax.reduce_window(x.astype(acc_dtype), acc_dtype(0), lax.add,
                            dims, strides, "VALID")
    if jnp.issubdtype(acc_dtype, jnp.integer):
        return acc // (kh * kw)
    return acc / (kh * kw)


def pool2d_out_shape(x_shape, window, stride=None):
    (kh, kw), (sh, sw) = norm_window_stride(window, stride)
    n, h, w, c = x_shape
    return (n, (h - kh) // sh + 1, (w - kw) // sw + 1, c)
