"""Conv4 — dual-MXU parallel convolution (paper: 2 DSPs, two convs/pass,
full precision).

Parallelism via resource duplication: the two activation streams are
stacked on a batch axis and one batched `dot_general` issues **two MXU
pass groups** — the TPU reading of "two DSP slices running in
parallel".  Full operand width (int8/int16/bf16/f32), unlike Conv3.
The weight tile is fetched once and shared by both streams (the
paper's serial-coefficient-load economy).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.resources import (Footprint, cost_cycles, hbm_cycles,
                                  mxu_pass_cycles)


def _kernel(xa_ref, xb_ref, w_ref, oa_ref, ob_ref, *, kh: int, kw: int,
            acc_dtype):
    ho, wo = oa_ref.shape[1], oa_ref.shape[2]
    cin = xa_ref.shape[3]

    def im2col(x):
        cols = []
        for i in range(kh):
            for j in range(kw):
                cols.append(x[i:i + ho, j:j + wo, :])
        return jnp.concatenate(cols, axis=-1).reshape(ho * wo, kh * kw * cin)

    patches = jnp.stack([im2col(xa_ref[0]), im2col(xb_ref[0])])  # (2, M, K)
    wmat = w_ref[...].reshape(kh * kw * cin, -1)                 # (K, bc)
    # Batched dot: two parallel MXU pass groups sharing one weight tile.
    acc = lax.dot_general(
        patches, wmat,
        dimension_numbers=(((2,), (0,)), ((), ())),
        preferred_element_type=acc_dtype)                        # (2, M, bc)
    oa_ref[0] = acc[0].reshape(ho, wo, -1)
    ob_ref[0] = acc[1].reshape(ho, wo, -1)


@functools.partial(jax.jit, static_argnames=("block_cout", "interpret"))
def conv2d_ip4(xa: jnp.ndarray, xb: jnp.ndarray, w: jnp.ndarray, *,
               block_cout: int = 128, interpret: bool = True):
    n, h, w_, cin = xa.shape
    kh, kw, _, cout = w.shape
    ho, wo = h - kh + 1, w_ - kw + 1
    acc_dtype = (jnp.int32 if jnp.issubdtype(xa.dtype, jnp.integer)
                 else jnp.float32)
    bc = min(block_cout, cout)
    grid = (n, pl.cdiv(cout, bc))
    img = pl.BlockSpec((1, h, w_, cin), lambda b, c: (b, 0, 0, 0))
    out = pl.BlockSpec((1, ho, wo, bc), lambda b, c: (b, 0, 0, c))
    return pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[img, img,
                  pl.BlockSpec((kh, kw, cin, bc), lambda b, c: (0, 0, 0, c))],
        out_specs=[out, out],
        out_shape=[jax.ShapeDtypeStruct((n, ho, wo, cout), acc_dtype),
                   jax.ShapeDtypeStruct((n, ho, wo, cout), acc_dtype)],
        interpret=interpret,
    )(xa, xb, w)


def footprint(n, h, w, cin, kh, kw, cout, *, itemsize=1,
              block_cout: int = 128) -> Footprint:
    ho, wo = h - kh + 1, w - kw + 1
    bc = min(block_cout, cout)
    k = kh * kw * cin
    vmem = (2 * h * w * cin * itemsize
            + 2 * ho * wo * k * itemsize
            + k * bc * itemsize
            + 2 * ho * wo * bc * 4)
    hbm = (2 * n * h * w * cin * itemsize
           + kh * kw * cin * cout * itemsize   # weights fetched ONCE
           + 2 * n * ho * wo * cout * 4)
    passes = 2 * n * ((cout + bc - 1) // bc)
    cyc = 2 * n * mxu_pass_cycles(ho * wo, k, cout)
    vpu = 2 * n * ho * wo * k
    return Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=passes,
                     vpu_ops=vpu,
                     est_cycles=cost_cycles(cyc, hbm),
                     outputs_per_pass=2, max_operand_bits=32)
