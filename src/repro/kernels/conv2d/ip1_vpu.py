"""Conv1 — logic-only convolution (paper: 0 DSP, high LUT/CLB usage).

TPU-native reading: the kernel body issues **no dot op** — every
multiply-accumulate runs on the VPU as an elementwise shifted
multiply-add over the image plane.  High vector-op count, zero MXU
passes.  This is the variant the selector picks when the MXU is
unavailable / saturated (budget.mxu_available=False), exactly the
paper's "suitable for FPGAs with limited DSPs".

Tiling: grid over (batch, Cout tiles).  Each grid step holds one image
plane (H, W, Cin), one weight tile (KH, KW, Cin, bc) and one output
plane (Ho, Wo, bc) in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.resources import (Footprint, cost_cycles, hbm_cycles,
                                  vpu_op_cycles)
from repro.kernels.conv2d.inner import accumulate_vpu


def _kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, acc_dtype):
    # x_ref: (1, H, W, Cin); w_ref: (kh, kw, Cin, bc); o_ref: (1, Ho, Wo, bc)
    x = x_ref[0].astype(acc_dtype)                      # (H, W, Cin)
    o_ref[0] = accumulate_vpu(x, w_ref, ho=o_ref.shape[1], wo=o_ref.shape[2],
                              kh=kh, kw=kw, acc_dtype=acc_dtype)


@functools.partial(jax.jit, static_argnames=("block_cout", "interpret"))
def conv2d_ip1(x: jnp.ndarray, w: jnp.ndarray, *,
               block_cout: int = 128, interpret: bool = True) -> jnp.ndarray:
    n, h, w_, cin = x.shape
    kh, kw, _, cout = w.shape
    ho, wo = h - kh + 1, w_ - kw + 1
    acc_dtype = (jnp.int32 if jnp.issubdtype(x.dtype, jnp.integer)
                 else jnp.float32)
    bc = min(block_cout, cout)
    grid = (n, pl.cdiv(cout, bc))
    return pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h, w_, cin), lambda b, c: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, bc), lambda b, c: (0, 0, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, bc), lambda b, c: (b, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, cout), acc_dtype),
        interpret=interpret,
    )(x, w)


def footprint(n, h, w, cin, kh, kw, cout, *, itemsize=1,
              block_cout: int = 128) -> Footprint:
    ho, wo = h - kh + 1, w - kw + 1
    bc = min(block_cout, cout)
    vmem = (h * w * cin * itemsize            # x plane
            + kh * kw * cin * bc * itemsize   # weight tile
            + ho * wo * bc * 4)               # int32/f32 accumulator
    hbm = (n * h * w * cin * itemsize
           + kh * kw * cin * cout * itemsize
           + n * ho * wo * cout * 4)
    vpu = n * ho * wo * cout * kh * kw * cin * 2   # mul+add per tap
    return Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=0,
                     vpu_ops=vpu,
                     est_cycles=cost_cycles(vpu_op_cycles(vpu), hbm),
                     outputs_per_pass=1, max_operand_bits=32)
