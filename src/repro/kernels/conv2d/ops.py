"""Public jit'd wrappers for the conv2d IP family.

`conv2d` / `conv2d_dual` take an explicit ``ip=`` name or a
``budget=`` (ResourceBudget) and defer to the resource-driven selector
— the paper's "automatic adaptation to the available resources".

``ladder=`` (e.g. ``(16, 8)``) lets the planner lower this call's
operand width when it cannot fit at native precision; a lowered plan
executes transparently through the quantized path
(``repro.quant.ops.quantized_conv2d``) and still returns float.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.resources import ResourceBudget
from repro.kernels.conv2d.ip1_vpu import conv2d_ip1
from repro.kernels.conv2d.ip2_mxu import conv2d_ip2
from repro.kernels.conv2d.ip3_packed import conv2d_ip3
from repro.kernels.conv2d.ip4_dual import conv2d_ip4

_SINGLE = {"ip1_vpu": conv2d_ip1, "ip2_mxu": conv2d_ip2}
_DUAL = {"ip3_packed": conv2d_ip3, "ip4_dual": conv2d_ip4}


def _maybe_reduce(y: jnp.ndarray, reduce_axis: Optional[str],
                  reduce: str) -> jnp.ndarray:
    """The channel-split hook: inside ``shard_map``, a conv whose input
    channels are sharded produces a *partial* sum — summing the partials
    over the mesh axis makes it the full output on every device.
    ``reduce="psum"`` is the XLA reference; ``"ring"`` goes through the
    explicit ppermute ring (``distributed/collectives.py``)."""
    if reduce_axis is None:
        return y
    if reduce == "ring":
        from repro.distributed.collectives import ring_all_reduce
        return ring_all_reduce(y, reduce_axis)
    if reduce != "psum":
        raise ValueError(f"unknown reduce {reduce!r}; have ('psum', 'ring')")
    import jax
    return jax.lax.psum(y, reduce_axis)


def conv2d(x: jnp.ndarray, w: jnp.ndarray, *, ip: Optional[str] = None,
           budget: Optional[ResourceBudget] = None, ladder=(),
           interpret: bool = True, reduce_axis: Optional[str] = None,
           reduce: str = "psum", **tile_kwargs) -> jnp.ndarray:
    """Single-stream convolution through a selected IP (Conv1/Conv2).

    ``tile_kwargs`` forward tiling parameters to the member (e.g.
    ``block_cout=`` for ``ip2_mxu``, typically from
    ``core.autotune.plan_tile_overrides``); pass them only with an
    explicit ``ip=`` or a plan known to pick a member that accepts them.

    ``reduce_axis=`` is the mesh-sharded execution hook: under
    ``shard_map`` with input channels split across that named axis, each
    device's result is a partial sum and this call all-reduces it into
    the full output (``reduce=`` picks ``"psum"`` or the explicit
    ``"ring"`` path; see distributed/shard_exec.py).
    """
    if ip is None:
        from repro.core.ip import SiteSpec
        from repro.core.plan import plan_single
        spec = SiteSpec.make("conv2d", "conv2d", (x.shape, w.shape),
                             x.dtype, ladder=ladder, dual=False)
        planned = plan_single(spec, budget)
        if planned.lowered:
            from repro.quant.ops import quantized_conv2d
            y = quantized_conv2d(x, w, bits=planned.precision_bits,
                                 ip=planned.ip.name, interpret=interpret)
            return _maybe_reduce(y, reduce_axis, reduce)
        ip = planned.ip.name
    ip = ip.split(".")[-1]
    if ip not in _SINGLE:
        raise KeyError(f"{ip!r} is not a single-stream conv IP "
                       f"(have {sorted(_SINGLE)})")
    y = _SINGLE[ip](x, w, interpret=interpret, **tile_kwargs)
    return _maybe_reduce(y, reduce_axis, reduce)


def conv2d_dual(xa: jnp.ndarray, xb: jnp.ndarray, w: jnp.ndarray, *,
                ip: Optional[str] = None,
                budget: Optional[ResourceBudget] = None,
                interpret: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Two parallel convolutions through a selected IP (Conv3/Conv4).

    No ``ladder=``: dual-stream callers already commit to a concrete
    operand dtype per stream (Conv3 demands int8 inputs outright).
    """
    if ip is None:
        from repro.core.ip import SiteSpec
        from repro.core.plan import plan_single
        spec = SiteSpec.make("conv2d", "conv2d", (xa.shape, w.shape),
                             xa.dtype, dual=True)
        ip = plan_single(spec, budget).ip.name
    ip = ip.split(".")[-1]
    if ip not in _DUAL:
        raise KeyError(f"{ip!r} is not a dual-stream conv IP "
                       f"(have {sorted(_DUAL)})")
    return _DUAL[ip](xa, xb, w, interpret=interpret)
