"""Conv2 — single-MXU convolution (paper: 1 DSP, low logic).

TPU-native reading: im2col is built inside VMEM from shifted slices and
the whole tap reduction collapses into **one MXU pass** per grid step
(`jnp.dot` with int32/f32 accumulation).  Minimal vector logic — the
paper's "reduces the use of logic; ideal for FPGAs with DSP
availability and limited logic resources".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.resources import (Footprint, cost_cycles, hbm_cycles,
                                  mxu_pass_cycles)
from repro.kernels.conv2d.inner import accumulate_mxu


def _kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, acc_dtype):
    # x_ref: (1, H, W, Cin); w_ref: (kh, kw, Cin, bc); o_ref: (1, Ho, Wo, bc)
    o_ref[0] = accumulate_mxu(x_ref[0], w_ref, ho=o_ref.shape[1],
                              wo=o_ref.shape[2], kh=kh, kw=kw,
                              acc_dtype=acc_dtype)


@functools.partial(jax.jit, static_argnames=("block_cout", "interpret"))
def conv2d_ip2(x: jnp.ndarray, w: jnp.ndarray, *,
               block_cout: int = 128, interpret: bool = True) -> jnp.ndarray:
    n, h, w_, cin = x.shape
    kh, kw, _, cout = w.shape
    ho, wo = h - kh + 1, w_ - kw + 1
    acc_dtype = (jnp.int32 if jnp.issubdtype(x.dtype, jnp.integer)
                 else jnp.float32)
    bc = min(block_cout, cout)
    grid = (n, pl.cdiv(cout, bc))
    return pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h, w_, cin), lambda b, c: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, bc), lambda b, c: (0, 0, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, bc), lambda b, c: (b, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, cout), acc_dtype),
        interpret=interpret,
    )(x, w)


def footprint(n, h, w, cin, kh, kw, cout, *, itemsize=1,
              block_cout: int = 128) -> Footprint:
    ho, wo = h - kh + 1, w - kw + 1
    bc = min(block_cout, cout)
    k = kh * kw * cin
    vmem = (h * w * cin * itemsize
            + ho * wo * k * itemsize          # im2col patches
            + k * bc * itemsize
            + ho * wo * bc * 4)
    hbm = (n * h * w * cin * itemsize
           + kh * kw * cin * cout * itemsize
           + n * ho * wo * cout * 4)
    passes = n * ((cout + bc - 1) // bc)
    cyc = n * mxu_pass_cycles(ho * wo, k, cout)
    vpu = n * ho * wo * k                     # im2col data movement ops
    return Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=passes,
                     vpu_ops=vpu,
                     est_cycles=cost_cycles(cyc, hbm),
                     outputs_per_pass=1, max_operand_bits=32)
