"""Pure-jnp oracle for the conv2d IP family.

Contract shared by all four IPs:
  x : (N, H, W, Cin)            activations (int8 fixed-point or float)
  w : (KH, KW, Cin, Cout)       kernel coefficients
  y : (N, H-KH+1, W-KW+1, Cout) VALID padding, stride 1

Integer inputs accumulate exactly in int32 (the paper's fixed-point
contract); float inputs accumulate in float32.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def _acc_dtype(x_dtype, w_dtype):
    if jnp.issubdtype(x_dtype, jnp.integer) and jnp.issubdtype(w_dtype, jnp.integer):
        return jnp.int32
    return jnp.float32


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Reference convolution (cross-correlation, as in CNN frameworks)."""
    acc = _acc_dtype(x.dtype, w.dtype)
    out = lax.conv_general_dilated(
        x.astype(acc), w.astype(acc),
        window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=acc)
    return out


def conv2d_dual_ref(xa: jnp.ndarray, xb: jnp.ndarray, w: jnp.ndarray):
    """Two parallel convolutions sharing one kernel (Conv3/Conv4 contract)."""
    return conv2d_ref(xa, w), conv2d_ref(xb, w)
