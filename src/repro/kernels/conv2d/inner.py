"""Shared conv inner-loop bodies — the single source of the per-tile
convolution math.

The standalone members (``ip1_vpu``, ``ip2_mxu``) and the fused
conv->pool->act members (``kernels/fused/cnn_block.py``) compute the
same accumulator tile; keeping the loop bodies here means a fused kernel
cannot drift numerically from the standalone IP it absorbs — the fusion
tests assert bitwise equality in float32, and that only holds because
both paths run literally this code.

Both helpers take the *already-loaded* VMEM views (one image plane, one
weight tile) and return the (Ho, Wo, bc) accumulator; callers own the
Ref loads/stores and the grid.
"""
from __future__ import annotations

import jax.numpy as jnp


def accumulate_vpu(x, w_ref, *, ho: int, wo: int, kh: int, kw: int,
                   acc_dtype):
    """Conv1-style logic-only accumulation: unrolled shifted
    multiply-accumulate over the taps — pure VPU, no dot op.

    ``x``: (H, W, Cin) plane already cast to ``acc_dtype``;
    ``w_ref``: (kh, kw, Cin, bc) weight Ref.  Returns (Ho, Wo, bc).
    """
    acc = jnp.zeros((ho, wo, w_ref.shape[-1]), dtype=acc_dtype)
    for i in range(kh):
        for j in range(kw):
            window = x[i:i + ho, j:j + wo, :]           # (Ho, Wo, Cin)
            tap = w_ref[i, j].astype(acc_dtype)         # (Cin, bc)
            # Elementwise broadcast-multiply + reduce over Cin — the
            # reduce is a chain of adds, not a dot: keep it explicit so
            # Mosaic lowers it to VPU ops.
            prod = window[..., :, None] * tap[None, None, :, :]
            acc = acc + jnp.sum(prod, axis=2)
    return acc


def accumulate_mxu(x, w_ref, *, ho: int, wo: int, kh: int, kw: int,
                   acc_dtype):
    """Conv2-style accumulation: im2col built in VMEM from shifted
    slices, the whole tap reduction collapsing into ONE MXU pass.

    ``x``: (H, W, Cin) plane in the operand dtype; ``w_ref``:
    (kh, kw, Cin, bc) weight Ref.  Returns (Ho, Wo, bc).
    """
    cin = x.shape[-1]
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(x[i:i + ho, j:j + wo, :])
    patches = jnp.concatenate(cols, axis=-1).reshape(ho * wo, kh * kw * cin)
    wmat = w_ref[...].reshape(kh * kw * cin, -1)        # (kh*kw*Cin, bc)
    # THE single MXU pass:
    acc = jnp.dot(patches, wmat, preferred_element_type=acc_dtype)
    return acc.reshape(ho, wo, -1)
