"""Conv3 — operand-packed dual convolution (paper: 1 DSP, two convs/pass,
operands limited to 8 bits).

The paper's signature trick: two 8-bit products share one wide
multiplier.  On the FPGA that is the 27x18 DSP slice; on TPU the VPU's
int32 multiplier plays that role.  Packing:

    p   = (a << 16) + b          # a, b int8-valued, p int32
    m   = p * w                  # ONE multiply, |m| < 2^31
    bw  = ((m + 2^15) mod 2^16) - 2^15     # signed low half  == b*w  (|b*w| <= 127^2 < 2^15)
    aw  = (m - bw) >> 16                   # borrow-corrected high == a*w

Both products are exact (tests assert bit-exactness vs two independent
integer convolutions).  The FPGA DSP's 48-bit accumulator lets the
original design accumulate *packed*; int32 lanes cannot (9 packed taps
would overflow the 16-bit guard), so we extract per-tap and accumulate
the two streams separately — multiplies stay halved (the scarce
resource), adds are cheap VPU ops.  Recorded as a hardware adaptation
in DESIGN.md.

Operand ceiling: 8 bits, as in the paper (|b*w| must fit 15 bits).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.resources import (Footprint, cost_cycles, hbm_cycles,
                                  vpu_op_cycles)


def _unpack(m):
    """Recover (a*w, b*w) from m = ((a<<16)+b) * w, exactly."""
    low = ((m + (1 << 15)) & 0xFFFF) - (1 << 15)   # signed low 16 bits
    high = (m - low) >> 16
    return high, low


def _kernel(xa_ref, xb_ref, w_ref, oa_ref, ob_ref, *, kh: int, kw: int):
    ho, wo = oa_ref.shape[1], oa_ref.shape[2]
    a = xa_ref[0].astype(jnp.int32)
    b = xb_ref[0].astype(jnp.int32)
    packed = (a << 16) + b                              # (H, W, Cin)
    acc_a = jnp.zeros(oa_ref.shape[1:], jnp.int32)
    acc_b = jnp.zeros(ob_ref.shape[1:], jnp.int32)
    for i in range(kh):
        for j in range(kw):
            win = packed[i:i + ho, j:j + wo, :]          # (Ho, Wo, Cin)
            tap = w_ref[i, j].astype(jnp.int32)          # (Cin, bc)
            m = win[..., :, None] * tap[None, None, :, :]  # ONE mul / pair
            aw, bw = _unpack(m)
            acc_a = acc_a + jnp.sum(aw, axis=2)
            acc_b = acc_b + jnp.sum(bw, axis=2)
    oa_ref[0] = acc_a
    ob_ref[0] = acc_b


@functools.partial(jax.jit, static_argnames=("block_cout", "interpret"))
def conv2d_ip3(xa: jnp.ndarray, xb: jnp.ndarray, w: jnp.ndarray, *,
               block_cout: int = 128, interpret: bool = True):
    if xa.dtype != jnp.int8 or xb.dtype != jnp.int8 or w.dtype != jnp.int8:
        raise TypeError("Conv3 is limited to 8-bit operands (paper Table I); "
                        f"got {xa.dtype}, {xb.dtype}, {w.dtype}")
    n, h, w_, cin = xa.shape
    kh, kw, _, cout = w.shape
    ho, wo = h - kh + 1, w_ - kw + 1
    bc = min(block_cout, cout)
    grid = (n, pl.cdiv(cout, bc))
    img = pl.BlockSpec((1, h, w_, cin), lambda b, c: (b, 0, 0, 0))
    out = pl.BlockSpec((1, ho, wo, bc), lambda b, c: (b, 0, 0, c))
    return pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw),
        grid=grid,
        in_specs=[img, img,
                  pl.BlockSpec((kh, kw, cin, bc), lambda b, c: (0, 0, 0, c))],
        out_specs=[out, out],
        out_shape=[jax.ShapeDtypeStruct((n, ho, wo, cout), jnp.int32),
                   jax.ShapeDtypeStruct((n, ho, wo, cout), jnp.int32)],
        interpret=interpret,
    )(xa, xb, w)


def footprint(n, h, w, cin, kh, kw, cout, *, itemsize=1,
              block_cout: int = 128) -> Footprint:
    ho, wo = h - kh + 1, w - kw + 1
    bc = min(block_cout, cout)
    vmem = (2 * h * w * cin * itemsize
            + h * w * cin * 4                 # packed plane
            + kh * kw * cin * bc * itemsize
            + 2 * ho * wo * bc * 4)
    hbm = (2 * n * h * w * cin * itemsize
           + kh * kw * cin * cout * itemsize
           + 2 * n * ho * wo * cout * 4)
    taps = n * ho * wo * cout * kh * kw * cin
    # ONE multiply per tap-pair (the win), ~5 cheap ops for unpack+acc.
    vpu = taps * 6
    return Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=0,
                     vpu_ops=vpu,
                     est_cycles=cost_cycles(vpu_op_cycles(vpu), hbm),
                     outputs_per_pass=2, max_operand_bits=8)
