"""Pure-jnp oracle for the selective-scan kernel.

Contract (the SSM core of a Mamba block, per batch element):
  x  : (B, T, Di)   post-conv activations
  dt : (B, T, Di)   softplus'd step sizes
  Bp : (B, T, Ds)   input projection
  Cp : (B, T, Ds)   output projection
  A  : (Di, Ds)     negative state matrix
  y  : (B, T, Di)   y_t = (h_t · Cp_t),  h_t = exp(dt_t A) h_{t-1} + (dt_t x_t) Bp_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def selective_scan_ref(x, dt, bp, cp, a):
    B, T, Di = x.shape

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        dA = jnp.exp(dt_t[..., None] * a[None])
        dBx = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = dA * h + dBx
        return h, jnp.einsum("bis,bs->bi", h, c_t)

    h0 = jnp.zeros((B, Di, a.shape[1]), jnp.float32)
    xs = (x.astype(jnp.float32).transpose(1, 0, 2),
          dt.astype(jnp.float32).transpose(1, 0, 2),
          bp.astype(jnp.float32).transpose(1, 0, 2),
          cp.astype(jnp.float32).transpose(1, 0, 2))
    h, ys = lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), h
