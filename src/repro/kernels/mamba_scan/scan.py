"""Pallas selective-scan kernel — the SSM recurrence with the state
resident in VMEM.

The jnp `lax.scan` twin round-trips the (Di x Ds) state through HBM
every timestep (T x Di x Ds x 4 B each way); here the state lives in a
VMEM scratch for the whole time block and only x/dt/B/C stream in and
y streams out — HBM traffic drops from O(T·Di·Ds) to O(T·(Di + Ds)),
a (Ds= d_state)-fold cut of the recurrence's memory term.  This is the
Conv1-style "logic-only" end of the IP spectrum (no MXU; the per-step
update is rank-1 VPU work), matching DESIGN.md §Arch-applicability for
the attention-free blocks.

Grid: (B, Di/bdi).  Block: full T in VMEM (T·bdi·4 bytes — e.g.
4096x256 = 4 MiB), state scratch (bdi, Ds).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.resources import (Footprint, cost_cycles, hbm_cycles,
                                  vpu_op_cycles)


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, hout_ref, h_ref, *,
            T: int):
    h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, _):
        # NB: all-slice indices only — a bare int here breaks interpret-mode
        # state discharge on jax 0.4.x (`'int' object has no attribute
        # 'shape'` in _load_discharge_rule).
        tsl = (slice(None), pl.dslice(t, 1), slice(None))
        x_t = pl.load(x_ref, tsl)[0, 0]    # (bdi,)
        dt_t = pl.load(dt_ref, tsl)[0, 0]
        b_t = pl.load(b_ref, tsl)[0, 0]    # (Ds,)
        c_t = pl.load(c_ref, tsl)[0, 0]
        dA = jnp.exp(dt_t[:, None] * a_ref[...])                     # (bdi,Ds)
        dBx = (dt_t * x_t)[:, None] * b_t[None, :]
        h_ref[...] = dA * h_ref[...] + dBx
        y_t = jnp.sum(h_ref[...] * c_t[None, :], axis=1)             # (bdi,)
        pl.store(y_ref, tsl, y_t[None, None])
        return 0

    jax.lax.fori_loop(0, T, step, 0)
    hout_ref[...] = h_ref[...][None]


@functools.partial(jax.jit, static_argnames=("block_di", "interpret"))
def selective_scan(x, dt, bp, cp, a, *, block_di: int = 256,
                   interpret: bool = True):
    """x/dt: (B,T,Di); bp/cp: (B,T,Ds); a: (Di,Ds) -> (y (B,T,Di), h)."""
    B, T, Di = x.shape
    Ds = a.shape[1]
    bdi = min(block_di, Di)
    grid = (B, pl.cdiv(Di, bdi))
    f32 = lambda t: t.astype(jnp.float32)
    y, h = pl.pallas_call(
        functools.partial(_kernel, T=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T, bdi), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, T, bdi), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, T, Ds), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((1, T, Ds), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((bdi, Ds), lambda b, d: (d, 0)),
        ],
        out_specs=[pl.BlockSpec((1, T, bdi), lambda b, d: (b, 0, d)),
                   pl.BlockSpec((1, bdi, Ds), lambda b, d: (b, d, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, T, Di), jnp.float32),
                   jax.ShapeDtypeStruct((B, Di, Ds), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bdi, Ds), jnp.float32)],
        interpret=interpret,
    )(f32(x), f32(dt), f32(bp), f32(cp), f32(a))
    return y, h


def footprint(b, t, di, ds, *, block_di: int = 256) -> Footprint:
    bdi = min(block_di, di)
    vmem = (2 * t * bdi + 2 * t * ds + bdi * ds * 2 + t * bdi) * 4
    hbm = (2 * b * t * di + 2 * b * t * ds + di * ds
           + b * t * di + b * di * ds) * 4
    vpu = b * t * di * ds * 6       # dA, dBx, h update, y reduce
    return Footprint(vmem_bytes=int(vmem), hbm_bytes=int(hbm), mxu_passes=0,
                     vpu_ops=int(vpu),
                     est_cycles=cost_cycles(vpu_op_cycles(vpu), hbm),
                     outputs_per_pass=1, max_operand_bits=32)
