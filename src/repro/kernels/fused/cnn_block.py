"""Fused conv->pool->activation CNN-block kernels — one resource-shaped
unit per block, the paper's stated future work ("integrate pooling and
activation with the convolution IPs").

The unfused chain launches three ``pallas_call``s and round-trips the
conv output (the largest tensor of the block) and the pool output
through HBM between them.  Each fused member computes the conv
accumulator tile, applies the pooling reduce and the activation to the
still-resident VMEM tile, and writes ONLY the final (pooled, activated)
tensor back — the intermediate reads+writes disappear from the DMA
column, which the additive cost model (``core.resources.cost_cycles``)
turns into a counted est-cycles drop.

Two members, one per conv IP style, sharing the standalone kernels'
inner-loop bodies verbatim (``kernels/conv2d/inner.py``,
``kernels/pool2d/vpu_window.py::window_reduce``) so fused and unfused
numerics cannot drift:

* ``fused_vpu`` — Conv1-style logic-only accumulation; zero MXU passes.
* ``fused_mxu`` — Conv2-style im2col + one MXU pass per tile.

**int8 rung** (the PR 3 mixed-precision path): ``scale=`` feeds the
combined (activation x per-channel weight) dequantization scale into
the kernel; the int32 conv accumulator is rescaled to float *in
register* and pooling/activation run on the rescaled tile — no
intermediate fixed-point codes are materialized, and the block's single
dequantize happens before its single write.

Tiling: grid over (batch, Cout tiles), like the standalone conv IPs.
Each grid step holds one input plane, one weight tile, the conv
accumulator tile, and the (much smaller) pooled output tile in VMEM —
the fused VMEM need is the price the planner weighs against the saved
traffic (docs/adaptive_ips.md, "Fusion contract").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.resources import (Footprint, cost_cycles, mxu_pass_cycles,
                                  vpu_op_cycles)
from repro.kernels.activation.ref import KINDS, _FNS
from repro.kernels.activation.vpu_exact import OP_COST
from repro.kernels.conv2d.inner import accumulate_mxu, accumulate_vpu
from repro.kernels.pool2d.ref import check_pool_geometry, norm_window_stride
from repro.kernels.pool2d.vpu_window import window_reduce


def _geometry(h, w, kh, kw, ph, pw, sh, sw):
    """(conv Ho, conv Wo, pooled Ho, pooled Wo) of one fused block."""
    co_h, co_w = h - kh + 1, w - kw + 1
    return co_h, co_w, (co_h - ph) // sh + 1, (co_w - pw) // sw + 1


def _kernel(x_ref, w_ref, *rest, style, kh, kw, ph, pw, sh, sw, mode,
            kind, acc_dtype):
    # rest is (scale_ref, o_ref) on the int8 rung, (o_ref,) otherwise.
    scale_ref, o_ref = rest if len(rest) == 2 else (None, rest[0])
    co_h = (o_ref.shape[1] - 1) * sh + ph
    co_w = (o_ref.shape[2] - 1) * sw + pw
    if style == "vpu":
        x = x_ref[0].astype(acc_dtype)
        acc = accumulate_vpu(x, w_ref, ho=co_h, wo=co_w, kh=kh, kw=kw,
                             acc_dtype=acc_dtype)
    else:
        acc = accumulate_mxu(x_ref[0], w_ref, ho=co_h, wo=co_w, kh=kh,
                             kw=kw, acc_dtype=acc_dtype)
    if scale_ref is not None:
        # The int8 rung's in-register dequantize: int32 accumulator ->
        # float via the combined (act x per-channel weight) scale, while
        # the tile is still VMEM-resident — no intermediate codes.
        acc = acc.astype(jnp.float32) * scale_ref[0]
    # Native-integer blocks keep the family oracle's fixed-point avg
    # (int32 accumulate, floor division); everything else pools in f32.
    pool_acc = (acc.dtype if jnp.issubdtype(acc.dtype, jnp.integer)
                else jnp.float32)
    pooled = window_reduce(acc, ho=o_ref.shape[1], wo=o_ref.shape[2],
                           kh=ph, kw=pw, sh=sh, sw=sw, mode=mode,
                           acc_dtype=pool_acc)
    o_ref[0] = _FNS[kind](pooled.astype(jnp.float32))


def _fused_call(style, x, w, scale, pool_window, pool_stride, pool_mode,
                act_kind, block_cout, interpret):
    if act_kind not in KINDS:
        raise ValueError(f"unknown activation {act_kind!r}; have {KINDS}")
    n, h, w_, cin = x.shape
    kh, kw, _, cout = w.shape
    (ph, pw), (sh, sw) = check_pool_geometry(
        (n, h - kh + 1, w_ - kw + 1, cout), pool_window, pool_stride)
    _, _, po, qo = _geometry(h, w_, kh, kw, ph, pw, sh, sw)
    acc_dtype = (jnp.int32 if jnp.issubdtype(x.dtype, jnp.integer)
                 else jnp.float32)
    bc = min(block_cout, cout)
    grid = (n, pl.cdiv(cout, bc))
    in_specs = [
        pl.BlockSpec((1, h, w_, cin), lambda b, c: (b, 0, 0, 0)),
        pl.BlockSpec((kh, kw, cin, bc), lambda b, c: (0, 0, 0, c)),
    ]
    operands = [x, w]
    if scale is not None:
        in_specs.append(pl.BlockSpec((1, 1, 1, bc), lambda b, c: (0, 0, 0, c)))
        operands.append(jnp.asarray(scale, jnp.float32).reshape(1, 1, 1, cout))
    return pl.pallas_call(
        functools.partial(_kernel, style=style, kh=kh, kw=kw, ph=ph, pw=pw,
                          sh=sh, sw=sw, mode=pool_mode, kind=act_kind,
                          acc_dtype=acc_dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, po, qo, bc), lambda b, c: (b, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((n, po, qo, cout), jnp.float32),
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit, static_argnames=(
    "pool_window", "pool_stride", "pool_mode", "act_kind", "block_cout",
    "interpret"))
def fused_cnn_vpu(x: jnp.ndarray, w: jnp.ndarray, scale=None, *,
                  pool_window=(2, 2), pool_stride=None,
                  pool_mode: str = "max", act_kind: str = "relu",
                  block_cout: int = 128,
                  interpret: bool = True) -> jnp.ndarray:
    """Logic-only fused block: Conv1-style MAC, pool + act in register.

    ``scale`` (f32, broadcastable to (1, 1, 1, Cout)) switches on the
    int8 rung: integer operands, int32 accumulate, in-register rescale.
    """
    return _fused_call("vpu", x, w, scale, pool_window, pool_stride,
                       pool_mode, act_kind, block_cout, interpret)


@functools.partial(jax.jit, static_argnames=(
    "pool_window", "pool_stride", "pool_mode", "act_kind", "block_cout",
    "interpret"))
def fused_cnn_mxu(x: jnp.ndarray, w: jnp.ndarray, scale=None, *,
                  pool_window=(2, 2), pool_stride=None,
                  pool_mode: str = "max", act_kind: str = "relu",
                  block_cout: int = 128,
                  interpret: bool = True) -> jnp.ndarray:
    """MXU fused block: im2col + one MXU pass, pool + act in register."""
    return _fused_call("mxu", x, w, scale, pool_window, pool_stride,
                       pool_mode, act_kind, block_cout, interpret)


# ---------------------------------------------------------------------------
# Footprints — the combined block priced as ONE launch: the conv working
# set plus the pooled tile in VMEM, but ONLY input + weights + final
# output in the DMA column.
# ---------------------------------------------------------------------------
def _pool_act_vpu_ops(n, cout, po, qo, ph, pw, kind):
    pool = 2 * n * po * qo * cout * ph * pw     # gather + compare/add per tap
    act = n * po * qo * cout * OP_COST.get(kind, 8)
    return pool + act


def footprint_vpu(n, h, w, cin, kh, kw, cout, ph, pw, sh, sw, *,
                  itemsize=1, mode="max", kind="relu",
                  block_cout: int = 128) -> Footprint:
    co_h, co_w, po, qo = _geometry(h, w, kh, kw, ph, pw, sh, sw)
    bc = min(block_cout, cout)
    vmem = (h * w * cin * itemsize            # x plane
            + kh * kw * cin * bc * itemsize   # weight tile
            + co_h * co_w * bc * 4            # resident conv accumulator
            + po * qo * bc * 4)               # pooled/activated tile
    hbm = (n * h * w * cin * itemsize
           + kh * kw * cin * cout * itemsize
           + n * po * qo * cout * 4)          # ONLY the final tensor
    vpu = (n * co_h * co_w * cout * kh * kw * cin * 2
           + _pool_act_vpu_ops(n, cout, po, qo, ph, pw, kind))
    if itemsize == 1:
        vpu += n * co_h * co_w * cout         # in-register rescale
    return Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=0,
                     vpu_ops=vpu,
                     est_cycles=cost_cycles(vpu_op_cycles(vpu), hbm),
                     outputs_per_pass=1, max_operand_bits=32, launches=1)


def footprint_mxu(n, h, w, cin, kh, kw, cout, ph, pw, sh, sw, *,
                  itemsize=1, mode="max", kind="relu",
                  block_cout: int = 128) -> Footprint:
    co_h, co_w, po, qo = _geometry(h, w, kh, kw, ph, pw, sh, sw)
    bc = min(block_cout, cout)
    k = kh * kw * cin
    vmem = (h * w * cin * itemsize
            + co_h * co_w * k * itemsize      # im2col patches
            + k * bc * itemsize
            + co_h * co_w * bc * 4
            + po * qo * bc * 4)
    hbm = (n * h * w * cin * itemsize
           + kh * kw * cin * cout * itemsize
           + n * po * qo * cout * 4)
    passes = n * ((cout + bc - 1) // bc)
    cyc = n * mxu_pass_cycles(co_h * co_w, k, cout)
    vpu = (n * co_h * co_w * k                # im2col data movement
           + _pool_act_vpu_ops(n, cout, po, qo, ph, pw, kind))
    if itemsize == 1:
        vpu += n * co_h * co_w * cout
    return Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=passes,
                     vpu_ops=vpu,
                     est_cycles=cost_cycles(max(cyc, vpu_op_cycles(vpu)), hbm),
                     outputs_per_pass=1, max_operand_bits=32, launches=1)
