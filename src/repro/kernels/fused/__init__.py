"""Fused CNN-block IP family: conv -> pool -> activation in ONE launch."""
