"""Public jit'd wrapper for the fused CNN-block IP family.

`fused_cnn_block` takes an explicit ``ip=`` name or a ``budget=``
(ResourceBudget) and defers to the resource-driven selector, mirroring
`kernels/conv2d/ops.py`.  ``ladder=`` lets the planner lower the whole
fused block's operand width; a lowered plan executes through
``repro.quant.ops.quantized_fused_cnn_block`` (int8: integer kernel with
the in-register rescale) and still returns float.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.resources import ResourceBudget
from repro.kernels.fused.cnn_block import fused_cnn_mxu, fused_cnn_vpu

_MEMBERS = {"fused_vpu": fused_cnn_vpu, "fused_mxu": fused_cnn_mxu}


def resolve_member(ip: str):
    """Qualified-or-short member name -> kernel, with the family-standard
    error (shared by the float wrapper below and the quantized path)."""
    short = ip.split(".")[-1]
    if short not in _MEMBERS:
        raise KeyError(f"{short!r} is not a fused CNN-block IP "
                       f"(have {sorted(_MEMBERS)})")
    return _MEMBERS[short]


def fused_cnn_block(x: jnp.ndarray, w: jnp.ndarray, *,
                    pool_window=(2, 2), pool_stride=None,
                    pool_mode: str = "max", activation: str = "relu",
                    ip: Optional[str] = None,
                    budget: Optional[ResourceBudget] = None, ladder=(),
                    interpret: bool = True, **tile_kwargs) -> jnp.ndarray:
    """conv -> pool -> activation as ONE launch through a selected member.

    ``tile_kwargs`` forward tiling parameters (``block_cout=``, typically
    from ``core.autotune.plan_tile_overrides``).
    """
    if ip is None:
        from repro.core.ip import SiteSpec
        from repro.core.plan import plan_single
        spec = SiteSpec.make("cnn_fused", "cnn_fused", (x.shape, w.shape),
                             x.dtype, ladder=ladder, window=pool_window,
                             stride=pool_stride, mode=pool_mode,
                             kind=activation)
        planned = plan_single(spec, budget)
        if planned.lowered:
            from repro.quant.ops import quantized_fused_cnn_block
            return quantized_fused_cnn_block(
                x, w, pool_window=pool_window, pool_stride=pool_stride,
                pool_mode=pool_mode, activation=activation,
                bits=planned.precision_bits, ip=planned.ip.name,
                interpret=interpret)
        ip = planned.ip.name
    return resolve_member(ip)(x, w, pool_window=tuple(pool_window),
                              pool_stride=pool_stride, pool_mode=pool_mode,
                              act_kind=activation, interpret=interpret,
                              **tile_kwargs)
