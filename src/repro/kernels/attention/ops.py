"""Public wrappers for the attention IP family (selector-aware).

Attention carries no ``ladder=``: the family is registered
``quantizable=False`` (no integer kernels), so the planner always holds
its sites at native width.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.resources import ResourceBudget
from repro.kernels.attention.flash import flash_attention
from repro.kernels.attention.decode import flash_decode
from repro.kernels.attention.ref import attention_ref


def attention(q, k, v, *, causal: bool = True, ip: Optional[str] = None,
              budget: Optional[ResourceBudget] = None,
              interpret: bool = True):
    if ip is None:
        from repro.core.ip import SiteSpec
        from repro.core.plan import plan_single
        spec = SiteSpec.make("attention", "attention", (q.shape, k.shape),
                             q.dtype)
        ip = plan_single(spec, budget).ip.name
    ip = ip.split(".")[-1]
    if ip == "attn_flash":
        return flash_attention(q, k, v, causal=causal, interpret=interpret)
    if ip == "attn_decode":
        return flash_decode(q, k, v, interpret=interpret)
    if ip == "attn_naive":
        return attention_ref(q, k, v, causal=causal)
    raise KeyError(f"unknown attention IP {ip!r}")
