"""Flash attention (tiled online-softmax) — the attention IP's MXU-heavy
member for training/prefill.

Adaptation notes (FPGA -> TPU): the paper's BlockSpec-era insight —
"size the working set to on-chip memory, stream the rest" — is exactly
flash attention's game: q/k/v tiles sized to VMEM, softmax statistics
(running max m, normalizer l) live in VMEM scratch across the kv-block
grid dimension, HBM traffic stays O(S*D) instead of O(S^2).

Grid: (B*Hq, Sq/bq, Skv/bk), kv innermost.  GQA is handled in the
index_map (q head -> kv head).  Causal blocks above the diagonal are
skipped with pl.when (no MXU work scheduled).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.resources import (Footprint, cost_cycles, hbm_cycles,
                                  mxu_pass_cycles)

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_kv: int, bq: int, bk: int, causal: bool, offs: int,
                  scale: float, skv: int):
    kv = pl.program_id(2)

    @pl.when(kv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qi = pl.program_id(1)

    def _body():
        q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        k_pos = kv * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < skv                                  # kv padding
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            mask = mask & (k_pos <= q_pos + offs)
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    if causal:
        # Skip fully-masked blocks: first kv index of block > last visible.
        @pl.when(kv * bk <= qi * bq + (bq - 1) + offs)
        def _run():
            _body()
    else:
        _body()

    @pl.when(kv == n_kv - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512,
                    bk: int = 512, interpret: bool = True):
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = d ** -0.5
    bq = min(bq, sq)
    bk = min(bk, skv)
    offs = skv - sq
    pq = (-sq) % bq
    pk = (-skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    sqp, skvp = sq + pq, skv + pk
    qr = q.reshape(b * hq, sqp, d)
    kr = k.reshape(b * hkv, skvp, d)
    vr = v.reshape(b * hkv, skvp, d)
    n_kv = pl.cdiv(skvp, bk)
    grid = (b * hq, pl.cdiv(sqp, bq), n_kv)

    def kv_map(h, i, kv):
        return (h // group, kv, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, n_kv=n_kv, bq=bq, bk=bk,
                          causal=causal, offs=offs, scale=scale, skv=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, kv: (h, i, 0)),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, kv: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sqp, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sqp, d)[:, :, :sq, :]


def footprint(b, hq, hkv, sq, skv, d, *, itemsize=2, bq=512, bk=512,
              causal=True) -> Footprint:
    bq_, bk_ = min(bq, sq), min(bk, skv)
    vmem = (bq_ * d + 2 * bk_ * d) * itemsize + (bq_ * d + 2 * bq_) * 4
    hbm = (b * hq * sq * d * 2 + 2 * b * hkv * skv * d) * itemsize
    frac = 0.5 if causal and sq == skv else 1.0
    flops = 4.0 * b * hq * sq * skv * d * frac
    cyc = flops / 2 / (128 * 128)  # MXU MACs/cycle
    passes = int(b * hq * pl.cdiv(sq, bq_) * pl.cdiv(skv, bk_) * frac) + 1
    return Footprint(vmem_bytes=int(vmem), hbm_bytes=int(hbm),
                     mxu_passes=passes, vpu_ops=int(b * hq * sq * skv * frac * 4),
                     est_cycles=cost_cycles(cyc, hbm),
                     outputs_per_pass=1, max_operand_bits=32)
