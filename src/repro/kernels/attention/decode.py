"""Flash-decode — the attention IP's serving member: one new token
against a long KV cache.

The q "tile" is the whole GQA group of a kv head (group x d), which
puts the group in the sublane dimension — the TPU-native layout for
single-token decode (a (1, d) q tile would waste 7/8 sublanes).
Grid: (B * Hkv, Skv / bk); online max/sum merge across kv blocks in
VMEM scratch — the same partial-softmax merge the SP (sequence-
parallel) path uses across chips with psum (distributed/collectives).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.resources import Footprint, hbm_cycles

_NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                   n_kv: int, scale: float, bk: int, skv: int):
    kv = pl.program_id(1)

    @pl.when(kv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale        # (group, d)
    k = k_ref[0].astype(jnp.float32)                # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (group, bk)
    group = s.shape[0]
    k_pos = kv * bk + jax.lax.broadcasted_iota(jnp.int32, (group, bk), 1)
    s = jnp.where(k_pos < skv, s, _NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(kv == n_kv - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def flash_decode(q, k, v, *, bk: int = 1024, interpret: bool = True):
    """q: (B, Hq, 1, D); k/v: (B, Hkv, Skv, D) -> (B, Hq, 1, D)."""
    b, hq, sq, d = q.shape
    assert sq == 1, "flash_decode is the single-token member"
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = d ** -0.5
    bk = min(bk, skv)
    pk = (-skv) % bk
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    skvp = skv + pk
    qr = q.reshape(b, hkv, group, d).reshape(b * hkv, group, d)
    kr = k.reshape(b * hkv, skvp, d)
    vr = v.reshape(b * hkv, skvp, d)
    n_kv = pl.cdiv(skvp, bk)
    grid = (b * hkv, n_kv)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, n_kv=n_kv, scale=scale, bk=bk,
                          skv=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, group, d), lambda h, kv: (h, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda h, kv: (h, kv, 0)),
            pl.BlockSpec((1, bk, d), lambda h, kv: (h, kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, d), lambda h, kv: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, group, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((group,), jnp.float32),
                        pltpu.VMEM((group,), jnp.float32),
                        pltpu.VMEM((group, d), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, 1, d)


def footprint(b, hq, hkv, skv, d, *, itemsize=2, bk=1024) -> Footprint:
    group = hq // hkv
    bk_ = min(bk, skv)
    vmem = (group * d + 2 * bk_ * d) * itemsize + (group * d + 2 * group) * 4
    hbm = 2 * b * hkv * skv * d * itemsize + 2 * b * hq * d * itemsize
    # decode is HBM-bound by construction: est = cache sweep time.
    return Footprint(vmem_bytes=int(vmem), hbm_bytes=int(hbm),
                     mxu_passes=b * hkv * pl.cdiv(skv, bk_),
                     vpu_ops=int(4 * b * hq * skv),
                     est_cycles=hbm_cycles(hbm),
                     outputs_per_pass=1, max_operand_bits=32)
