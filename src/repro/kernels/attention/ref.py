"""Pure-jnp oracle for the attention IP family.

Contract (GQA-general):
  q : (B, Hq, Sq, D)
  k : (B, Hkv, Skv, D)     Hq % Hkv == 0; group = Hq // Hkv
  v : (B, Hkv, Skv, D)
  out: (B, Hq, Sq, D)
`causal=True` masks j > i + (Skv - Sq)  (decode-aligned causal).
"""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  scale: float | None = None) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(b, hkv, group, sq, d)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf)
    if causal:
        offs = skv - sq
        qi = jnp.arange(sq)[:, None]
        kj = jnp.arange(skv)[None, :]
        mask = kj <= qi + offs
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def decode_attention_ref(q, k, v, *, scale: float | None = None):
    """Single-token decode: q (B, Hq, 1, D) against a full KV cache."""
    return attention_ref(q, k, v, causal=False, scale=scale)
