"""Pure-jnp oracle for the activation IP family.

Contract shared by all activation IPs:
  x : any shape, float (bf16/f32) or integer fixed-point
  y : same shape; computed in float32

Float inputs are returned in their own dtype; integer inputs are
promoted to float32 (an activation output is no longer fixed-point —
requantization is a separate, explicit step, see models/blocks.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

KINDS = ("relu", "relu6", "sigmoid", "tanh", "gelu")

_FNS = {
    "relu": lambda x: jnp.maximum(x, 0.0),
    "relu6": lambda x: jnp.clip(x, 0.0, 6.0),
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
    "gelu": jax.nn.gelu,
}


def activation_ref(x: jnp.ndarray, *, kind: str = "relu") -> jnp.ndarray:
    if kind not in _FNS:
        raise ValueError(f"unknown activation {kind!r}; have {KINDS}")
    y = _FNS[kind](x.astype(jnp.float32))
    out_dtype = x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32
    return y.astype(out_dtype)
