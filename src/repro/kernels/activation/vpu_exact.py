"""Act1 — exact elementwise activation on the VPU (full-precision IP).

Every transcendental is evaluated exactly (to float32 ULP) by the
vector unit: zero MXU passes, but a per-element op count that grows
with the activation's complexity (tanh/gelu cost an order of magnitude
more VPU ops than relu).  This is the member the selector picks when
the deployment demands full precision (budget.precision_bits > 8).

Tiling: the input is viewed as (rows, lanes) and the grid walks row
blocks; each grid step holds one (block_rows, K) tile in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.resources import (Footprint, cost_cycles, hbm_cycles,
                                  vpu_op_cycles)
from repro.kernels.activation.ref import _FNS, KINDS

# Approximate VPU scalar-op cost per element (mul/add/cmp units).
OP_COST = {"relu": 1, "relu6": 2, "sigmoid": 10, "tanh": 12, "gelu": 15}


def _kernel(x_ref, o_ref, *, kind, out_dtype):
    y = _FNS[kind](x_ref[...].astype(jnp.float32))
    o_ref[...] = y.astype(out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("kind", "block_rows", "interpret"))
def activation_exact(x: jnp.ndarray, *, kind: str = "relu",
                     block_rows: int = 256,
                     interpret: bool = True) -> jnp.ndarray:
    if kind not in KINDS:
        raise ValueError(f"unknown activation {kind!r}; have {KINDS}")
    out_dtype = (x.dtype if jnp.issubdtype(x.dtype, jnp.floating)
                 else jnp.float32)
    shape = x.shape
    k = shape[-1] if x.ndim >= 1 and shape else 1
    x2 = x.reshape(-1, k) if x.ndim != 2 else x
    m = x2.shape[0]
    bm = min(block_rows, m)
    y2 = pl.pallas_call(
        functools.partial(_kernel, kind=kind, out_dtype=out_dtype),
        grid=(pl.cdiv(m, bm),),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), out_dtype),
        interpret=interpret,
    )(x2)
    return y2.reshape(shape)


def footprint(n_elems, *, itemsize=4, kind="relu",
              block_rows: int = 256, lanes: int = 128) -> Footprint:
    block = min(block_rows * lanes, n_elems)
    vmem = block * itemsize + block * 4            # in tile + f32 out tile
    hbm = n_elems * (itemsize + itemsize)          # stream in + out
    vpu = n_elems * OP_COST.get(kind, 8)
    return Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=0,
                     vpu_ops=vpu,
                     est_cycles=cost_cycles(vpu_op_cycles(vpu), hbm),
                     outputs_per_pass=1, max_operand_bits=32)
