"""Public jit'd wrappers for the activation IP family.

`activation` takes an explicit ``ip=`` name or a ``budget=``
(ResourceBudget) and defers to the resource-driven selector, mirroring
`kernels/conv2d/ops.py`.  ``ladder=`` allows the planner to lower the
call's operand width; lowered plans evaluate the nonlinearity on the
intN-quantized input grid (``repro.quant.ops.quantized_activation``).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.resources import ResourceBudget
from repro.kernels.activation.lut_poly import activation_lut
from repro.kernels.activation.vpu_exact import activation_exact

_MEMBERS = {"act_vpu": activation_exact, "act_lut": activation_lut}


def activation(x: jnp.ndarray, *, kind: str = "relu",
               ip: Optional[str] = None,
               budget: Optional[ResourceBudget] = None, ladder=(),
               interpret: bool = True) -> jnp.ndarray:
    """Elementwise activation through a selected IP (Act1/Act2)."""
    if ip is None:
        from repro.core.ip import SiteSpec
        from repro.core.plan import plan_single
        spec = SiteSpec.make("activation", "activation", (x.shape,),
                             x.dtype, ladder=ladder, kind=kind)
        planned = plan_single(spec, budget)
        if planned.lowered:
            from repro.quant.ops import quantized_activation
            return quantized_activation(x, kind=kind,
                                        bits=planned.precision_bits,
                                        ip=planned.ip.name,
                                        interpret=interpret)
        ip = planned.ip.name
    ip = ip.split(".")[-1]
    if ip not in _MEMBERS:
        raise KeyError(
            f"{ip!r} is not an activation IP (have {sorted(_MEMBERS)})")
    return _MEMBERS[ip](x, kind=kind, interpret=interpret)
