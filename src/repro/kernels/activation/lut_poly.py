"""Act2 — low-precision LUT activation (the paper's fixed-point IP, here).

In the spirit of the paper's 8-bit fixed-point VHDL IPs: the input is
quantized to a 256-level grid over the activation's saturation range and
the nonlinearity becomes a single table lookup — ~4 cheap VPU ops per
element instead of a transcendental, and (in deployment) 1-byte operand
streaming instead of 2-4-byte floats.  Only saturating activations are
supported (relu6/sigmoid/tanh): outside the tabulated range they are
constant, so clipping the index is exact there; unbounded kinds
(relu/gelu) would be wrong beyond the range and are left to the exact
member — capability filtering the selector enforces.

Accuracy: worst-case error is half a quantization step times the
activation's Lipschitz constant plus the saturation tail — ≤ ~0.04 for
the supported kinds (asserted against the oracle in tests).

The table itself is built on the host from the family's ``ref.py``
oracle, so the approximation can never drift from the reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.resources import (Footprint, cost_cycles, hbm_cycles,
                                  vpu_op_cycles)
from repro.kernels.activation.ref import activation_ref

TABLE_SIZE = 256

# Saturation range per supported kind: |x| > range -> the activation is
# (numerically) constant, so index clipping is exact there.
RANGES = {"relu6": 8.0, "sigmoid": 8.0, "tanh": 4.0}
SUPPORTED_KINDS = tuple(sorted(RANGES))


def build_table(kind: str) -> jnp.ndarray:
    """256-entry float32 table sampled from the ref.py oracle."""
    r = RANGES[kind]
    xs = jnp.linspace(-r, r, TABLE_SIZE, dtype=jnp.float32)
    return activation_ref(xs, kind=kind)


def _kernel(x_ref, t_ref, o_ref, *, r, out_dtype):
    x = x_ref[...].astype(jnp.float32)
    scale = (TABLE_SIZE - 1) / (2.0 * r)
    q = jnp.clip(jnp.round((x + r) * scale), 0, TABLE_SIZE - 1)
    o_ref[...] = jnp.take(t_ref[...], q.astype(jnp.int32)).astype(out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("kind", "block_rows", "interpret"))
def activation_lut(x: jnp.ndarray, *, kind: str = "tanh",
                   block_rows: int = 256,
                   interpret: bool = True) -> jnp.ndarray:
    if kind not in RANGES:
        raise ValueError(
            f"LUT activation supports saturating kinds {SUPPORTED_KINDS}; "
            f"{kind!r} is unbounded — use the exact IP")
    out_dtype = (x.dtype if jnp.issubdtype(x.dtype, jnp.floating)
                 else jnp.float32)
    table = build_table(kind)
    shape = x.shape
    k = shape[-1] if x.ndim >= 1 and shape else 1
    x2 = x.reshape(-1, k) if x.ndim != 2 else x
    m = x2.shape[0]
    bm = min(block_rows, m)
    y2 = pl.pallas_call(
        functools.partial(_kernel, r=RANGES[kind], out_dtype=out_dtype),
        grid=(pl.cdiv(m, bm),),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0)),
                  pl.BlockSpec((TABLE_SIZE,), lambda i: (0,))],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), out_dtype),
        interpret=interpret,
    )(x2, table)
    return y2.reshape(shape)


def footprint(n_elems, *, itemsize=4, kind="tanh",
              block_rows: int = 256, lanes: int = 128) -> Footprint:
    block = min(block_rows * lanes, n_elems)
    vmem = block * itemsize + block * 4 + TABLE_SIZE * 4
    # Deployment story: operands stream as 1-byte fixed-point codes
    # (quantize at the producer, dequantize at the consumer) plus the table.
    hbm = n_elems * 2 + TABLE_SIZE * 4
    vpu = n_elems * 4            # scale, clip, round, gather
    return Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=0,
                     vpu_ops=vpu,
                     est_cycles=cost_cycles(vpu_op_cycles(vpu), hbm),
                     outputs_per_pass=1, max_operand_bits=8)
