"""Tiled MXU matmul kernels — the matmul end of the IP library.

`mm_mxu` is the Conv2 analogue for the LM hot path: one MXU pass per
(bm, bn, bk) tile with a float32/int32 VMEM accumulator, K innermost so
the accumulator tile stays resident.  Works for bf16/f32 (f32 accum)
and int8 (int32 accum — the paper's fixed-point contract, and 2x MXU
throughput on TPU).

`mm_vpu` is the Conv1 analogue: no dot op at all — broadcast
multiply + reduce on the VPU.  Only sane for small/irregular shapes or
an MXU-saturated budget; exists to complete the resource spectrum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.resources import (Footprint, cost_cycles, hbm_cycles,
                                  mxu_pass_cycles, vpu_op_cycles)


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int, acc_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=acc_dtype)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad2(x, b0, b1):
    """Zero-pad a 2D array up to block multiples (exact for matmul)."""
    p0 = (-x.shape[0]) % b0
    p1 = (-x.shape[1]) % b1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"))
def mm_mxu(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 256, bn: int = 256,
           bk: int = 512, out_dtype=None, interpret: bool = True) -> jnp.ndarray:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    integer = (jnp.issubdtype(a.dtype, jnp.integer)
               and jnp.issubdtype(b.dtype, jnp.integer))
    acc_dtype = jnp.int32 if integer else jnp.float32
    out_dtype = out_dtype or acc_dtype
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    a = _pad2(a, bm, bk)
    b = _pad2(b, bk, bn)
    (mp, kp), np_ = a.shape, b.shape[1]
    n_k = pl.cdiv(kp, bk)
    grid = (pl.cdiv(mp, bm), pl.cdiv(np_, bn), n_k)
    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )(a, b)[:m, :n]


def _mm_vpu_kernel(a_ref, b_ref, o_ref, *, acc_dtype):
    a = a_ref[...].astype(acc_dtype)            # (bm, K)
    b = b_ref[...].astype(acc_dtype)            # (K, bn)
    # Broadcast multiply + sum: no dot — Conv1's "logic only" contract.
    o_ref[...] = jnp.sum(a[:, :, None] * b[None, :, :], axis=1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def mm_vpu(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 64, bn: int = 128,
           interpret: bool = True) -> jnp.ndarray:
    m, k = a.shape
    _, n = b.shape
    integer = (jnp.issubdtype(a.dtype, jnp.integer)
               and jnp.issubdtype(b.dtype, jnp.integer))
    acc_dtype = jnp.int32 if integer else jnp.float32
    bm, bn = min(bm, m), min(bn, n)
    a = _pad2(a, bm, 1)
    b = _pad2(b, 1, bn)
    mp, np_ = a.shape[0], b.shape[1]
    grid = (pl.cdiv(mp, bm), pl.cdiv(np_, bn))
    return pl.pallas_call(
        functools.partial(_mm_vpu_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                  pl.BlockSpec((k, bn), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), acc_dtype),
        interpret=interpret,
    )(a, b)[:m, :n]


def footprint_mxu(m, k, n, *, itemsize=2, bm=256, bn=256, bk=512) -> Footprint:
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    vmem = bm * bk * itemsize + bk * bn * itemsize + 2 * bm * bn * 4
    hbm = (m * k + k * n) * itemsize + m * n * 4
    cyc = mxu_pass_cycles(m, k, n) * (1 if itemsize > 1 else 0.5)
    passes = pl.cdiv(m, bm) * pl.cdiv(n, bn) * pl.cdiv(k, bk)
    return Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=passes,
                     vpu_ops=0, est_cycles=cost_cycles(cyc, hbm),
                     outputs_per_pass=1, max_operand_bits=32)


def footprint_vpu(m, k, n, *, itemsize=2, bm=64, bn=128) -> Footprint:
    bm, bn = min(bm, m), min(bn, n)
    vmem = bm * k * itemsize + k * bn * itemsize + bm * bn * 4
    hbm = (m * k + k * n) * itemsize + m * n * 4
    vpu = 2 * m * k * n
    return Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=0,
                     vpu_ops=vpu,
                     est_cycles=cost_cycles(vpu_op_cycles(vpu), hbm),
                     outputs_per_pass=1, max_operand_bits=32)
