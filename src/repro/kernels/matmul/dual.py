"""Dual-stream matmul kernels — Conv3/Conv4 generalized to the LM hot path.

`mm_dual_shared` (Conv3 analogue): two int8 activation streams share one
weight-tile fetch and one kernel pass — the weights cross HBM->VMEM
*once* for two outputs (the paper's serial-coefficient-load economy) and
the int8 MXU path runs at 2x bf16 throughput ("two convolutions per
DSP").  Operands limited to 8 bits, as in the paper.

`mm_dual_full` (Conv4 analogue): same shared-weight structure at full
precision (bf16/f32) — two MXU pass groups, wider operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.resources import (Footprint, cost_cycles, hbm_cycles,
                                  mxu_pass_cycles)


def _dual_kernel(a1_ref, a2_ref, b_ref, o1_ref, o2_ref, acc1, acc2, *,
                 n_k: int, acc_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc1[...] = jnp.zeros_like(acc1)
        acc2[...] = jnp.zeros_like(acc2)

    b = b_ref[...]                    # ONE weight-tile load ...
    acc1[...] += jnp.dot(a1_ref[...], b, preferred_element_type=acc_dtype)
    acc2[...] += jnp.dot(a2_ref[...], b, preferred_element_type=acc_dtype)

    @pl.when(k == n_k - 1)
    def _done():
        o1_ref[...] = acc1[...].astype(o1_ref.dtype)
        o2_ref[...] = acc2[...].astype(o2_ref.dtype)


def _mm_dual(a1, a2, b, *, bm, bn, bk, interpret, require_int8):
    m, k = a1.shape
    assert a1.shape == a2.shape
    _, n = b.shape
    if require_int8:
        for t in (a1, a2, b):
            if t.dtype != jnp.int8:
                raise TypeError("mm_dual_shared is limited to 8-bit operands "
                                f"(paper Conv3 contract); got {t.dtype}")
    integer = jnp.issubdtype(a1.dtype, jnp.integer)
    acc_dtype = jnp.int32 if integer else jnp.float32
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    from repro.kernels.matmul.mxu import _pad2
    a1 = _pad2(a1, bm, bk)
    a2 = _pad2(a2, bm, bk)
    b = _pad2(b, bk, bn)
    (mp, kp), np_ = a1.shape, b.shape[1]
    n_k = pl.cdiv(kp, bk)
    grid = (pl.cdiv(mp, bm), pl.cdiv(np_, bn), n_k)
    a_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    out = pl.pallas_call(
        functools.partial(_dual_kernel, n_k=n_k, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[a_spec, a_spec,
                  pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))],
        out_specs=[o_spec, o_spec],
        out_shape=[jax.ShapeDtypeStruct((mp, np_), acc_dtype)] * 2,
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)] * 2,
        interpret=interpret,
    )(a1, a2, b)
    return tuple(o[:m, :n] for o in out)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def mm_dual_shared(a1, a2, b, *, bm: int = 256, bn: int = 256, bk: int = 512,
                   interpret: bool = True):
    return _mm_dual(a1, a2, b, bm=bm, bn=bn, bk=bk, interpret=interpret,
                    require_int8=True)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def mm_dual_full(a1, a2, b, *, bm: int = 256, bn: int = 256, bk: int = 512,
                 interpret: bool = True):
    return _mm_dual(a1, a2, b, bm=bm, bn=bn, bk=bk, interpret=interpret,
                    require_int8=False)


def footprint_dual(m, k, n, *, itemsize=1, bm=256, bn=256, bk=512,
                   int8: bool = True) -> Footprint:
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    vmem = 2 * bm * bk * itemsize + bk * bn * itemsize + 4 * bm * bn * 4
    hbm = 2 * m * k * itemsize + k * n * itemsize + 2 * m * n * 4
    # int8 MXU runs 2x: two streams cost one bf16-equivalent pass set.
    scale = 1.0 if int8 else 2.0
    cyc = scale * mxu_pass_cycles(m, k, n)
    passes = int(scale * pl.cdiv(m, bm) * pl.cdiv(n, bn) * pl.cdiv(k, bk))
    return Footprint(vmem_bytes=vmem, hbm_bytes=hbm, mxu_passes=max(passes, 1),
                     vpu_ops=0, est_cycles=cost_cycles(cyc, hbm),
                     outputs_per_pass=2,
                     max_operand_bits=8 if int8 else 32)
