"""Public wrappers for the matmul IP family (selector-aware).

``ladder=`` on `matmul` lets the planner lower the call's operand width
(w8a8 through the int8 MXU path) when the native width does not fit;
lowered plans execute via ``repro.quant.ops.quantized_matmul``.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.resources import ResourceBudget
from repro.kernels.matmul.mxu import mm_mxu, mm_vpu
from repro.kernels.matmul.dual import mm_dual_full, mm_dual_shared

_SINGLE = {"mm_mxu": mm_mxu, "mm_vpu": mm_vpu}
_DUAL = {"mm_dual_shared": mm_dual_shared, "mm_dual_full": mm_dual_full}


def matmul(a: jnp.ndarray, b: jnp.ndarray, *, ip: Optional[str] = None,
           budget: Optional[ResourceBudget] = None, ladder=(),
           interpret: bool = True, **tile_kwargs) -> jnp.ndarray:
    if ip is None:
        from repro.core.ip import SiteSpec
        from repro.core.plan import plan_single
        spec = SiteSpec.make("matmul", "matmul", (a.shape, b.shape),
                             a.dtype, ladder=ladder, dual=False)
        planned = plan_single(spec, budget)
        if planned.lowered:
            from repro.quant.ops import quantized_matmul
            return quantized_matmul(a, b, bits=planned.precision_bits,
                                    ip=planned.ip.name, interpret=interpret,
                                    **tile_kwargs)
        ip = planned.ip.name
    ip = ip.split(".")[-1]
    return _SINGLE[ip](a, b, interpret=interpret, **tile_kwargs)


def matmul_dual(a1: jnp.ndarray, a2: jnp.ndarray, b: jnp.ndarray, *,
                ip: Optional[str] = None,
                budget: Optional[ResourceBudget] = None,
                interpret: bool = True, **tile_kwargs):
    if ip is None:
        from repro.core.ip import SiteSpec
        from repro.core.plan import plan_single
        spec = SiteSpec.make("matmul", "matmul", (a1.shape, b.shape),
                             a1.dtype, dual=True)
        ip = plan_single(spec, budget).ip.name
    ip = ip.split(".")[-1]
    return _DUAL[ip](a1, a2, b, interpret=interpret, **tile_kwargs)
