"""Pure-jnp oracle for the matmul IP family.

Contract:
  a : (M, K)   activations
  b : (K, N)   weights
  y : (M, N)   int32 accumulation for integer inputs, f32 otherwise

Dual-stream contract (the conv3/conv4 generalization):
  a1, a2 : (M, K) two activation streams sharing the weight b.
"""
from __future__ import annotations

import jax.numpy as jnp


def _acc(a, b):
    if jnp.issubdtype(a.dtype, jnp.integer) and jnp.issubdtype(b.dtype, jnp.integer):
        return jnp.int32
    return jnp.float32


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(a, b, preferred_element_type=_acc(a, b))


def matmul_dual_ref(a1: jnp.ndarray, a2: jnp.ndarray, b: jnp.ndarray):
    return matmul_ref(a1, b), matmul_ref(a2, b)
