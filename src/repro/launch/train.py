"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires together every substrate layer: sharded state (pjit), the
deterministic resumable data pipeline, async checkpointing with atomic
commit, watchdog + straggler monitoring, restore-on-start (elastic:
restores onto whatever mesh the surviving devices support), and
optional cross-pod gradient compression.  ``--simulate-failure N``
raises at step N to exercise the restart path end-to-end (used by the
tests; the *serving* restart path is demoed by
examples/elastic_restart.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import make_pipeline
from repro.distributed.sharding import (ShardingPolicy, batch_pspecs,
                                        state_pspecs, to_shardings)
from repro.launch.mesh import make_host_mesh, mesh_axis_sizes
from repro.models import api
from repro.models.frontends import input_specs
from repro.checkpoint import store
from repro.optim.adamw import AdamWConfig
from repro.runtime.fault_tolerance import StragglerMonitor, Watchdog


class SimulatedFailure(RuntimeError):
    pass


def build(cfg, opt_cfg, mesh, policy):
    state_abs = api.init_train_state_abstract(cfg, opt_cfg)
    sspec = state_pspecs(cfg, mesh, state_abs, policy)
    sshard = to_shardings(mesh, sspec)

    @jax.jit
    def init_fn(key):
        return api.init_train_state(cfg, opt_cfg, key)

    def make_state(key):
        with mesh:
            return jax.jit(init_fn, out_shardings=sshard)(key)

    step_fn = jax.jit(lambda s, b: api.train_step(cfg, opt_cfg, s, b),
                      donate_argnums=(0,))
    return make_state, step_fn, sshard


def train(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-shards", type=int, default=1)
    ap.add_argument("--simulate-failure", type=int, default=-1)
    ap.add_argument("--watchdog-timeout", type=float, default=300.0)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. to reach ~100M params)")
    ap.add_argument("--n-layers", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    over = {}
    if args.d_model:
        over.update(d_model=args.d_model,
                    d_ff=4 * args.d_model,
                    head_dim=args.d_model // cfg.n_heads)
    if args.n_layers:
        over.update(n_layers=args.n_layers)
    if over:
        cfg = dataclasses.replace(cfg, **over)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                          total_steps=args.steps,
                          moment_dtype=cfg.moment_dtype)

    mesh = make_host_mesh(data=len(jax.devices()), model=1)
    sizes = mesh_axis_sizes(mesh)
    policy = ShardingPolicy(fsdp=cfg.fsdp)
    print(f"[train] arch={cfg.name} params={cfg.param_count():,} "
          f"mesh={sizes} ckpt={args.ckpt_dir}", flush=True)

    make_state, step_fn, sshard = build(cfg, opt_cfg, mesh, policy)

    # ---- restore or init -------------------------------------------------
    start_step = 0
    state_abs = api.init_train_state_abstract(cfg, opt_cfg)
    latest = store.latest_step(args.ckpt_dir)
    if latest is not None:
        state, extra = store.restore(args.ckpt_dir, state_abs,
                                     shardings=sshard)
        start_step = int(extra.get("next_step", latest))
        print(f"[train] restored step {latest} -> resuming at {start_step}",
              flush=True)
    else:
        state = make_state(jax.random.PRNGKey(args.seed))

    data = make_pipeline(cfg.vocab_size, args.seq, args.batch,
                         seed=args.seed, n_shards=args.data_shards)
    ckpt = store.AsyncCheckpointer(args.ckpt_dir)
    monitor = StragglerMonitor(
        on_straggler=lambda ev: print(
            f"[straggler] step {ev.step}: {ev.step_time:.3f}s "
            f"({ev.ratio:.1f}x ewma) -> rebalance hook", flush=True))
    dog = Watchdog(args.watchdog_timeout,
                   on_timeout=lambda: print("[watchdog] step timeout — "
                                            "restart from last checkpoint",
                                            flush=True)).start()

    losses = []
    try:
        for step in range(start_step, args.steps):
            if step == args.simulate_failure:
                raise SimulatedFailure(f"injected failure at step {step}")
            t0 = time.time()
            batch = data[step]
            with mesh:
                state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            dog.beat()
            monitor.record(step, dt)
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt*1e3:7.1f} ms",
                      flush=True)
            if step and step % args.ckpt_every == 0:
                ckpt.save(step, state, extra={"next_step": step + 1})
        ckpt.save(args.steps - 1, state, extra={"next_step": args.steps})
        ckpt.wait()
        dog.stop()
        print(f"[train] done. first loss {losses[0]:.4f} -> "
              f"last {losses[-1]:.4f} (events: "
              f"{len(monitor.events)} stragglers)", flush=True)
        return losses
    except SimulatedFailure as e:
        ckpt.wait()
        dog.stop()
        print(f"[train] FAILURE: {e} — relaunch me to resume from the last "
              f"committed checkpoint", flush=True)
        sys.exit(17)


if __name__ == "__main__":
    train()
