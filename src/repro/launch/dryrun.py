import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
#   init.  This file is the ONLY place the 512-placeholder-device trick
#   is applied (smoke tests and benches see the real single device).

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_NAMES, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.distributed.sharding import (ShardingPolicy, batch_pspecs,  # noqa: E402
                                        cache_pspecs, params_pspecs,
                                        state_pspecs, to_shardings)
from repro.launch.analysis import (Roofline, collective_bytes,  # noqa: E402
                                   hlo_op_histogram, ideal_traffic,
                                   model_flops)
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes  # noqa: E402
from repro.models import api  # noqa: E402
from repro.models.frontends import input_specs  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402


def _sharded_bytes(tree, spec_tree, mesh) -> float:
    """Analytic bytes/device for a (possibly abstract) pytree + specs."""
    sizes = mesh_axis_sizes(mesh)

    def leaf_bytes(leaf, spec):
        shard = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shard *= sizes[a]
        n = 1
        for d in leaf.shape:
            n *= d
        return n * jnp.dtype(leaf.dtype).itemsize / shard

    total = 0.0
    for leaf, spec in zip(jax.tree.leaves(tree),
                          jax.tree.leaves(spec_tree,
                                          is_leaf=lambda x: isinstance(x, P))):
        total += leaf_bytes(leaf, spec)
    return total


def build_cell(cfg, shape_name: str, mesh, policy=ShardingPolicy()):
    """Returns (fn, abstract_args, in_shardings, static_bytes/device)."""
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    opt_cfg = AdamWConfig(moment_dtype=cfg.moment_dtype)

    if shape.kind == "train":
        state = api.init_train_state_abstract(cfg, opt_cfg)
        sspec = state_pspecs(cfg, mesh, state, policy)
        bspec = batch_pspecs(cfg, mesh, specs)
        fn = lambda s, b: api.train_step(cfg, opt_cfg, s, b)
        args = (state, specs)
        shardings = (to_shardings(mesh, sspec), to_shardings(mesh, bspec))
        static = _sharded_bytes(state, sspec, mesh)
        donate = (0,)
    elif shape.kind == "prefill":
        params = api.init_params_abstract(cfg)
        pspec = params_pspecs(cfg, mesh, params, policy)
        bspec = batch_pspecs(cfg, mesh, specs)
        fn = lambda p, b: api.prefill_step(cfg, p, b)
        args = (params, specs)
        shardings = (to_shardings(mesh, pspec), to_shardings(mesh, bspec))
        static = _sharded_bytes(params, pspec, mesh)
        donate = ()
    else:  # decode
        params = api.init_params_abstract(cfg)
        pspec = params_pspecs(cfg, mesh, params, policy)
        caches = jax.eval_shape(
            lambda: api.init_decode_caches(cfg, shape.global_batch,
                                           shape.seq_len))
        cspec = cache_pspecs(cfg, mesh, caches, policy)
        bspec = batch_pspecs(cfg, mesh, specs)
        tokens = specs["tokens"]
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        fn = lambda p, c, t, i: api.decode_step(cfg, p, c, t, i)
        args = (params, caches, tokens, pos)
        shardings = (to_shardings(mesh, pspec), to_shardings(mesh, cspec),
                     to_shardings(mesh, bspec)["tokens"],
                     jax.NamedSharding(mesh, P()))
        static = (_sharded_bytes(params, pspec, mesh)
                  + _sharded_bytes(caches, cspec, mesh))
        donate = (1,)
    return fn, args, shardings, static, donate


def _compile_and_measure(cfg, shape_name: str, mesh, policy):
    """Lower+compile one graph; return raw metrics dict."""
    t0 = time.time()
    fn, args, shardings, static_bytes, donate = build_cell(
        cfg, shape_name, mesh, policy)
    with mesh:
        lowered = jax.jit(fn, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):   # jax<=0.4.x: one dict per device
            cost = cost[0] if cost else {}
        try:
            mem = compiled.memory_analysis()
            mem_d = {
                "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_size_in_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
            }
        except Exception as e:  # CPU backend may not support it
            mem_d = {"error": str(e)}
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        hist = hlo_op_histogram(hlo)
        hlo_len = len(hlo)
        del hlo, compiled, lowered
    return {
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "static_bytes_per_device": static_bytes,
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "memory": mem_d, "collectives": coll, "hlo_ops": hist,
        "hlo_chars": hlo_len,
    }


def _calibration_cfgs(cfg):
    """1-group and 2-group unrolled configs at full width.

    XLA counts a while-loop body once; lowering unrolled graphs at 1 and
    2 groups gives per-group deltas to extrapolate true totals:
        total = m2 + (n_groups - 2) * (m2 - m1).
    Inner *time* scans (mamba/rwkv recurrences) stay while-loops — a
    ~1% FLOP undercount, recorded in EXPERIMENTS.md methodology.
    """
    from repro.models.transformer import block_period
    P = block_period(cfg)
    n_groups = cfg.n_layers // P
    rep = {"scan_layers": False, "remat": cfg.remat}
    c1 = dataclasses.replace(cfg, n_layers=P, **rep)
    c2 = dataclasses.replace(cfg, n_layers=2 * P, **rep)
    if cfg.enc_layers:
        c1 = dataclasses.replace(c1, enc_layers=1)
        c2 = dataclasses.replace(c2, enc_layers=2)
    return c1, c2, n_groups


def _extrapolate(m1: dict, m2: dict, n_groups: int) -> dict:
    """total = m2 + (G-2) * (m2 - m1), per scalar metric."""
    out = {}
    for key in ("flops", "bytes_accessed"):
        out[key] = m2[key] + (n_groups - 2) * (m2[key] - m1[key])
    coll = {}
    for k, v2 in m2["collectives"].items():
        if k == "counts":
            continue
        v1 = m1["collectives"][k]
        coll[k] = v2 + (n_groups - 2) * (v2 - v1)
    out["collectives"] = coll
    return out


# ---------------------------------------------------------------------------
# §Perf variants: each is a real graph/sharding change, run via
#   --variant <name> (tag defaults to the variant name).
# ---------------------------------------------------------------------------
VARIANTS = {
    # attention score chunks materialized bf16 (stats stay f32)
    "bf16scores": lambda cfg: dataclasses.replace(
        cfg, attn_score_dtype="bfloat16"),
    # MoE dispatch via scatter/gather instead of one-hot einsums
    "scattermoe": lambda cfg: dataclasses.replace(
        cfg, moe_dispatch="scatter") if cfg.moe else cfg,
    # remat policy: save matmul outputs instead of recomputing everything
    "dotsremat": lambda cfg: dataclasses.replace(cfg, remat="block_dots"),
    # skip fully-masked causal kv chunks (exact; the Pallas kernel's
    # pl.when block-skip expressed as lax.cond in the graph twin)
    "causalskip": lambda cfg: dataclasses.replace(cfg, causal_skip=True),
    # pad attention heads up to the TP degree so they shard 16-way
    # (zero-extended heads = identical function; removes replicated
    # attention compute for 56-head/8-kv archs)
    "padheads": lambda cfg: dataclasses.replace(
        cfg, n_heads=-(-cfg.n_heads // 16) * 16,
        n_kv_heads=16 if cfg.n_kv_heads % 16 else cfg.n_kv_heads)
    if (cfg.n_heads % 16 or cfg.n_kv_heads % 16) else cfg,
    # capacity factor 1.25 -> 1.0: shrinks every expert tensor 20% for
    # ~2% dropped tokens (prod-standard trade)
    "cap1": lambda cfg: dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    if cfg.moe else cfg,
    # the combined optimized configuration (bf16scores excluded: refuted
    # on the CPU-twin metric — CPU bf16 emulation inserts f32 converts;
    # dotsremat is applied to train cells only, see run_cell)
    "opt": lambda cfg: VARIANTS["padheads"](VARIANTS["causalskip"](
        VARIANTS["cap1"](VARIANTS["dotsremat"](VARIANTS["scattermoe"](cfg))))),
}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             force: bool = False, policy=ShardingPolicy(),
             tag: str = "", calibrate: bool = True,
             variant: str = "") -> dict:
    mesh_name = "multi" if multi_pod else "single"
    if variant and not tag:
        tag = variant
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_path = out_dir / f"{cell_id}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if variant:
        cfg = VARIANTS[variant](cfg)
        if variant == "opt" and shape.kind != "train" \
                and cfg.remat == "block_dots":
            # saving dot outputs is pure overhead without a backward pass
            cfg = dataclasses.replace(cfg, remat="block")
    ok, why = shape_applicable(cfg, shape)
    record = {"cell": cell_id, "arch": arch, "shape": shape_name,
              "mesh": mesh_name, "tag": tag or "baseline"}
    if not ok:
        record.update(status="skipped", reason=why)
        out_path.write_text(json.dumps(record, indent=2))
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    if cfg.fsdp and not policy.fsdp:
        policy = dataclasses.replace(policy, fsdp=True)
    try:
        # 1) the deployable scan graph: memory + collective schedule
        main = _compile_and_measure(cfg, shape_name, mesh, policy)
        # 2) cost calibration: unrolled 1-group / 2-group graphs
        if calibrate:
            c1, c2, n_groups = _calibration_cfgs(cfg)
            m1 = _compile_and_measure(c1, shape_name, mesh, policy)
            m2 = _compile_and_measure(c2, shape_name, mesh, policy)
            tot = _extrapolate(m1, m2, n_groups)
            cal = {"n_groups": n_groups,
                   "cal1_compile_s": m1["compile_s"],
                   "cal2_compile_s": m2["compile_s"]}
        else:
            tot = {"flops": main["flops"],
                   "bytes_accessed": main["bytes_accessed"],
                   "collectives": {k: v for k, v in
                                   main["collectives"].items()
                                   if k != "counts"}}
            cal = {"n_groups": None}

        mf = model_flops(cfg, shape)
        sizes = mesh_axis_sizes(mesh)
        tp = sizes.get("model", 1)
        dp = chips // tp
        min_hbm, min_coll = ideal_traffic(cfg, shape, dp, tp, chips,
                                          fsdp=policy.fsdp)
        roof = Roofline(flops=tot["flops"] * chips,
                        hbm_bytes=tot["bytes_accessed"] * chips,
                        coll_bytes=tot["collectives"]["total"] * chips,
                        chips=chips, model_flops=mf,
                        min_hbm_bytes=min_hbm, min_coll_bytes=min_coll)
        record.update(
            status="ok", chips=chips,
            lower_s=main["lower_s"], compile_s=main["compile_s"],
            static_bytes_per_device=main["static_bytes_per_device"],
            memory=main["memory"],
            scan_graph={"flops": main["flops"],
                        "bytes_accessed": main["bytes_accessed"],
                        "collectives": {k: v for k, v in
                                        main["collectives"].items()
                                        if k != "counts"},
                        "collective_counts": main["collectives"]["counts"],
                        "hlo_ops": main["hlo_ops"],
                        "hlo_chars": main["hlo_chars"]},
            calibration=cal,
            totals_per_device=tot,
            roofline=roof.as_dict(),
        )
    except Exception as e:
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    out_path.write_text(json.dumps(record, indent=2))
    return record


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--variant", default="", choices=[""] + list(VARIANTS))
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = ARCH_NAMES if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    policy = ShardingPolicy(fsdp=args.fsdp)

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                t0 = time.time()
                rec = run_cell(arch, shape, multi, out_dir,
                               force=args.force, policy=policy, tag=args.tag,
                               calibrate=not args.no_calibrate,
                               variant=args.variant)
                dt = time.time() - t0
                status = rec["status"]
                n_ok += status == "ok"
                n_skip += status == "skipped"
                n_err += status == "error"
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f"dom={r['dominant']:<10s} "
                             f"frac={r['roofline_fraction']:.3f} "
                             f"mem/dev={rec['static_bytes_per_device']/2**30:.2f}GiB")
                elif status == "error":
                    extra = rec["error"][:120]
                print(f"[{status:>7s}] {rec['cell']:<55s} {dt:6.1f}s {extra}",
                      flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors", flush=True)
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
