"""Batched serving driver: prefill + decode with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
        --requests 12 --max-new 16

A minimal but real serving loop: a request queue feeds fixed-slot
batches; prefill fills a slot's KV cache (padded to max_len so decode
appends in place), decode advances all live slots one token per tick,
finished slots are immediately refilled from the queue (continuous
batching).  Greedy sampling; per-slot position bookkeeping.

Note on slot caches: decode_step takes the *batched* cache; a slot's
prefill writes its rows via dynamic_update_slice on the batch dim.
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import api


class Request:
    def __init__(self, rid: int, prompt: np.ndarray, max_new: int):
        self.rid = rid
        self.prompt = prompt
        self.max_new = max_new
        self.generated: List[int] = []
        self.done = False


def serve(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.embed_inputs:
        raise SystemExit("serve.py drives token-in archs; use examples for "
                         "stub-frontend archs")
    rng = np.random.default_rng(args.seed)
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))

    B, L = args.slots, args.max_len

    prefill_one = jax.jit(lambda p, b: api.prefill_step(cfg, p, b,
                                                        pad_to=L))
    decode_fn = jax.jit(lambda p, c, t, i: api.decode_step(cfg, p, c, t, i))

    # Batched slot cache (B slots); per-slot prefill writes its row.
    caches = api.init_decode_caches(cfg, B, L)

    def write_slot(caches, slot_cache, slot: int):
        """Insert a 1-row prefill cache into slot `slot` of the batch."""
        def upd(c, s):
            if c.ndim != s.ndim:
                return c
            pad = [(0, 0)] * s.ndim
            if s.shape[2 if s.ndim >= 3 else 1] != c.shape[2 if c.ndim >= 3 else 1] \
               and s.ndim >= 3:
                pad[2] = (0, c.shape[2] - s.shape[2])
                s = jnp.pad(s, pad)
            return jax.lax.dynamic_update_slice_in_dim(c, s.astype(c.dtype),
                                                       slot, axis=1)
        return jax.tree.map(upd, caches, slot_cache)

    queue = [Request(i, rng.integers(1, cfg.vocab_size,
                                     (args.prompt_len,), dtype=np.int64),
                     args.max_new)
             for i in range(args.requests)]
    slots: List[Optional[Request]] = [None] * B
    pos = np.zeros(B, dtype=np.int64)
    cur_tok = np.zeros(B, dtype=np.int64)
    completed: List[Request] = []
    t0 = time.time()
    n_decode_ticks = 0

    def admit(caches):
        for s in range(B):
            if slots[s] is None and queue:
                req = queue.pop(0)
                batch = {"tokens": jnp.asarray(req.prompt[None, :],
                                               jnp.int32)}
                logits, c1, plen = prefill_one(params, batch)
                caches = write_slot(caches, c1, s)
                slots[s] = req
                pos[s] = plen
                cur_tok[s] = int(jnp.argmax(logits[0]))
                req.generated.append(cur_tok[s])
        return caches

    caches = admit(caches)
    while any(s is not None for s in slots) or queue:
        # one decode tick for all live slots (dead slots decode garbage
        # into their own rows — isolated and overwritten on admit)
        tick_pos = int(max(pos))  # uniform pos: caches padded to max_len
        tokens = jnp.asarray(cur_tok[:, None], jnp.int32)
        logits, caches = decode_fn(params, caches, tokens,
                                   jnp.int32(tick_pos))
        n_decode_ticks += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s in range(B):
            req = slots[s]
            if req is None:
                continue
            pos[s] += 1
            cur_tok[s] = nxt[s]
            req.generated.append(int(nxt[s]))
            if len(req.generated) >= req.max_new or pos[s] >= L - 1:
                req.done = True
                completed.append(req)
                slots[s] = None
        caches = admit(caches)

    dt = time.time() - t0
    toks = sum(len(r.generated) for r in completed)
    print(f"[serve] {len(completed)} requests, {toks} tokens, "
          f"{n_decode_ticks} decode ticks, {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)", flush=True)
    for r in completed[:3]:
        print(f"  req {r.rid}: {r.generated[:8]}...", flush=True)
    return completed


if __name__ == "__main__":
    serve()
