"""Render experiments/dryrun JSONs into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def load(dir_: Path, mesh: str, tag: str = "baseline"):
    recs = []
    for f in sorted(dir_.glob(f"*__{mesh}*.json")):
        r = json.loads(f.read_text())
        if r.get("tag", "baseline") == tag and r["mesh"] == mesh:
            recs.append(r)
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def dryrun_table(recs):
    lines = ["| arch | shape | status | compile s | bytes/dev GiB | "
             "HLO GFLOPs/dev | coll GiB/dev | collective schedule |",
             "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP | - | - | - "
                         f"| - | {r['reason'][:60]} |")
            continue
        if r["status"] == "error":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | - | - | - "
                         f"| - | {r['error'][:60]} |")
            continue
        sg = r["scan_graph"]
        counts = sg["collective_counts"]
        sched = " ".join(f"{k.split('-')[0][:2]}{k.split('-')[-1][:3]}:{v}"
                         for k, v in counts.items() if v)
        tot = r.get("totals_per_device", sg)
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.0f} "
            f"| {fmt_bytes(r['static_bytes_per_device'])} "
            f"| {tot['flops']/1e9:.0f} "
            f"| {tot['collectives']['total']/2**30:.2f} "
            f"| {sched or 'none'} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant "
             "| 6ND/HLO | frac | one-line diagnosis |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        diag = diagnose(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['t_compute_s']*1e3:.1f} "
            f"| {ro['t_memory_s']*1e3:.1f} | {ro['t_collective_s']*1e3:.1f} "
            f"| **{ro['dominant']}** | {ro['useful_flops_ratio']:.2f} "
            f"| {ro['roofline_fraction']:.3f} | {diag} |")
    return "\n".join(lines)


def diagnose(r) -> str:
    ro = r["roofline"]
    dom = ro["dominant"]
    if dom == "memory":
        ratio = ro["hbm_bytes"] / max(ro["min_hbm_bytes"], 1)
        if r["shape"].startswith("decode") or r["shape"].startswith("long"):
            return (f"{ratio:.0f}x min traffic: cache update copies + "
                    "gathered weights; fix: in-place donation + 2D-TP "
                    "weight sharding")
        return (f"{ratio:.0f}x min traffic: f32 score chunks + remat "
                "recompute traffic; fix: Pallas flash kernel (VMEM-resident "
                "scores) + selective remat")
    if dom == "collective":
        return "all-reduce bound: resharding / overlap needed"
    return "compute-bound: good — push useful-flops ratio"


def perf_table(d: Path):
    """§Perf: baseline vs variants for the three hillclimb cells, plus
    the kernel-deployed memory model (Pallas flash attention: VMEM-
    resident scores; every op output crosses HBM once)."""
    from repro.configs import SHAPES, get_config
    from repro.launch.analysis import deployed_traffic
    from repro.core.resources import HBM_BW
    cells = [("olmo-1b", "train_4k"),
             ("grok-1-314b", "train_4k"),
             ("llava-next-34b", "prefill_32k")]
    lines = ["| cell | variant | t_mem s | t_comp s | t_coll s | frac | Δ vs base |",
             "|---|---|---|---|---|---|---|"]
    for arch, shape in cells:
        base_frac = None
        for f in sorted(d.glob(f"{arch}__{shape}__single*.json")):
            r = json.loads(f.read_text())
            if r["status"] != "ok":
                continue
            ro = r["roofline"]
            tag = r.get("tag", "baseline")
            if tag == "baseline":
                base_frac = ro["roofline_fraction"]
        for f in sorted(d.glob(f"{arch}__{shape}__single*.json")):
            r = json.loads(f.read_text())
            if r["status"] != "ok":
                continue
            ro = r["roofline"]
            tag = r.get("tag", "baseline")
            delta = (f"{ro['roofline_fraction']/base_frac:.2f}x"
                     if base_frac else "-")
            lines.append(
                f"| {arch}/{shape} | {tag} | {ro['t_memory_s']:.1f} "
                f"| {ro['t_compute_s']:.1f} | {ro['t_collective_s']:.1f} "
                f"| {ro['roofline_fraction']:.4f} | {delta} |")
        # kernel-deployed model row: the best variant's measured compute
        # and collective terms + the Pallas-kernel memory model (scores
        # in VMEM, op outputs cross HBM once).
        import dataclasses as _dc
        cfg = get_config(arch)
        if cfg.n_heads % 16 or cfg.n_kv_heads % 16:   # padheads applied
            cfg = _dc.replace(cfg, n_heads=-(-cfg.n_heads // 16) * 16,
                              n_kv_heads=16)
        sh = SHAPES[shape]
        dep = deployed_traffic(cfg, sh, dp=16, tp=16, chips=256,
                               fsdp=cfg.fsdp)
        opt_f = d / f"{arch}__{shape}__single__opt.json"
        src = json.loads((opt_f if opt_f.exists() else
                          d / f"{arch}__{shape}__single.json").read_text())
        ro = src["roofline"]
        t_mem_dep = dep / (256 * HBM_BW)
        bound = max(ro["t_compute_s"], t_mem_dep, ro["t_collective_s"])
        frac_dep = min(ro["ideal_time_s"] / max(bound, 1e-12), 1.0)
        dom = ("compute" if bound == ro["t_compute_s"] else
               "memory" if bound == t_mem_dep else "collective")
        lines.append(
            f"| {arch}/{shape} | **deployed (Pallas kernels, opt)** "
            f"| {t_mem_dep:.1f} | {ro['t_compute_s']:.1f} "
            f"| {ro['t_collective_s']:.1f} | {frac_dep:.4f} "
            f"| {frac_dep/base_frac:.1f}x ({dom}-bound) |"
            if base_frac else "")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()
    d = Path(args.dir)

    single = load(d, "single", args.tag)
    multi = load(d, "multi", args.tag)
    print("## Dry-run — single pod 16x16 (256 chips)\n")
    print(dryrun_table(single))
    print("\n## Dry-run — multi-pod 2x16x16 (512 chips)\n")
    print(dryrun_table(multi))
    print("\n## Roofline (single-pod, calibrated)\n")
    print(roofline_table(single))
    ok = [r for r in single if r["status"] == "ok"]
    if ok:
        fr = [r["roofline"]["roofline_fraction"] for r in ok]
        print(f"\nmean baseline fraction: {sum(fr)/len(fr):.3f} | "
              f"min {min(fr):.3f} | max {max(fr):.3f}")
        worst = sorted(ok, key=lambda r: r["roofline"]["roofline_fraction"])
        print("worst cells:", [(r["cell"],
                                round(r["roofline"]["roofline_fraction"], 3))
                               for r in worst[:5]])
        collb = sorted(ok, key=lambda r: -r["roofline"]["t_collective_s"]
                       / max(r["roofline"]["bound_time_s"], 1e-12))
        print("most collective-heavy:",
              [(r["cell"], round(r["roofline"]["t_collective_s"]
                                 / r["roofline"]["bound_time_s"], 3))
               for r in collb[:5]])
    print("\n## §Perf hillclimb cells (all recorded variants)\n")
    print(perf_table(d))


if __name__ == "__main__":
    main()
