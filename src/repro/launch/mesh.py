"""Production mesh builders.

Functions, not module constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh):
    """Data-parallel axes: ('pod','data') on multi-pod, ('data',) else."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
