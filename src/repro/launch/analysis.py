"""Compiled-artifact analysis: collective-bytes parser + roofline terms.

``cost_analysis()`` gives HLO FLOPs / bytes; collective traffic is NOT
in there, so we parse the post-optimization HLO text and sum operand
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  Hardware constants from core.resources (TPU v5e:
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.core.resources import HBM_BW, ICI_BW_PER_LINK, PEAK_BF16_FLOPS

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "f32[256,4096,128]{2,1,0}" — capture dtype + dims
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# result line: "%name = f32[...] all-reduce(...)" or tuple results
_INSTR_RE = re.compile(
    r"=\s+(\([^)]*\)|[\w\[\]{},]+)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"[\s(]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{(\d+(?:,\d+)*)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum *operand* bytes per collective kind from optimized HLO text.

    For all-gather the printed result is the gathered (large) buffer:
    operand = result / group_size.  For reduce-scatter the result is the
    scattered buffer: operand = result * group_size.  For all-reduce /
    all-to-all / collective-permute operand == result.
    """
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        result_text, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        nbytes = _shape_bytes(result_text)
        g = _group_size(line)
        if op == "all-gather":
            nbytes = nbytes / max(g, 1)
        elif op == "reduce-scatter":
            nbytes = nbytes * max(g, 1)
        out[op] += nbytes
        counts[op] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts  # type: ignore[assignment]
    return out


def hlo_op_histogram(hlo_text: str, ops=("transpose", "reshape", "copy",
                                         "convert", "fusion", "while")):
    hist = {}
    for op in ops:
        hist[op] = len(re.findall(rf"=\s+[\w\[\]{{}},()\s]*?\b{op}\(", hlo_text))
    return hist


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Roofline:
    flops: float                # counted HLO FLOPs (all chips)
    hbm_bytes: float            # counted HLO bytes accessed (all chips)
    coll_bytes: float           # counted collective operand bytes (all chips)
    chips: int
    model_flops: float = 0.0    # 6·N_active·D analytic useful FLOPs
    min_hbm_bytes: float = 0.0  # analytic minimum traffic (all chips)
    min_coll_bytes: float = 0.0
    ici_links: int = 4

    def _t(self, flops, hbm, coll):
        return {"compute": flops / (self.chips * PEAK_BF16_FLOPS),
                "memory": hbm / (self.chips * HBM_BW),
                "collective": coll / (self.chips * ICI_BW_PER_LINK
                                      * self.ici_links)}

    @property
    def t_compute(self):
        return self._t(self.flops, self.hbm_bytes, self.coll_bytes)["compute"]

    @property
    def t_memory(self):
        return self._t(self.flops, self.hbm_bytes, self.coll_bytes)["memory"]

    @property
    def t_collective(self):
        return self._t(self.flops, self.hbm_bytes,
                       self.coll_bytes)["collective"]

    @property
    def dominant(self) -> str:
        t = self._t(self.flops, self.hbm_bytes, self.coll_bytes)
        return max(t, key=t.get)

    @property
    def bound_time(self) -> float:
        return max(self._t(self.flops, self.hbm_bytes,
                           self.coll_bytes).values())

    @property
    def ideal_time(self) -> float:
        """Bound time of an ideal implementation: useful FLOPs, minimum
        HBM traffic, minimum collective traffic."""
        return max(self._t(self.model_flops, self.min_hbm_bytes,
                           self.min_coll_bytes).values())

    @property
    def roofline_fraction(self) -> float:
        """ideal bound / actual bound — 1.0 means the compiled graph is
        at the hardware roofline for this workload (the §Perf score)."""
        if self.bound_time == 0:
            return 0.0
        return min(self.ideal_time / self.bound_time, 1.0)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "model_flops": self.model_flops,
            "min_hbm_bytes": self.min_hbm_bytes,
            "min_coll_bytes": self.min_coll_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "bound_time_s": self.bound_time, "ideal_time_s": self.ideal_time,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) for train; 2·N·D per token for
    inference (prefill: xD tokens; decode: 1 token/seq)."""
    n_active = cfg.param_count(active_only=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    per_tok = 6 * n_active if shape.kind == "train" else 2 * n_active
    return float(per_tok) * tokens


def ideal_traffic(cfg, shape, dp: int, tp: int, chips: int,
                  fsdp: bool = False):
    """Analytic minimum (HBM bytes, collective bytes), summed over chips.

    Documented approximations (EXPERIMENTS.md §Roofline methodology):
      * params sharded over tp (plus dp when fsdp); per-chip *storage*
        N/tp (N/(tp·dp) under fsdp).
      * train HBM: params read fwd+bwd+update + grads w+r + opt r+w
        + per-group boundary activations (save+reload, remat=block)
        + logits write+read + token embeds.  Under fsdp the gathered
        weights additionally pass HBM twice (write on gather, read).
      * decode HBM: local param shard read + full KV/state cache spread
        over all chips (the 2D-tensor-parallel lower bound: weights stay
        sharded, tiny decode activations are psum'd instead of weights
        being gathered); prefill: params + activations + cache write.
      * train collectives: DP grad ring all-reduce 2·G·(dp-1)/dp (or
        reduce-scatter+all-gather under fsdp, same bytes) + fsdp weight
        all-gathers (fwd+bwd) + TP 2 all-reduce/layer fwd + 2 bwd of the
        (B,S,D) activation (ring: 2x each) + MoE all-to-alls.
      * decode/prefill collectives: TP activation all-reduces (+MoE a2a).
    """
    p_item = jnp_itemsize(cfg.param_dtype)
    m_item = jnp_itemsize(cfg.moment_dtype)
    c_item = jnp_itemsize(cfg.compute_dtype)
    N = cfg.param_count()
    shard = tp * (dp if fsdp else 1)
    params_store_dev = N * p_item / shard
    opt_dev = 2 * N * m_item / shard
    B, S = shape.global_batch, shape.seq_len
    B_loc = B / dp if B >= dp else B
    D, V, L = cfg.d_model, cfg.vocab_size, cfg.n_layers
    tokens_loc = B_loc * (S if shape.kind != "decode" else 1)

    from repro.models.transformer import block_period
    P = block_period(cfg)
    G = max(L // P, 1)

    if shape.kind == "train":
        # weights must be materialized per chip at N/tp for the big
        # activation matmuls, whether stored locally or gathered.
        params_use_dev = N * p_item / tp
        hbm_dev = (3 * params_use_dev + 2 * opt_dev + 2 * N * 4 / shard
                   + 2 * G * B_loc * S * D * c_item                # boundaries
                   + 2 * B_loc * S * V / tp * c_item               # logits
                   + 2 * B_loc * S * D * c_item)                   # embeds
        coll_dev = (2 * (N * 4 / shard) * (dp - 1) / dp            # grad sync
                    + (8 if tp > 1 else 0) * L * B_loc * S * D * c_item)
        if fsdp:
            coll_dev += 2 * params_use_dev * (dp - 1) / dp         # w gathers
        if cfg.moe:
            coll_dev += 4 * tokens_loc * D * c_item * cfg.moe.top_k \
                * (L // cfg.moe.moe_every) / L
    elif shape.kind == "prefill":
        cache_dev = L * B_loc * S * cfg.n_kv_heads * cfg.head_dim * 2 * c_item
        hbm_dev = (params_store_dev + 2 * G * B_loc * S * D * c_item
                   + cache_dev)
        coll_dev = (4 if tp > 1 else 0) * L * B_loc * S * D * c_item
        if cfg.moe:
            coll_dev += 2 * tokens_loc * D * c_item * cfg.moe.top_k \
                * (L // cfg.moe.moe_every) / L
    else:  # decode
        n_attn = sum(1 for k in cfg.attn_layout if k == "attn")
        cache_total = B * S * cfg.n_kv_heads * cfg.head_dim * 2 * c_item * n_attn
        if cfg.family == "encdec":
            cache_total *= 2  # self + cross caches
        state_total = 0.0
        if any(k == "mamba" for k in cfg.attn_layout):
            n_m = sum(1 for k in cfg.attn_layout if k == "mamba")
            state_total += n_m * B * cfg.d_inner * (cfg.mamba.d_state * 4
                                                    + c_item)
        if any(k == "rwkv" for k in cfg.attn_layout):
            hs = cfg.rwkv.head_size
            state_total += L * B * (D // hs) * hs * hs * 4
        # best case: params stay sharded (2D TP), cache spread over chips
        hbm_dev = params_store_dev + (cache_total + state_total) / chips
        coll_dev = (4 if tp > 1 else 0) * L * B_loc * 1 * D * c_item \
            + (2 * L * B_loc * D * c_item if fsdp else 0)  # dp-axis psums
    return hbm_dev * chips, coll_dev * chips


def jnp_itemsize(dtype_str: str) -> int:
    import jax.numpy as jnp
    return jnp.dtype(dtype_str).itemsize


# ---------------------------------------------------------------------------
# Kernel-deployed memory model
# ---------------------------------------------------------------------------
def deployed_traffic(cfg, shape, dp: int, tp: int, chips: int,
                     fsdp: bool = False) -> float:
    """HBM bytes/step (all chips) of the TPU deployment where attention
    runs through the Pallas flash/flash-decode kernels (score chunks are
    VMEM-resident — their HBM traffic is q/k/v/o only) and every other
    major op's output crosses HBM exactly once (no fusion credit).

    This is the deployment-true memory term the CPU-twin graph cannot
    express: XLA-CPU materializes score chunks that the Pallas kernel
    holds in VMEM, and `cost_analysis()` re-counts each buffer at both
    producer and consumers.  Used for the `deployed` rows of §Perf.
    """
    c_item = jnp_itemsize(cfg.compute_dtype)
    p_item = jnp_itemsize(cfg.param_dtype)
    m_item = jnp_itemsize(cfg.moment_dtype)
    N = cfg.param_count()
    B, S = shape.global_batch, shape.seq_len
    B_loc = B / dp if B >= dp else B
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    qkv_dim = (Hq + 2 * Hkv) * Dh / tp if Hq % tp == 0 else (Hq + 2 * Hkv) * Dh
    shard = tp * (dp if fsdp else 1)

    if shape.kind == "decode":
        S_act = 1
    else:
        S_act = S
    act = B_loc * S_act * c_item

    per_attn = act * (2 * D + 2 * qkv_dim + 2 * Hq * Dh / max(tp, 1) + 2 * D)
    if shape.kind == "decode":
        # flash-decode sweeps the cache once
        n_attn = sum(1 for k in cfg.attn_layout if k == "attn")
        cache = (B * S * Hkv * Dh * 2 * c_item * n_attn) / chips
        per_attn += 0  # cache counted once below
    ffn_f = F / tp if F % tp == 0 else F
    per_ffn = act * (2 * D + 4 * ffn_f + 2 * D)
    if cfg.moe:
        per_ffn *= cfg.moe.top_k * 1.25 / cfg.moe.moe_every + (
            1 - 1 / cfg.moe.moe_every)
    mamba_di = cfg.d_inner / tp
    per_mamba = act * (2 * D + 8 * mamba_di + 2 * D)
    per_rwkv = act * (2 * D + 12 * D + 4 * F)

    layer_bytes = 0.0
    for kind in cfg.attn_layout:
        layer_bytes += {"attn": per_attn + per_ffn,
                        "mamba": per_mamba + per_ffn if cfg.moe else per_mamba + per_ffn,
                        "rwkv": per_rwkv}[kind]
    if cfg.enc_layers:
        layer_bytes += cfg.enc_layers * (per_attn + per_ffn) \
            + cfg.n_layers * per_attn  # cross-attn
    logits = 2 * B_loc * S_act * V / max(tp, 1) * c_item

    if shape.kind == "train":
        # fwd + remat-recompute fwd + bwd ~ 3x activation traffic;
        # params read fwd+bwd + grads + opt update
        total = (3 * layer_bytes + 2 * logits
                 + 3 * N * p_item / tp + 2 * N * 4 / shard
                 + 2 * 2 * N * m_item / shard)
    elif shape.kind == "prefill":
        cache_w = cfg.n_layers * B_loc * S * Hkv * Dh * 2 * c_item
        total = layer_bytes + logits + N * p_item / tp + cache_w
    else:
        n_attn = sum(1 for k in cfg.attn_layout if k == "attn")
        cache = (B * S * Hkv * Dh * 2 * c_item * n_attn
                 * (2 if cfg.family == "encdec" else 1)) / chips
        state = 0.0
        if any(k == "mamba" for k in cfg.attn_layout):
            n_m = sum(1 for k in cfg.attn_layout if k == "mamba")
            state += n_m * B * cfg.d_inner * (cfg.mamba.d_state * 4 + c_item) / chips
        if any(k == "rwkv" for k in cfg.attn_layout):
            hs = cfg.rwkv.head_size
            state += cfg.n_layers * B * (D // hs) * hs * hs * 4 / chips
        total = layer_bytes + logits + N * p_item / shard + cache + state
    return total * chips
