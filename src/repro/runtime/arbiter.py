"""Budget arbitration across co-resident tenants.

The paper sizes ONE network against the device's resources; a serving
deployment runs several at once.  The arbiter is ``plan_network``'s
partitioning logic lifted one level: the device ``ResourceBudget`` is
split across registered tenants proportional to *observed demand* (an
EWMA of the work each tenant submits), with every tenant floored at the
minimal fraction its network can still plan under
(``core.plan.network_min_fraction``).  Because that floor descends each
site's precision ladder, a tenant squeezed below its f32 footprint is
granted a slice where it *degrades to int16/int8* instead of failing —
the paper's resource-driven adaptation, made dynamic.

Hysteresis: grants only move when some tenant's target drifts more than
``rebalance_threshold`` from its current grant.  Every rebalance makes
the server re-plan its tenants under the new slices
(``core.plan.replan``), so the threshold is the knob trading
steady-state optimality against re-plan churn.

Pure trace-time Python; deterministic given the observation sequence.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.resources import ResourceBudget

POLICIES = ("demand", "static")


@dataclasses.dataclass(frozen=True)
class TenantShare:
    """One tenant's slice of the device at one arbitration round."""

    name: str
    demand: float       # EWMA of submitted work (est-cycles)
    floor: float        # minimal feasible fraction (ladder included)
    fraction: float     # granted fraction of the device budget


class BudgetArbiter:
    """Splits one device budget across tenants; see module docstring.

    ``policy="demand"`` is the headline arbitration;
    ``policy="static"`` grants an even 1/n split regardless of demand
    or floors — the baseline the benchmarks compare against.
    """

    def __init__(self, budget: Optional[ResourceBudget] = None, *,
                 policy: str = "demand", rebalance_threshold: float = 0.05,
                 demand_alpha: float = 0.5, calibration=None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; have {POLICIES}")
        if not 0.0 < demand_alpha <= 1.0:
            raise ValueError("demand_alpha must be in (0, 1]")
        self.budget = budget or ResourceBudget()
        self.policy = policy
        # The unit the demand EWMA is denominated in: with a fitted
        # CalibrationTable the server prices each tenant's unit cost in
        # *calibrated* cycles, so grants track measured work, not the
        # analytical estimate.  Kept here so ``calibration_key`` in
        # telemetry names the model the grants were computed under.
        self.calibration = calibration
        self.rebalance_threshold = rebalance_threshold
        self.demand_alpha = demand_alpha
        self._floors: Dict[str, float] = {}
        self._demand: Dict[str, float] = {}
        self._pending: Dict[str, float] = {}
        self._granted: Dict[str, float] = {}
        self.rebalances = 0

    def register(self, name: str, floor: float = 0.0) -> None:
        """Admit one tenant.  Validates the whole tenant set *before*
        mutating any state, so a rejected registration leaves no ghost
        entry behind."""
        if name in self._floors:
            raise ValueError(f"tenant {name!r} already registered")
        floor = min(max(float(floor), 0.0), 1.0)
        floors = {**self._floors, name: floor}
        if self.policy == "demand":
            total = sum(floors.values())
            if total > 1.0 + 1e-9:
                raise ValueError(
                    f"tenant floors jointly need {total:.3f}x the device "
                    f"budget — co-residency infeasible even at the "
                    f"narrowest ladder rungs: {floors}")
        else:
            # static grants an unconditional 1/n: a tenant whose floor
            # exceeds that can never serve — reject at admission, same
            # honesty as the demand-policy joint check.
            even = 1.0 / len(floors)
            bad = {m: f for m, f in floors.items() if f > even + 1e-9}
            if bad:
                raise ValueError(
                    f"static even split grants {even:.3f} per tenant, "
                    f"below the minimal feasible fraction of: {bad}")
        self._floors[name] = floor
        self._demand[name] = 0.0
        self._pending[name] = 0.0

    def observe(self, name: str, cost: float) -> None:
        """Record submitted work (est-cycles) for one tenant; folded
        into the demand EWMA at the next ``split()``."""
        self._pending[name] += float(cost)

    def _targets(self) -> Dict[str, float]:
        names = list(self._floors)
        n = len(names)
        if self.policy == "static":
            return {m: 1.0 / n for m in names}
        total_floor = sum(self._floors.values())
        total_demand = sum(self._demand.values())
        if total_demand <= 0.0:
            raw = {m: 1.0 / n for m in names}
        else:
            raw = {m: self._demand[m] / total_demand for m in names}
        surplus = max(0.0, 1.0 - total_floor)
        return {m: self._floors[m] + surplus * raw[m] for m in names}

    def split(self) -> Dict[str, TenantShare]:
        """Fold pending observations into the EWMA and (re)grant.

        The first call always grants; later calls move the grants only
        when some tenant's target drifted more than
        ``rebalance_threshold`` from its current grant (then every
        grant snaps to target, counted in ``rebalances``).  A change in
        the tenant *set* (a registration since the last round) always
        re-grants — hysteresis only ever holds a split that covers
        every current tenant.
        """
        if not self._floors:
            return {}
        a = self.demand_alpha
        for name, pend in self._pending.items():
            self._demand[name] = (1 - a) * self._demand[name] + a * pend
            self._pending[name] = 0.0
        targets = self._targets()
        if set(self._granted) != set(targets):
            was_granted = bool(self._granted)
            self._granted = dict(targets)
            if was_granted:
                self.rebalances += 1
        elif any(abs(targets[m] - self._granted[m])
                 > self.rebalance_threshold for m in targets):
            self._granted = dict(targets)
            self.rebalances += 1
        return {m: TenantShare(name=m, demand=self._demand[m],
                               floor=self._floors[m],
                               fraction=self._granted[m])
                for m in self._floors}

    def budget_for(self, name: str) -> ResourceBudget:
        """The device-budget slice currently granted to ``name``."""
        if name not in self._granted:
            raise KeyError(f"tenant {name!r} has no grant yet "
                           f"(call split() first)")
        return self.budget.scaled(self._granted[name])
