"""Budget arbitration across co-resident tenants.

The paper sizes ONE network against the device's resources; a serving
deployment runs several at once.  The arbiter is ``plan_network``'s
partitioning logic lifted one level: the device ``ResourceBudget`` is
split across registered tenants proportional to *observed demand* (an
EWMA of the work each tenant submits), with every tenant floored at the
minimal fraction its network can still plan under
(``core.plan.network_min_fraction``).  Because that floor descends each
site's precision ladder, a tenant squeezed below its f32 footprint is
granted a slice where it *degrades to int16/int8* instead of failing —
the paper's resource-driven adaptation, made dynamic.

Hysteresis: grants only move when some tenant's target drifts more than
``rebalance_threshold`` from its current grant.  Every rebalance makes
the server re-plan its tenants under the new slices
(``core.plan.replan``), so the threshold is the knob trading
steady-state optimality against re-plan churn.

Pure trace-time Python; deterministic given the observation sequence.
**Mesh mode** (``mesh=`` a ``MeshSpec`` with devices > 1): the arbiter
grants *device slices* — disjoint sets of whole devices — instead of
fractions of one chip.  Demand still drives the split, but grants are
integers (largest-remainder rounding, every tenant floored at one whole
device), ``budget_for`` returns the FULL per-device budget (a granted
device is not shared), and ``mesh_for``/``device_slice`` expose the
per-tenant sub-mesh the server plans and executes against
(``core.plan.plan_network(mesh=...)``).  Admission rejects more tenants
than devices — a tenant cannot hold less than one chip.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from repro.core.resources import MeshSpec, ResourceBudget
from repro.core.shard import degree_ladder
from repro.obs.trace import NOOP_SPAN, TRACER, log_event

POLICIES = ("demand", "static")


@dataclasses.dataclass(frozen=True)
class TenantShare:
    """One tenant's slice of the device at one arbitration round."""

    name: str
    demand: float       # EWMA of submitted work (est-cycles)
    floor: float        # minimal feasible fraction (ladder included)
    fraction: float     # granted fraction of the device budget
    devices: int = 0    # mesh mode: whole devices granted (0 = no mesh)


class BudgetArbiter:
    """Splits one device budget across tenants; see module docstring.

    ``policy="demand"`` is the headline arbitration;
    ``policy="static"`` grants an even 1/n split regardless of demand
    or floors — the baseline the benchmarks compare against.
    """

    def __init__(self, budget: Optional[ResourceBudget] = None, *,
                 policy: str = "demand", rebalance_threshold: float = 0.05,
                 demand_alpha: float = 0.5, calibration=None,
                 mesh: Optional[MeshSpec] = None,
                 slo_pressure: float = 0.0, miss_alpha: float = 0.5,
                 grant_quantum: float = 0.0):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; have {POLICIES}")
        if not 0.0 < demand_alpha <= 1.0:
            raise ValueError("demand_alpha must be in (0, 1]")
        if slo_pressure < 0.0:
            raise ValueError("slo_pressure must be >= 0")
        if not 0.0 < miss_alpha <= 1.0:
            raise ValueError("miss_alpha must be in (0, 1]")
        if not 0.0 <= grant_quantum < 1.0:
            raise ValueError("grant_quantum must be in [0, 1)")
        self.budget = budget or ResourceBudget()
        self.policy = policy
        # Mesh mode: grants are whole-device slices of this mesh; None
        # (or one device) keeps the fractional single-chip behavior.
        self.mesh = mesh if (mesh is not None and mesh.devices > 1) else None
        self._devices: Dict[str, int] = {}
        # The unit the demand EWMA is denominated in: with a fitted
        # CalibrationTable the server prices each tenant's unit cost in
        # *calibrated* cycles, so grants track measured work, not the
        # analytical estimate.  Kept here so ``calibration_key`` in
        # telemetry names the model the grants were computed under.
        self.calibration = calibration
        self.rebalance_threshold = rebalance_threshold
        self.demand_alpha = demand_alpha
        # SLO pressure: a tenant's demand weight is multiplied by
        # (1 + slo_pressure * deadline-miss-rate EWMA), so grants chase
        # *deadlines missed*, not just work submitted (0.0 = off — the
        # pre-SLO demand arbiter, and what plain AdaptiveServer uses).
        self.slo_pressure = slo_pressure
        self.miss_alpha = miss_alpha
        # Grant quantization: targets snap DOWN to multiples of
        # ``grant_quantum`` (never below a tenant's floor), so grants —
        # and therefore the ``ResourceBudget`` slices the server plans
        # under — take at most 1/quantum distinct values per tenant
        # instead of a fresh float per EWMA fold.  That bounds the plan
        # cache's key cardinality: steady-state traffic re-plans into
        # cache hits rather than minting a new budget key (and a new
        # compile) every rebalance.  0.0 = off (exact targets).
        self.grant_quantum = grant_quantum
        self._floors: Dict[str, float] = {}
        self._demand: Dict[str, float] = {}
        self._pending: Dict[str, float] = {}
        self._granted: Dict[str, float] = {}
        self._miss_rate: Dict[str, float] = {}
        self.rebalances = 0
        self.preemptions = 0

    def register(self, name: str, floor: float = 0.0) -> None:
        """Admit one tenant.  Validates the whole tenant set *before*
        mutating any state, so a rejected registration leaves no ghost
        entry behind."""
        if name in self._floors:
            raise ValueError(f"tenant {name!r} already registered")
        if self.mesh is not None and len(self._floors) >= self.mesh.devices:
            raise ValueError(
                f"mesh has {self.mesh.devices} devices and every tenant "
                f"holds at least one whole device; cannot admit "
                f"{name!r} as tenant #{len(self._floors) + 1}")
        floor = min(max(float(floor), 0.0), 1.0)
        floors = {**self._floors, name: floor}
        if self.policy == "demand":
            total = sum(floors.values())
            if total > 1.0 + 1e-9:
                raise ValueError(
                    f"tenant floors jointly need {total:.3f}x the device "
                    f"budget — co-residency infeasible even at the "
                    f"narrowest ladder rungs: {floors}")
        else:
            # static grants an unconditional 1/n: a tenant whose floor
            # exceeds that can never serve — reject at admission, same
            # honesty as the demand-policy joint check.
            even = 1.0 / len(floors)
            bad = {m: f for m, f in floors.items() if f > even + 1e-9}
            if bad:
                raise ValueError(
                    f"static even split grants {even:.3f} per tenant, "
                    f"below the minimal feasible fraction of: {bad}")
        self._floors[name] = floor
        self._demand[name] = 0.0
        self._pending[name] = 0.0
        self._miss_rate[name] = 0.0

    def observe(self, name: str, cost: float) -> None:
        """Record submitted work (est-cycles) for one tenant; folded
        into the demand EWMA at the next ``split()``."""
        self._pending[name] += float(cost)

    def record_outcome(self, name: str, *, served: int, missed: int) -> None:
        """Fold one dispatch round's deadline outcomes into the
        tenant's miss-rate EWMA (``missed`` counts late completions AND
        shed requests; ``served`` counts everything that left the queue
        this round).  With ``slo_pressure > 0`` the EWMA multiplies the
        tenant's demand weight at the next ``split()`` — deadline
        misses, not just submitted work, set the grants."""
        if name not in self._floors:
            raise KeyError(f"tenant {name!r} is not registered")
        rate = min(max(float(missed) / max(served, 1), 0.0), 1.0)
        a = self.miss_alpha
        self._miss_rate[name] = (1 - a) * self._miss_rate[name] + a * rate

    def miss_rate(self, name: str) -> float:
        """The tenant's current deadline-miss-rate EWMA."""
        return self._miss_rate.get(name, 0.0)

    def _targets(self) -> Dict[str, float]:
        names = list(self._floors)
        n = len(names)
        if self.policy == "static":
            return {m: 1.0 / n for m in names}
        total_floor = sum(self._floors.values())
        weight = {m: self._demand[m]
                  * (1.0 + self.slo_pressure * self._miss_rate[m])
                  for m in names}
        total_weight = sum(weight.values())
        if total_weight <= 0.0:
            raw = {m: 1.0 / n for m in names}
        else:
            raw = {m: weight[m] / total_weight for m in names}
        surplus = max(0.0, 1.0 - total_floor)
        targets = {m: self._floors[m] + surplus * raw[m] for m in names}
        return self._quantize(targets)

    def _quantize(self, targets: Dict[str, float]) -> Dict[str, float]:
        """Snap each target down to the ``grant_quantum`` grid, floored
        at the tenant's minimal feasible fraction.  Rounding down keeps
        the sum feasible (never exceeds the un-quantized total); a
        target that rounds below its floor lands ON the floor — itself
        a recurring, cache-friendly value."""
        q = self.grant_quantum
        if q <= 0.0:
            return targets
        return {m: max(self._floors[m], q * math.floor(t / q + 1e-9))
                for m, t in targets.items()}

    def split(self) -> Dict[str, TenantShare]:
        """Fold pending observations into the EWMA and (re)grant.

        The first call always grants; later calls move the grants only
        when some tenant's target drifted more than
        ``rebalance_threshold`` from its current grant (then every
        grant snaps to target, counted in ``rebalances``).  A change in
        the tenant *set* (a registration since the last round) always
        re-grants — hysteresis only ever holds a split that covers
        every current tenant.
        """
        if not self._floors:
            return {}
        with (TRACER.span("arbiter.split", "arbiter",
                          {"tenants": len(self._floors)})
              if TRACER.enabled else NOOP_SPAN):
            return self._split()

    def _split(self) -> Dict[str, TenantShare]:
        a = self.demand_alpha
        for name, pend in self._pending.items():
            self._demand[name] = (1 - a) * self._demand[name] + a * pend
            self._pending[name] = 0.0
        targets = self._targets()
        if set(self._granted) != set(targets):
            was_granted = bool(self._granted)
            self._granted = dict(targets)
            if was_granted:
                self.rebalances += 1
                log_event("arbiter.rebalance", cause="tenant_set",
                          tenants=len(targets), total=self.rebalances)
        elif any(abs(targets[m] - self._granted[m])
                 > self.rebalance_threshold for m in targets):
            self._granted = dict(targets)
            self.rebalances += 1
            log_event("arbiter.rebalance", cause="drift",
                      threshold=self.rebalance_threshold,
                      tenants=len(targets), total=self.rebalances)
        self._devices = self._device_grants(self._granted)
        return {m: TenantShare(name=m, demand=self._demand[m],
                               floor=self._floors[m],
                               fraction=self._granted[m],
                               devices=self._devices.get(m, 0))
                for m in self._floors}

    def _device_grants(self, granted: Dict[str, float],
                       devices: Optional[int] = None) -> Dict[str, int]:
        """Mesh mode: the fractional grants rounded to whole devices —
        every tenant floored at ONE device, the rest split by largest
        remainder (deterministic: remainder then name).  Empty when not
        in mesh mode.  ``devices=`` overrides the pool size (the
        device-loss path previews grants on the shrunk mesh)."""
        if self.mesh is None or not granted:
            return {}
        d = devices if devices is not None else self.mesh.devices
        names = list(granted)
        spare = d - len(names)
        raw = {m: max(granted[m] * d - 1.0, 0.0) for m in names}
        total = sum(raw.values())
        if total <= 0.0 or spare <= 0:
            ideal = {m: 0.0 for m in names}
        else:
            ideal = {m: raw[m] / total * spare for m in names}
        grant = {m: 1 + int(ideal[m]) for m in names}
        left = d - sum(grant.values())
        order = sorted(names, key=lambda m: (-(ideal[m] - int(ideal[m])), m))
        for m in order[:left]:
            grant[m] += 1
        return grant

    def preempt(self, winner: str, victim: str) -> float:
        """Immediate grant transfer: squeeze ``victim`` to its floor
        and hand the freed fraction to ``winner`` — what a priority
        tenant does to a queued lower-priority bucket *instead of*
        out-bidding it through the demand EWMA (which takes rounds of
        hysteresis to move).  Bypasses the rebalance threshold, counts
        as a rebalance, and logs an ``arbiter.preempt`` event.  Returns
        the fraction that moved (0.0 when the victim already sat at its
        floor).  Fractional mode only — mesh grants are whole devices
        and re-slice through ``split()``."""
        if self.mesh is not None:
            raise ValueError("preempt() is fractional-mode only; mesh "
                             "grants move through split()")
        for m in (winner, victim):
            if m not in self._granted:
                raise KeyError(f"tenant {m!r} has no grant yet "
                               f"(call split() first)")
        freed = max(0.0, self._granted[victim] - self._floors[victim])
        if freed <= 0.0:
            return 0.0
        self._granted[victim] = self._floors[victim]
        self._granted[winner] += freed
        self.rebalances += 1
        self.preemptions += 1
        log_event("arbiter.preempt", winner=winner, victim=victim,
                  moved=freed, total=self.preemptions)
        return freed

    # -- degraded mesh (device loss) -----------------------------------------
    def _ladder_snap(self, raw: Dict[str, int],
                     prior: Dict[str, int]) -> Dict[str, int]:
        """Snap each tenant's shrunk device grant DOWN its degree ladder
        (largest divisor of the pre-loss grant that fits) so every batch
        shape that sharded before still shards on the degraded slice —
        correctness first, utilization second (leftover devices idle).
        Grants that grew (or held) pass through unchanged."""
        out = {}
        for name, g in raw.items():
            p = prior.get(name, g)
            if 0 < g < p:
                g = degree_ladder(p, survivors=g)[0]
            out[name] = g
        return out

    def degraded_grants(self, losses: int = 1) -> Dict[str, int]:
        """Pure preview of the whole-device grants after losing
        ``losses`` devices — what spare-plan pre-warming
        (``AdaptiveServer.prewarm_spares``) plans against *before* any
        fault fires.  No state moves."""
        if self.mesh is None:
            raise ValueError("degraded_grants() is mesh-mode only")
        survivors = self.mesh.devices - int(losses)
        if survivors < len(self._floors):
            raise ValueError(
                f"losing {losses} device(s) leaves {survivors} for "
                f"{len(self._floors)} tenants — every tenant holds at "
                f"least one whole device")
        raw = self._device_grants(self._granted, devices=survivors)
        return self._ladder_snap(raw, self._devices or raw)

    def on_device_loss(self, device: Optional[int] = None) -> list:
        """Shrink the mesh by one device and re-grant whole-device
        slices on the survivors — device loss handled as a budget shock.

        The pool size comes from ``fault_tolerance.choose_mesh_shape``
        (correctness-first: the usable pool is the best grid the
        survivors can still form against the pre-loss mesh) and each
        shrunk tenant descends its ``degree_ladder`` (largest divisor of
        its pre-loss grant), so surviving batch shapes keep sharding.
        Raises when fewer devices than tenants survive — degradation
        cannot evict.  Returns the tenants whose grant moved (the ones
        the server re-plans); logs ``mesh.degraded``."""
        if self.mesh is None:
            raise ValueError("on_device_loss() is mesh-mode only")
        survivors = self.mesh.devices - 1
        if survivors < len(self._floors):
            raise ValueError(
                f"degraded mesh has {survivors} device(s) for "
                f"{len(self._floors)} tenants — every tenant holds at "
                f"least one whole device; recover instead of degrading")
        from repro.runtime.fault_tolerance import choose_mesh_shape
        data, model = choose_mesh_shape(survivors,
                                        prefer_model=self.mesh.devices)
        usable = max(data * model, len(self._floors))
        before = dict(self._devices)
        self.mesh = dataclasses.replace(self.mesh, devices=usable)
        raw = self._device_grants(self._granted, devices=usable)
        self._devices = self._ladder_snap(raw, before or raw)
        self.rebalances += 1
        affected = sorted(m for m in self._floors
                          if self._devices.get(m) != before.get(m))
        log_event("mesh.degraded",
                  lost=-1 if device is None else int(device),
                  devices=usable, affected=len(affected),
                  total=self.rebalances)
        return affected

    def shares(self) -> Dict[str, TenantShare]:
        """The current grants as ``TenantShare`` rows without folding
        pending observations (what ``split()`` already decided, plus
        any ``preempt()`` moves since)."""
        return {m: TenantShare(name=m, demand=self._demand[m],
                               floor=self._floors[m],
                               fraction=self._granted.get(m, 0.0),
                               devices=self._devices.get(m, 0))
                for m in self._floors}

    # -- persistence (plan-preserving restart) ------------------------------
    def state_dict(self) -> dict:
        """JSON-able snapshot of the arbitration state a restart must
        preserve: floors, demand/miss EWMAs, un-folded observations,
        and the current grants.  Restoring this (``load_state``) keeps
        post-restart budget slices bit-identical to pre-crash, so every
        tenant's first batch re-plans under the *same* slice and hits
        the imported plan cache."""
        return {
            "floors": dict(self._floors),
            "demand": dict(self._demand),
            "pending": dict(self._pending),
            "granted": dict(self._granted),
            "miss_rate": dict(self._miss_rate),
            "rebalances": self.rebalances,
            "preemptions": self.preemptions,
        }

    def load_state(self, state: dict) -> None:
        """Restore a ``state_dict`` snapshot.  Every snapshotted tenant
        must already be registered (registration re-derives the floor
        from the plan, which must match the snapshot — a drifted floor
        means the checkpoint belongs to a different deployment)."""
        missing = set(state["floors"]) - set(self._floors)
        if missing:
            raise ValueError(f"snapshot covers unregistered tenants: "
                             f"{sorted(missing)}")
        for name, floor in state["floors"].items():
            if abs(self._floors[name] - floor) > 1e-9:
                raise ValueError(
                    f"tenant {name!r} floor drifted: snapshot "
                    f"{floor:.6f} vs registered {self._floors[name]:.6f}")
        self._demand.update(state["demand"])
        self._pending.update(state["pending"])
        self._granted.update(state["granted"])
        self._devices = self._device_grants(self._granted)
        self._miss_rate.update(state.get("miss_rate", {}))
        self.rebalances = int(state.get("rebalances", self.rebalances))
        self.preemptions = int(state.get("preemptions", self.preemptions))

    def budget_for(self, name: str) -> ResourceBudget:
        """The budget slice currently granted to ``name``.  Mesh mode
        grants whole devices, so every tenant plans against the FULL
        per-device budget; its parallelism comes from ``mesh_for``."""
        if name not in self._granted:
            raise KeyError(f"tenant {name!r} has no grant yet "
                           f"(call split() first)")
        if self.mesh is not None:
            return self.budget
        return self.budget.scaled(self._granted[name])

    def devices_for(self, name: str) -> int:
        """Mesh mode: whole devices currently granted to ``name``."""
        if self.mesh is None:
            raise ValueError("arbiter is not in mesh mode")
        if name not in self._devices:
            raise KeyError(f"tenant {name!r} has no device grant yet "
                           f"(call split() first)")
        return self._devices[name]

    def mesh_for(self, name: str) -> MeshSpec:
        """The per-tenant sub-mesh: same axis and link bandwidth as the
        arbiter's mesh, sized to the tenant's device grant — what the
        server hands to ``plan_network(mesh=...)``."""
        return dataclasses.replace(self.mesh,
                                   devices=self.devices_for(name))

    def device_slice(self, name: str) -> Tuple[int, int]:
        """The contiguous [start, stop) device-index range granted to
        ``name`` (registration order) — what execution builds its
        ``jax.sharding.Mesh`` over."""
        n = self.devices_for(name)
        start = 0
        for m in self._floors:
            if m == name:
                return (start, start + n)
            start += self._devices[m]
        raise KeyError(name)  # pragma: no cover — devices_for gates
