"""Per-tenant serving telemetry.

Latency is measured in *estimated cycles* — the same cost model the
planner optimizes (``Footprint.est_cycles``), so arbitration policies
are comparable without wall-clock noise from the interpret-mode CPU
substrate.  Precision mix counts planned-site executions per operand
width (how often the tenant actually served lowered), and the plan-cache
columns are windowed deltas of ``core.plan.plan_cache_stats``.

Sharding columns: ``shard_degree_mix`` counts planned-site executions
per shard degree (degree 1 = replicated), ``shard_degree`` is the
widest degree the tenant has served, and ``comm_cycles_share`` is the
fraction of the tenant's total estimated cycles spent in collectives —
how much of a mesh tenant's bill is traffic, not compute.

SLO columns (populated by ``runtime/scheduler.py``; zero under the
plain synchronous server) keep the **dual-clock rule**: latency
percentiles stay in modeled est-cycles (``p50_cycles``/``p95_cycles``)
while deadline outcomes are judged on the monotonic wall clock — so the
snapshot carries BOTH clocks: ``wall_p50_s``/``wall_p95_s`` are
measured wall-clock latencies of SLO-tracked requests, and
``deadline_miss_rate`` = (late completions + shed) / SLO-tracked
requests.  ``shed`` counts requests dropped as already-hopeless,
``preemptions`` counts dispatches where this tenant's priority jumped a
queued lower-priority bucket.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List

from repro.obs.metrics import percentile

# Percentiles are computed over the most recent window rather than the
# full request history, so a long-lived server's memory stays bounded
# (the same treatment the plan cache gets in core/plan.py).
LATENCY_WINDOW = 4096


@dataclasses.dataclass
class TenantTelemetry:
    """Counters one ``AdaptiveServer`` keeps per registered tenant."""

    name: str
    max_batch: int
    requests: int = 0
    batches: int = 0
    occupancy_sum: float = 0.0
    latencies: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW))
    precision_mix: Dict[int, int] = dataclasses.field(default_factory=dict)
    shard_degree_mix: Dict[int, int] = dataclasses.field(
        default_factory=dict)
    comm_cycles_sum: float = 0.0
    est_cycles_sum: float = 0.0
    replans: int = 0            # grant moves that forced a re-plan
    plan_cache_hits: int = 0
    plan_cache_misses: int = 0
    max_quant_rel_err: float = 0.0
    # SLO accounting (dual clock: deadlines are wall-clock; the
    # percentile columns above stay est-cycles)
    slo_tracked: int = 0        # requests submitted under an SLOSpec
    deadline_misses: int = 0    # late completions + shed
    shed: int = 0               # dropped as already-hopeless
    preemptions: int = 0        # priority dispatches past a queued bucket
    wall_latencies: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW))
    # Fault-survival accounting (runtime/guards.py, runtime/faults.py):
    # requests the guard failed outright vs shed as deadline-hopeless,
    # retries spent absorbing transient faults, and how often this
    # tenant's device grant shrank through the degraded-mesh path.
    guard_rejected: int = 0
    guard_shed: int = 0
    guard_retries: int = 0
    degradations: int = 0

    def record_batch(self, batch_size: int, latencies: List[float],
                     plan, *, cache_hits: int, cache_misses: int,
                     quant_err: float = 0.0) -> None:
        self.requests += batch_size
        self.batches += 1
        self.occupancy_sum += batch_size / self.max_batch
        self.latencies.extend(latencies)
        for site in plan.sites:
            bits = site.precision_bits
            self.precision_mix[bits] = self.precision_mix.get(bits, 0) + 1
            deg = getattr(site, "shard_degree", 1)
            self.shard_degree_mix[deg] = (
                self.shard_degree_mix.get(deg, 0) + 1)
            self.comm_cycles_sum += site.footprint.comm_cycles
            self.est_cycles_sum += site.footprint.est_cycles
        self.plan_cache_hits += cache_hits
        self.plan_cache_misses += cache_misses
        self.max_quant_rel_err = max(self.max_quant_rel_err, quant_err)

    @property
    def batch_occupancy(self) -> float:
        """Mean fill of executed batches, in [1/max_batch, 1]."""
        return self.occupancy_sum / self.batches if self.batches else 0.0

    @property
    def lowered_fraction(self) -> float:
        """Fraction of planned-site executions that ran below 32 bits."""
        total = sum(self.precision_mix.values())
        low = sum(n for b, n in self.precision_mix.items() if b < 32)
        return low / total if total else 0.0

    @property
    def shard_degree(self) -> int:
        """Widest shard degree this tenant has served (1 = replicated)."""
        return max(self.shard_degree_mix, default=1)

    @property
    def comm_cycles_share(self) -> float:
        """Collective cycles / total estimated cycles served."""
        return (self.comm_cycles_sum / self.est_cycles_sum
                if self.est_cycles_sum else 0.0)

    def record_slo_batch(self, wall_latencies: List[float],
                         missed: int) -> None:
        """One SLO-tracked batch's wall-clock outcomes: per-request
        measured wall latency (seconds) and how many of them finished
        past their deadline."""
        self.slo_tracked += len(wall_latencies)
        self.wall_latencies.extend(wall_latencies)
        self.deadline_misses += missed

    def record_shed(self, n: int = 1) -> None:
        """``n`` requests dropped as already-hopeless; every shed is a
        deadline miss too."""
        self.shed += n
        self.slo_tracked += n
        self.deadline_misses += n

    @property
    def deadline_miss_rate(self) -> float:
        """(late completions + shed) / SLO-tracked requests."""
        return (self.deadline_misses / self.slo_tracked
                if self.slo_tracked else 0.0)

    def wall_percentile(self, q: float) -> float:
        """q-th percentile of measured wall-clock latency (seconds) of
        SLO-tracked requests — the second clock of the dual-clock rule
        (``latency_percentile`` is the est-cycles one)."""
        return percentile(self.wall_latencies, q)

    def latency_percentile(self, q: float) -> float:
        """q-th percentile (0..100) of request latency in est-cycles,
        over the most recent ``LATENCY_WINDOW`` requests.  Delegates to
        the shared estimator (``repro.obs.metrics.percentile``) so the
        metrics exposition and this snapshot can never disagree."""
        return percentile(self.latencies, q)

    def snapshot(self) -> dict:
        cache_lookups = self.plan_cache_hits + self.plan_cache_misses
        return {
            "name": self.name,
            "requests": self.requests,
            "batches": self.batches,
            "batch_occupancy": self.batch_occupancy,
            "p50_cycles": self.latency_percentile(50),
            "p95_cycles": self.latency_percentile(95),
            "precision_mix": dict(sorted(self.precision_mix.items())),
            "lowered_fraction": self.lowered_fraction,
            "shard_degree": self.shard_degree,
            "shard_degree_mix": dict(sorted(
                self.shard_degree_mix.items())),
            "comm_cycles_share": self.comm_cycles_share,
            # dual-clock SLO columns: *_cycles above are the modeled
            # est-cycles clock; wall_* here are the monotonic wall clock
            "slo_tracked": self.slo_tracked,
            "deadline_misses": self.deadline_misses,
            "deadline_miss_rate": self.deadline_miss_rate,
            "shed": self.shed,
            "preemptions": self.preemptions,
            "wall_p50_s": self.wall_percentile(50),
            "wall_p95_s": self.wall_percentile(95),
            # fault-survival columns (zero in a fault-free life)
            "guard_rejected": self.guard_rejected,
            "guard_shed": self.guard_shed,
            "guard_retries": self.guard_retries,
            "degradations": self.degradations,
            "replans": self.replans,
            "plan_cache_hits": self.plan_cache_hits,
            "plan_cache_misses": self.plan_cache_misses,
            "plan_cache_hit_rate": (self.plan_cache_hits / cache_lookups
                                    if cache_lookups else 0.0),
            "max_quant_rel_err": self.max_quant_rel_err,
        }
