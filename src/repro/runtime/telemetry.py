"""Per-tenant serving telemetry.

Latency is measured in *estimated cycles* — the same cost model the
planner optimizes (``Footprint.est_cycles``), so arbitration policies
are comparable without wall-clock noise from the interpret-mode CPU
substrate.  Precision mix counts planned-site executions per operand
width (how often the tenant actually served lowered), and the plan-cache
columns are windowed deltas of ``core.plan.plan_cache_stats``.

Sharding columns: ``shard_degree_mix`` counts planned-site executions
per shard degree (degree 1 = replicated), ``shard_degree`` is the
widest degree the tenant has served, and ``comm_cycles_share`` is the
fraction of the tenant's total estimated cycles spent in collectives —
how much of a mesh tenant's bill is traffic, not compute.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List

from repro.obs.metrics import percentile

# Percentiles are computed over the most recent window rather than the
# full request history, so a long-lived server's memory stays bounded
# (the same treatment the plan cache gets in core/plan.py).
LATENCY_WINDOW = 4096


@dataclasses.dataclass
class TenantTelemetry:
    """Counters one ``AdaptiveServer`` keeps per registered tenant."""

    name: str
    max_batch: int
    requests: int = 0
    batches: int = 0
    occupancy_sum: float = 0.0
    latencies: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW))
    precision_mix: Dict[int, int] = dataclasses.field(default_factory=dict)
    shard_degree_mix: Dict[int, int] = dataclasses.field(
        default_factory=dict)
    comm_cycles_sum: float = 0.0
    est_cycles_sum: float = 0.0
    replans: int = 0            # grant moves that forced a re-plan
    plan_cache_hits: int = 0
    plan_cache_misses: int = 0
    max_quant_rel_err: float = 0.0

    def record_batch(self, batch_size: int, latencies: List[float],
                     plan, *, cache_hits: int, cache_misses: int,
                     quant_err: float = 0.0) -> None:
        self.requests += batch_size
        self.batches += 1
        self.occupancy_sum += batch_size / self.max_batch
        self.latencies.extend(latencies)
        for site in plan.sites:
            bits = site.precision_bits
            self.precision_mix[bits] = self.precision_mix.get(bits, 0) + 1
            deg = getattr(site, "shard_degree", 1)
            self.shard_degree_mix[deg] = (
                self.shard_degree_mix.get(deg, 0) + 1)
            self.comm_cycles_sum += site.footprint.comm_cycles
            self.est_cycles_sum += site.footprint.est_cycles
        self.plan_cache_hits += cache_hits
        self.plan_cache_misses += cache_misses
        self.max_quant_rel_err = max(self.max_quant_rel_err, quant_err)

    @property
    def batch_occupancy(self) -> float:
        """Mean fill of executed batches, in [1/max_batch, 1]."""
        return self.occupancy_sum / self.batches if self.batches else 0.0

    @property
    def lowered_fraction(self) -> float:
        """Fraction of planned-site executions that ran below 32 bits."""
        total = sum(self.precision_mix.values())
        low = sum(n for b, n in self.precision_mix.items() if b < 32)
        return low / total if total else 0.0

    @property
    def shard_degree(self) -> int:
        """Widest shard degree this tenant has served (1 = replicated)."""
        return max(self.shard_degree_mix, default=1)

    @property
    def comm_cycles_share(self) -> float:
        """Collective cycles / total estimated cycles served."""
        return (self.comm_cycles_sum / self.est_cycles_sum
                if self.est_cycles_sum else 0.0)

    def latency_percentile(self, q: float) -> float:
        """q-th percentile (0..100) of request latency in est-cycles,
        over the most recent ``LATENCY_WINDOW`` requests.  Delegates to
        the shared estimator (``repro.obs.metrics.percentile``) so the
        metrics exposition and this snapshot can never disagree."""
        return percentile(self.latencies, q)

    def snapshot(self) -> dict:
        cache_lookups = self.plan_cache_hits + self.plan_cache_misses
        return {
            "name": self.name,
            "requests": self.requests,
            "batches": self.batches,
            "batch_occupancy": self.batch_occupancy,
            "p50_cycles": self.latency_percentile(50),
            "p95_cycles": self.latency_percentile(95),
            "precision_mix": dict(sorted(self.precision_mix.items())),
            "lowered_fraction": self.lowered_fraction,
            "shard_degree": self.shard_degree,
            "shard_degree_mix": dict(sorted(
                self.shard_degree_mix.items())),
            "comm_cycles_share": self.comm_cycles_share,
            "replans": self.replans,
            "plan_cache_hits": self.plan_cache_hits,
            "plan_cache_misses": self.plan_cache_misses,
            "plan_cache_hit_rate": (self.plan_cache_hits / cache_lookups
                                    if cache_lookups else 0.0),
            "max_quant_rel_err": self.max_quant_rel_err,
        }
