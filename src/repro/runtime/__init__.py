"""Serving + reliability runtime.

``server.py``/``arbiter.py``/``batching.py``/``telemetry.py`` form the
adaptive-IP serving subsystem — multi-tenant budget arbitration,
shape-bucketed batching, live re-planning (docs/adaptive_ips.md,
"Serving runtime contract").  ``fault_tolerance.py`` holds the
watchdog / straggler / elastic-remesh hooks.
"""
from repro.runtime.arbiter import BudgetArbiter, TenantShare
from repro.runtime.batching import Request, ShapeBucketQueue
from repro.runtime.server import AdaptiveServer, Completion, Tenant
from repro.runtime.telemetry import TenantTelemetry

__all__ = [
    "AdaptiveServer", "BudgetArbiter", "Completion", "Request",
    "ShapeBucketQueue", "Tenant", "TenantShare", "TenantTelemetry",
]
