"""Serving + reliability runtime.

``server.py``/``arbiter.py``/``batching.py``/``telemetry.py`` form the
adaptive-IP serving subsystem — multi-tenant budget arbitration,
shape-bucketed batching, live re-planning (docs/adaptive_ips.md,
"Serving runtime contract").  ``scheduler.py`` adds the SLO-aware
continuous-batching dispatch loop and ``recovery.py`` the
plan-preserving restart path on top of ``fault_tolerance.py``'s
watchdog / straggler / elastic-remesh hooks (docs/adaptive_ips.md,
"Scheduling & recovery contract").  ``faults.py`` (deterministic fault
injection) and ``guards.py`` (output screening + bounded deadline-aware
retry + degraded-mesh survival) are the chaos half
(docs/adaptive_ips.md, "Fault-injection & degradation contract").
"""
from repro.runtime.arbiter import BudgetArbiter, TenantShare
from repro.runtime.batching import Request, ShapeBucketQueue
from repro.runtime.faults import (FAULT_KINDS, INJECTOR, DeviceLost,
                                  FaultInjector, FaultSpec, InjectedFault)
from repro.runtime.guards import (GuardPolicy, GuardReport, GuardViolation,
                                  backoff_schedule, execute_guarded,
                                  screen_finite)
from repro.runtime.recovery import (RecoveryManager, recover_server,
                                    simulate_worker_death, snapshot_server)
from repro.runtime.scheduler import SLOScheduler, SLOSpec
from repro.runtime.server import AdaptiveServer, Completion, Tenant
from repro.runtime.telemetry import TenantTelemetry

__all__ = [
    "AdaptiveServer", "BudgetArbiter", "Completion", "DeviceLost",
    "FAULT_KINDS", "FaultInjector", "FaultSpec", "GuardPolicy",
    "GuardReport", "GuardViolation", "INJECTOR", "InjectedFault",
    "RecoveryManager", "Request", "SLOScheduler", "SLOSpec",
    "ShapeBucketQueue", "Tenant", "TenantShare", "TenantTelemetry",
    "backoff_schedule", "execute_guarded", "recover_server",
    "screen_finite", "simulate_worker_death", "snapshot_server",
]
