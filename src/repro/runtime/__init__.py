"""Serving + reliability runtime.

``server.py``/``arbiter.py``/``batching.py``/``telemetry.py`` form the
adaptive-IP serving subsystem — multi-tenant budget arbitration,
shape-bucketed batching, live re-planning (docs/adaptive_ips.md,
"Serving runtime contract").  ``scheduler.py`` adds the SLO-aware
continuous-batching dispatch loop and ``recovery.py`` the
plan-preserving restart path on top of ``fault_tolerance.py``'s
watchdog / straggler / elastic-remesh hooks (docs/adaptive_ips.md,
"Scheduling & recovery contract").
"""
from repro.runtime.arbiter import BudgetArbiter, TenantShare
from repro.runtime.batching import Request, ShapeBucketQueue
from repro.runtime.recovery import (RecoveryManager, recover_server,
                                    simulate_worker_death, snapshot_server)
from repro.runtime.scheduler import SLOScheduler, SLOSpec
from repro.runtime.server import AdaptiveServer, Completion, Tenant
from repro.runtime.telemetry import TenantTelemetry

__all__ = [
    "AdaptiveServer", "BudgetArbiter", "Completion", "RecoveryManager",
    "Request", "SLOScheduler", "SLOSpec", "ShapeBucketQueue", "Tenant",
    "TenantShare", "TenantTelemetry", "recover_server",
    "simulate_worker_death", "snapshot_server",
]
