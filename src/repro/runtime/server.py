"""AdaptiveServer — multi-tenant serving over the adaptive-IP planner.

The paper's claim is that IPs adapt to the resources *actually
available*; offline that meant one ``plan_network`` call against one
static budget.  This server makes the claim dynamic: several registered
CNN frontends ("tenants") share one device ``ResourceBudget``, a
``BudgetArbiter`` splits it proportional to observed demand (floored at
each tenant's minimal feasible fraction, ladder rungs included), and
when the split shifts the affected tenants are *live re-planned*
through ``core.plan.replan`` — a tenant squeezed below its f32
footprint degrades to int16/int8 execution instead of failing.

Time model: latency is accounted in **estimated cycles**, the same cost
model the planner optimizes.  With ``calibration=`` (a fitted
``core.calibrate_cost.CalibrationTable``) both sides upgrade together:
plans are ranked by measured scale factors and the lane clock advances
by the same calibrated cycles, so grants, telemetry and the planner all
optimize the objective that was actually measured.  Each tenant owns a serving lane (its
spatial slice of the device, the FPGA-region analogy): batches of a
lane execute sequentially, a batch occupies the lane for its plan's
``total_cycles``, and a request's latency is queue wait plus service.
Numerics are real — every batch runs its planned Pallas kernels — only
*time* is modeled, which keeps policies comparable without wall-clock
noise from the interpret-mode substrate.

Requests are shape-bucketed (``batching.py``): same-shaped samples of a
tenant stack into one planned execution, so repeat batch shapes hit the
plan cache with zero selector work.  With ``autotune=True`` the tunable
sites of each executed plan run sweep-chosen tilings
(``core.autotune.plan_tile_overrides``) instead of member defaults.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core.plan import (STATS, network_min_fraction, plan_network,
                             replan)
from repro.core.resources import MeshSpec, ResourceBudget
from repro.models.frontends import apply_cnn_frontend, cnn_frontend_site_specs
from repro.obs.trace import NOOP_SPAN, TRACER, log_event
from repro.runtime.arbiter import BudgetArbiter, TenantShare
from repro.runtime.batching import Request, ShapeBucketQueue
from repro.runtime.faults import INJECTOR, InjectedFault
from repro.runtime.guards import GuardPolicy, execute_guarded
from repro.runtime.telemetry import TenantTelemetry

_SIDE_CACHE_MAX = 256   # bound for the tile- and specs-caches


@dataclasses.dataclass
class Tenant:
    """One registered CNN frontend and its serving state."""

    name: str
    params: Any
    input_shape: Tuple[int, ...]        # per-sample (H, W, C)
    pool_window: Tuple[int, int]
    activation: str
    ladder: Tuple[int, ...]
    measure_quant: bool
    floor: float                        # min feasible device fraction
    unit_cost: float                    # est-cycles of one request, ample
    granted: float = 0.0                # current device fraction
    lane_free: float = 0.0              # when this lane next idles (cycles)
    telemetry: TenantTelemetry = None


@dataclasses.dataclass(frozen=True)
class Completion:
    """One served request: result + accounting.  ``ok=False`` means the
    execution guard gave the batch up (rejected or shed) — ``result`` is
    None and the lane did not advance."""

    rid: int
    tenant: str
    result: Any                         # (S, d_model) patch embeddings
    arrival: float
    finished: float
    batch_size: int
    ok: bool = True

    @property
    def latency(self) -> float:
        return self.finished - self.arrival


class AdaptiveServer:
    """Admit, batch, arbitrate, re-plan, execute.  See module docstring.

    ``policy="demand"`` arbitrates; ``policy="static"`` is the even-split
    baseline.  ``autotune=True`` swaps member-default tilings for
    sweep-chosen ones on the tunable sites of every executed plan.
    """

    def __init__(self, budget: Optional[ResourceBudget] = None, *,
                 policy: str = "demand", rebalance_threshold: float = 0.05,
                 max_batch: int = 4, autotune: bool = False,
                 interpret: bool = True, demand_alpha: float = 0.5,
                 fuse: bool = True, calibration=None,
                 mesh: Optional[MeshSpec] = None,
                 slo_pressure: float = 0.0, miss_alpha: float = 0.5,
                 grant_quantum: float = 0.0):
        self.budget = budget or ResourceBudget()
        # fuse (default True): serve every tenant through fusion-aware
        # plans — a block the planner can fuse runs conv->pool->act as
        # ONE launch, falling back per block when the fused footprint
        # won't fit the tenant's slice.  fuse=False opts out.
        self.fuse = fuse
        # calibration: a fitted CalibrationTable prices every planning
        # decision, the demand weights, and the lane time model in
        # measured scale factors instead of the raw analytical cycles
        # (see core/calibrate_cost.py).  None keeps the analytical model.
        self.calibration = calibration
        # mesh: a MeshSpec with devices > 1 puts the arbiter in mesh
        # mode — tenants are granted whole-device slices and each batch
        # is planned with plan_network(mesh=<tenant sub-mesh>), so a
        # tenant holding several devices may serve *sharded* plans
        # (executed through shard_map when the layout is uniform; see
        # _execute).  None keeps the fractional single-chip server.
        # slo_pressure > 0 makes the arbiter chase deadline-miss EWMAs
        # on top of demand — only meaningful under the SLO scheduler
        # (``runtime/scheduler.py``), which feeds ``record_outcome``.
        self.arbiter = BudgetArbiter(self.budget, policy=policy,
                                     rebalance_threshold=rebalance_threshold,
                                     demand_alpha=demand_alpha,
                                     calibration=calibration, mesh=mesh,
                                     slo_pressure=slo_pressure,
                                     miss_alpha=miss_alpha,
                                     grant_quantum=grant_quantum)
        self.mesh = self.arbiter.mesh
        self.max_batch = max_batch
        self.autotune = autotune
        self.interpret = interpret
        self.clock = 0.0
        self.tenants: Dict[str, Tenant] = {}
        self._queue = ShapeBucketQueue()
        self._shares: Dict[str, TenantShare] = {}
        # opt-in per-tenant survival policies (runtime/guards.py); a
        # tenant without one executes bare — faults propagate
        self._guards: Dict[str, GuardPolicy] = {}
        self._tile_cache: Dict[tuple, dict] = {}
        # bucket key -> site specs: spec construction runs jax.eval_shape
        # per block, so hot repeat buckets must not rebuild them
        self._specs_cache: Dict[tuple, tuple] = {}
        self._next_rid = 0

    # -- admission ----------------------------------------------------------
    def register(self, name: str, params, input_shape, *,
                 pool_window=(2, 2), activation: str = "relu",
                 ladder: Tuple[int, ...] = (),
                 measure_quant: bool = False) -> Tenant:
        """Register a CNN frontend as a tenant.

        Prices the tenant up front: its *floor* (minimal feasible device
        fraction at max batch, ladder included — what the arbiter must
        always grant) and its *unit cost* (est-cycles of a one-sample
        plan under the full device, the demand weight).  Raises the
        planner's error when the tenant cannot run even with the whole
        device to itself — admission fails honestly.
        """
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        input_shape = tuple(int(d) for d in input_shape)
        canonical = self._specs(params, (self.max_batch,) + input_shape,
                                "float32", pool_window, activation, ladder)
        # Admission check: both the max-batch and the one-sample graphs
        # must plan under the full device (raises the planner's
        # canonical error otherwise) — and both plans warm the share
        # cache for the replan fast path.  The floor stays priced on the
        # unfused graph: fusion-aware planning always falls back to the
        # three-site chain, so the unfused minimum remains the sound
        # feasibility guarantee the arbiter must honor.
        plan_network(canonical, self.budget, fuse=self.fuse,
                     calibration=self.calibration)
        floor = network_min_fraction(canonical, self.budget)
        unit = plan_network(
            self._specs(params, (1,) + input_shape, "float32",
                        pool_window, activation, ladder),
            self.budget, fuse=self.fuse,
            calibration=self.calibration).calibrated_cycles(self.calibration)
        tenant = Tenant(name=name, params=params, input_shape=input_shape,
                        pool_window=tuple(pool_window), activation=activation,
                        ladder=tuple(ladder), measure_quant=measure_quant,
                        floor=floor, unit_cost=unit,
                        telemetry=TenantTelemetry(name=name,
                                                  max_batch=self.max_batch))
        self.arbiter.register(name, floor)
        self.tenants[name] = tenant
        return tenant

    def set_guard(self, name: str,
                  policy: Optional[GuardPolicy]) -> None:
        """Opt tenant ``name`` into guarded execution (output screening
        + bounded deadline-aware retry + degrade-on-device-loss; see
        ``runtime/guards.py``).  ``None`` clears the policy — the tenant
        executes bare again and faults propagate to the caller."""
        if name not in self.tenants:
            raise KeyError(f"tenant {name!r} is not registered")
        if policy is None:
            self._guards.pop(name, None)
        else:
            self._guards[name] = policy

    def guard_for(self, name: str) -> Optional[GuardPolicy]:
        return self._guards.get(name)

    @staticmethod
    def _specs(params, batch_shape, dtype, pool_window, activation, ladder):
        return tuple(cnn_frontend_site_specs(
            params, batch_shape, dtype, pool_window=tuple(pool_window),
            activation=activation, ladder=tuple(ladder)))

    def submit(self, name: str, x, *, at: Optional[float] = None):
        """Queue one sample (H, W, C) — or a (B, H, W, C) stack, queued
        as B independent requests — arriving at clock ``at`` (default:
        now).  Returns the request id (or list of ids)."""
        tenant = self.tenants[name]
        x = jnp.asarray(x)
        if x.ndim == len(tenant.input_shape) + 1:
            return [self.submit(name, xi, at=at) for xi in x]
        if x.shape != tenant.input_shape:
            raise ValueError(
                f"tenant {name!r} expects samples of shape "
                f"{tenant.input_shape}, got {x.shape}")
        arrival = self.clock if at is None else float(at)
        rid = self._next_rid
        self._next_rid += 1
        self._queue.push(Request(rid=rid, tenant=name, x=x, arrival=arrival))
        self.arbiter.observe(name, tenant.unit_cost)
        return rid

    # -- serving ------------------------------------------------------------
    def step(self) -> List[Completion]:
        """One serving round: arbitrate, then drain every bucket.

        Re-grants move tenant budget slices; a moved slice re-plans the
        tenant's graphs on their next batch (the ``replan`` fast path —
        counted in telemetry as a re-plan when the tenant had already
        been granted before).
        """
        if not self._queue:
            return []
        self._apply_shares(self.arbiter.split())
        completions: List[Completion] = []
        for key in self._queue.keys():
            while True:
                batch = self._queue.pop_batch(key, self.max_batch)
                if not batch:
                    break
                completions.extend(self._execute(batch))
        if completions:
            self.clock = max(self.clock,
                             max(c.finished for c in completions))
        return completions

    def _apply_shares(self, shares: Dict[str, TenantShare]) -> None:
        """Adopt one arbitration round's grants.  A moved grant changes
        the tenant's slice budget, which re-plans its graphs on the next
        batch — counted as a re-plan when the tenant had already been
        granted before.  Shared by ``step`` and the SLO scheduler
        (``runtime/scheduler.py``), so both loops account grant moves
        identically."""
        self._shares = shares
        for name, share in shares.items():
            t = self.tenants[name]
            if t.granted and abs(share.fraction - t.granted) > 1e-12:
                t.telemetry.replans += 1
            t.granted = share.fraction

    def drain(self, max_steps: int = 1000) -> List[Completion]:
        out: List[Completion] = []
        for _ in range(max_steps):
            if not self._queue:
                break
            out.extend(self.step())
        return out

    def _execute(self, batch: List[Request], *,
                 deadline_budget_s: Optional[float] = None
                 ) -> List[Completion]:
        # Tracing contract: the disabled path costs one attribute read
        # and one branch per span site — no argument dicts, no span
        # objects (NOOP_SPAN is the shared singleton).
        with (TRACER.span("serve.execute", "serving",
                          {"tenant": batch[0].tenant,
                           "batch": len(batch)})
              if TRACER.enabled else NOOP_SPAN):
            return self._execute_batch(batch,
                                       deadline_budget_s=deadline_budget_s)

    def _tenant_budget(self, tenant: Tenant):
        if self.mesh is not None:
            # mesh mode: the tenant holds whole devices — plan against
            # the FULL per-device budget and let the planner decide how
            # (whether) to shard across the granted sub-mesh.
            return (self.arbiter.budget_for(tenant.name),
                    self.arbiter.mesh_for(tenant.name))
        return self.budget.scaled(tenant.granted), None

    def _route_execute_faults(self, tenant: Tenant) -> None:
        """Injection seam "execute": apply the faults due at this batch
        — device loss marks the corpse, budget shrink scales the device
        budget, a kernel exception raises (last, so co-scheduled faults
        still land)."""
        boom = None
        for f in INJECTOR.poll("execute", tenant.name):
            if f.kind == "device_loss":
                INJECTOR.lose(int(f.param))
            elif f.kind == "budget_shrink":
                self.on_budget_shrink(f.param if f.param > 0 else 0.5)
            elif f.kind == "kernel_exception":
                boom = InjectedFault(
                    f"injected kernel-launch failure "
                    f"(tenant {tenant.name!r})")
        if boom is not None:
            raise boom

    def _attempt(self, tenant: Tenant, xb, *, retry_f32: bool = False):
        """One execution attempt: route injected faults, (re)plan under
        the tenant's *current* slice — a degraded mesh re-plans here —
        run the kernels, screen hooks applied by the caller.  Returns
        ``(y, plan, quant_err)``.  ``retry_f32=True`` plans with the
        precision ladder off (the guard's non-finite fallback)."""
        if INJECTOR.enabled:
            self._route_execute_faults(tenant)
        slice_budget, tenant_mesh = self._tenant_budget(tenant)
        ladder = () if retry_f32 else tenant.ladder
        skey = (tenant.name, xb.shape, str(xb.dtype), ladder)
        specs = self._specs_cache.get(skey)
        if specs is None:
            specs = self._specs(tenant.params, xb.shape, xb.dtype,
                                tenant.pool_window, tenant.activation,
                                ladder)
            if len(self._specs_cache) >= _SIDE_CACHE_MAX:
                self._specs_cache.pop(next(iter(self._specs_cache)))
            self._specs_cache[skey] = specs
        plan = replan(specs, slice_budget, fuse=self.fuse,
                      calibration=self.calibration, mesh=tenant_mesh)
        if INJECTOR.enabled and tenant_mesh is not None:
            INJECTOR.check_devices(*self.arbiter.device_slice(tenant.name))
        tile_overrides = None
        if self.autotune:
            tkey = (specs, slice_budget)
            tile_overrides = self._tile_cache.get(tkey)
            if tile_overrides is None:
                from repro.core.autotune import plan_tile_overrides
                tile_overrides = plan_tile_overrides(plan)
                if len(self._tile_cache) >= _SIDE_CACHE_MAX:
                    self._tile_cache.pop(next(iter(self._tile_cache)))
                self._tile_cache[tkey] = tile_overrides
        quant_report = {} if (ladder and tenant.measure_quant) else None
        sharded = self._shardable(plan, xb)
        with (TRACER.span("kernel", "kernel",
                          {"tenant": tenant.name,
                           "launches": plan.total_launches,
                           "sharded": sharded})
              if TRACER.enabled else NOOP_SPAN):
            if sharded:
                y = self._run_frontend_sharded(
                    tenant, xb, plan, tile_overrides=tile_overrides)
            else:
                y = apply_cnn_frontend(tenant.params, xb, network=plan,
                                       pool_window=tenant.pool_window,
                                       activation=tenant.activation,
                                       interpret=self.interpret,
                                       ladder=ladder,
                                       quant_report=quant_report,
                                       tile_overrides=tile_overrides,
                                       fuse=self.fuse)
        if INJECTOR.enabled:
            y = INJECTOR.perturb_output("output", y, tenant.name)
        quant_err = 0.0
        if quant_report:
            from repro.quant.report import max_rel_error
            quant_err = max_rel_error(quant_report)
        return y, plan, quant_err

    def _execute_batch(self, batch: List[Request], *,
                       deadline_budget_s: Optional[float] = None
                       ) -> List[Completion]:
        tenant = self.tenants[batch[0].tenant]
        xb = jnp.stack([r.x for r in batch])
        hits0, misses0 = STATS.plan_hits, STATS.plan_misses
        policy = self._guards.get(tenant.name)
        out: Dict[str, Any] = {}

        def attempt(retry_f32: bool = False):
            y, plan, qerr = self._attempt(tenant, xb, retry_f32=retry_f32)
            out["plan"], out["quant_err"] = plan, qerr
            return y

        if policy is None:
            y = attempt()
            report = None
        else:
            y, report = execute_guarded(
                attempt, policy, tenant=tenant.name,
                remaining_s=deadline_budget_s,
                on_device_loss=lambda e: self.on_device_loss(e.device))
            tenant.telemetry.guard_retries += report.retries
        if y is None:
            # the guard gave the batch up: failed completions, lane not
            # advanced, no record_batch (there is no plan bill to pay)
            if report.outcome == "shed":
                tenant.telemetry.guard_shed += len(batch)
            else:
                tenant.telemetry.guard_rejected += len(batch)
            start = max(tenant.lane_free, max(r.arrival for r in batch))
            return [Completion(rid=r.rid, tenant=r.tenant, result=None,
                               arrival=r.arrival, finished=start,
                               batch_size=len(batch), ok=False)
                    for r in batch]
        plan, quant_err = out["plan"], out["quant_err"]
        start = max(tenant.lane_free, max(r.arrival for r in batch))
        if TRACER.enabled:
            TRACER.instant(
                "batch.queue_wait", "serving",
                {"tenant": tenant.name,
                 "max_wait_cycles":
                     start - min(r.arrival for r in batch)})
        service = plan.calibrated_cycles(self.calibration)
        if INJECTOR.enabled:
            service = INJECTOR.scale_latency(service, tenant.name)
        finish = start + service
        tenant.lane_free = finish
        latencies = [finish - r.arrival for r in batch]
        tenant.telemetry.record_batch(
            len(batch), latencies, plan,
            cache_hits=STATS.plan_hits - hits0,
            cache_misses=STATS.plan_misses - misses0,
            quant_err=quant_err)
        return [Completion(rid=r.rid, tenant=r.tenant, result=y[i],
                           arrival=r.arrival, finished=finish,
                           batch_size=len(batch))
                for i, r in enumerate(batch)]

    @staticmethod
    def _shardable(plan, xb) -> bool:
        """True when the plan can run through the shard_map frontend
        path: a mesh plan whose sites are ALL batch-sharded at the mesh
        degree (a uniform layout needs no mid-chain relays inside the
        frontend walk), float precision, and a batch that tiles evenly.
        Mixed/chan/degree-1 layouts fall back to the replicated walk of
        the same plan — identical math, the mesh then only reshapes the
        time model."""
        if plan.mesh is None or plan.mesh.devices <= 1:
            return False
        d = plan.mesh.devices
        sharded = plan.sharded_sites()
        if len(sharded) != len(plan.sites):
            return False
        if any(s.shard_axis != "batch" or s.shard_degree != d
               or s.lowered for s in plan.sites):
            return False
        return xb.shape[0] % d == 0

    def _run_frontend_sharded(self, tenant: Tenant, xb, plan,
                              *, tile_overrides=None):
        """The whole frontend under one shard_map over the tenant's
        device slice: each device runs the per-device plan
        (``plan.device_plan()``) on its batch block; ``out_specs``
        re-tiles the result so the caller sees the replicated contract.
        Bit-identical to the replicated walk for batch sharding (tests
        assert it).  The ``jax.sharding.Mesh`` over the tenant's device
        slice comes from ``fault_tolerance.elastic_remesh`` — the same
        builder the degraded path re-meshes through after a device
        loss."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.runtime.fault_tolerance import elastic_remesh
        d = plan.mesh.devices
        start, _stop = self.arbiter.device_slice(tenant.name)
        mesh = elastic_remesh(d, axis=plan.mesh.axis, offset=start)
        dplan = plan.device_plan()

        def device_fn(xg):
            return apply_cnn_frontend(tenant.params, xg, network=dplan,
                                      pool_window=tenant.pool_window,
                                      activation=tenant.activation,
                                      interpret=self.interpret,
                                      tile_overrides=tile_overrides)

        fn = shard_map(device_fn, mesh=mesh,
                       in_specs=(P(plan.mesh.axis),),
                       out_specs=P(plan.mesh.axis), check_rep=False)
        y = fn(xb)
        if INJECTOR.enabled:
            # injection seam "collective": the gathered result of a
            # sharded execution (corruption lands after the collective)
            y = INJECTOR.perturb_output("collective", y, tenant.name)
        return y

    # -- degraded mesh / fault survival --------------------------------------
    def on_device_loss(self, device: Optional[int] = None) -> list:
        """Degrade, don't die: shrink the mesh by one device
        (``BudgetArbiter.on_device_loss``) and mark the affected tenants
        — their next batch re-plans at the shrunk shard degree (the
        degree ladder descends; precision is untouched because every
        surviving device still plans under the FULL per-device budget).
        Returns the affected tenant names."""
        affected = self.arbiter.on_device_loss(device)
        self.mesh = self.arbiter.mesh
        for name in affected:
            self.tenants[name].telemetry.degradations += 1
        return affected

    def on_budget_shrink(self, fraction: float) -> None:
        """Mid-serving budget shock: the device budget scales to
        ``fraction`` of itself (every tenant's slice shrinks with it at
        its next batch — the precision ladder absorbs what the smaller
        envelope cannot fit)."""
        if not 0.0 < fraction <= 1.0:
            raise ValueError("fraction must be in (0, 1]")
        self.budget = self.budget.scaled(fraction)
        self.arbiter.budget = self.budget
        log_event("budget.shrunk", fraction=fraction)

    def prewarm_spares(self, losses: int = 1) -> int:
        """Pre-plan every tenant's graphs against the post-loss device
        grants (``BudgetArbiter.degraded_grants``), so a real device
        loss re-plans **zero graphs cold** — the spare plans already sit
        in the cache under the exact keys the degraded mesh will ask
        for.  Mesh mode only.  Returns the number of spare plans
        warmed (cache hits included: warm is warm)."""
        if self.mesh is None:
            raise ValueError("prewarm_spares() is mesh-mode only")
        grants = self.arbiter.degraded_grants(losses)
        survivors = self.mesh.devices - int(losses)
        # the post-loss split() may also re-settle by plain largest
        # remainder (no ladder snap) — warm those grants too
        resettle = self.arbiter._device_grants(
            self.arbiter._granted, devices=survivors)
        warmed = 0
        for name, tenant in self.tenants.items():
            degrees = {grants.get(name, 0), resettle.get(name, 0)} - {0}
            for n_dev in degrees:
                spare_mesh = dataclasses.replace(self.arbiter.mesh,
                                                 devices=n_dev)
                for b in range(1, self.max_batch + 1):
                    specs = self._specs(
                        tenant.params, (b,) + tenant.input_shape,
                        "float32", tenant.pool_window, tenant.activation,
                        tenant.ladder)
                    plan_network(specs, self.budget, fuse=self.fuse,
                                 calibration=self.calibration,
                                 mesh=spare_mesh if n_dev > 1 else None)
                    warmed += 1
        log_event("mesh.spares_prewarmed", losses=losses, plans=warmed)
        return warmed

    # -- observability ------------------------------------------------------
    def shares(self) -> Dict[str, TenantShare]:
        """The latest arbitration round's grants (empty before a step)."""
        return dict(self._shares)

    def pending(self) -> int:
        return len(self._queue)

    def queue_stats(self) -> Dict[str, int]:
        """Lifetime counters of the shape-bucket queue."""
        return self._queue.stats()

    def metrics(self, registry=None):
        """This server's state folded into a ``MetricsRegistry``
        (``repro.obs.metrics``): planner/cache counters, event log,
        tracer stats, arbiter rebalances, and per-tenant telemetry
        including shard degree and comm-cycles share.  Render with
        ``.render()`` (Prometheus text) or ``.snapshot()``."""
        from repro.obs.metrics import system_metrics
        return system_metrics(server=self, registry=registry)

    def telemetry(self) -> Dict[str, dict]:
        """Per-tenant snapshot: latency percentiles (est-cycles),
        batch occupancy, precision mix, re-plans, plan-cache hit rate,
        measured quantization error, and the current grant/floor.
        ``calibration_key`` identifies the cost model the plans and the
        time accounting were priced under (None = analytical)."""
        from repro.core.calibrate_cost import calibration_key
        calkey = calibration_key(self.calibration)
        out = {}
        for name, t in self.tenants.items():
            snap = t.telemetry.snapshot()
            snap["granted_fraction"] = t.granted
            snap["floor_fraction"] = t.floor
            snap["unit_cost_cycles"] = t.unit_cost
            snap["calibration_key"] = calkey
            out[name] = snap
        return out
