"""AdaptiveServer — multi-tenant serving over the adaptive-IP planner.

The paper's claim is that IPs adapt to the resources *actually
available*; offline that meant one ``plan_network`` call against one
static budget.  This server makes the claim dynamic: several registered
CNN frontends ("tenants") share one device ``ResourceBudget``, a
``BudgetArbiter`` splits it proportional to observed demand (floored at
each tenant's minimal feasible fraction, ladder rungs included), and
when the split shifts the affected tenants are *live re-planned*
through ``core.plan.replan`` — a tenant squeezed below its f32
footprint degrades to int16/int8 execution instead of failing.

Time model: latency is accounted in **estimated cycles**, the same cost
model the planner optimizes.  With ``calibration=`` (a fitted
``core.calibrate_cost.CalibrationTable``) both sides upgrade together:
plans are ranked by measured scale factors and the lane clock advances
by the same calibrated cycles, so grants, telemetry and the planner all
optimize the objective that was actually measured.  Each tenant owns a serving lane (its
spatial slice of the device, the FPGA-region analogy): batches of a
lane execute sequentially, a batch occupies the lane for its plan's
``total_cycles``, and a request's latency is queue wait plus service.
Numerics are real — every batch runs its planned Pallas kernels — only
*time* is modeled, which keeps policies comparable without wall-clock
noise from the interpret-mode substrate.

Requests are shape-bucketed (``batching.py``): same-shaped samples of a
tenant stack into one planned execution, so repeat batch shapes hit the
plan cache with zero selector work.  With ``autotune=True`` the tunable
sites of each executed plan run sweep-chosen tilings
(``core.autotune.plan_tile_overrides``) instead of member defaults.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core.plan import (STATS, network_min_fraction, plan_network,
                             replan)
from repro.core.resources import MeshSpec, ResourceBudget
from repro.models.frontends import apply_cnn_frontend, cnn_frontend_site_specs
from repro.obs.trace import NOOP_SPAN, TRACER
from repro.runtime.arbiter import BudgetArbiter, TenantShare
from repro.runtime.batching import Request, ShapeBucketQueue
from repro.runtime.telemetry import TenantTelemetry

_SIDE_CACHE_MAX = 256   # bound for the tile- and specs-caches


@dataclasses.dataclass
class Tenant:
    """One registered CNN frontend and its serving state."""

    name: str
    params: Any
    input_shape: Tuple[int, ...]        # per-sample (H, W, C)
    pool_window: Tuple[int, int]
    activation: str
    ladder: Tuple[int, ...]
    measure_quant: bool
    floor: float                        # min feasible device fraction
    unit_cost: float                    # est-cycles of one request, ample
    granted: float = 0.0                # current device fraction
    lane_free: float = 0.0              # when this lane next idles (cycles)
    telemetry: TenantTelemetry = None


@dataclasses.dataclass(frozen=True)
class Completion:
    """One served request: result + accounting."""

    rid: int
    tenant: str
    result: Any                         # (S, d_model) patch embeddings
    arrival: float
    finished: float
    batch_size: int

    @property
    def latency(self) -> float:
        return self.finished - self.arrival


class AdaptiveServer:
    """Admit, batch, arbitrate, re-plan, execute.  See module docstring.

    ``policy="demand"`` arbitrates; ``policy="static"`` is the even-split
    baseline.  ``autotune=True`` swaps member-default tilings for
    sweep-chosen ones on the tunable sites of every executed plan.
    """

    def __init__(self, budget: Optional[ResourceBudget] = None, *,
                 policy: str = "demand", rebalance_threshold: float = 0.05,
                 max_batch: int = 4, autotune: bool = False,
                 interpret: bool = True, demand_alpha: float = 0.5,
                 fuse: bool = True, calibration=None,
                 mesh: Optional[MeshSpec] = None,
                 slo_pressure: float = 0.0, miss_alpha: float = 0.5,
                 grant_quantum: float = 0.0):
        self.budget = budget or ResourceBudget()
        # fuse (default True): serve every tenant through fusion-aware
        # plans — a block the planner can fuse runs conv->pool->act as
        # ONE launch, falling back per block when the fused footprint
        # won't fit the tenant's slice.  fuse=False opts out.
        self.fuse = fuse
        # calibration: a fitted CalibrationTable prices every planning
        # decision, the demand weights, and the lane time model in
        # measured scale factors instead of the raw analytical cycles
        # (see core/calibrate_cost.py).  None keeps the analytical model.
        self.calibration = calibration
        # mesh: a MeshSpec with devices > 1 puts the arbiter in mesh
        # mode — tenants are granted whole-device slices and each batch
        # is planned with plan_network(mesh=<tenant sub-mesh>), so a
        # tenant holding several devices may serve *sharded* plans
        # (executed through shard_map when the layout is uniform; see
        # _execute).  None keeps the fractional single-chip server.
        # slo_pressure > 0 makes the arbiter chase deadline-miss EWMAs
        # on top of demand — only meaningful under the SLO scheduler
        # (``runtime/scheduler.py``), which feeds ``record_outcome``.
        self.arbiter = BudgetArbiter(self.budget, policy=policy,
                                     rebalance_threshold=rebalance_threshold,
                                     demand_alpha=demand_alpha,
                                     calibration=calibration, mesh=mesh,
                                     slo_pressure=slo_pressure,
                                     miss_alpha=miss_alpha,
                                     grant_quantum=grant_quantum)
        self.mesh = self.arbiter.mesh
        self.max_batch = max_batch
        self.autotune = autotune
        self.interpret = interpret
        self.clock = 0.0
        self.tenants: Dict[str, Tenant] = {}
        self._queue = ShapeBucketQueue()
        self._shares: Dict[str, TenantShare] = {}
        self._tile_cache: Dict[tuple, dict] = {}
        # bucket key -> site specs: spec construction runs jax.eval_shape
        # per block, so hot repeat buckets must not rebuild them
        self._specs_cache: Dict[tuple, tuple] = {}
        self._next_rid = 0

    # -- admission ----------------------------------------------------------
    def register(self, name: str, params, input_shape, *,
                 pool_window=(2, 2), activation: str = "relu",
                 ladder: Tuple[int, ...] = (),
                 measure_quant: bool = False) -> Tenant:
        """Register a CNN frontend as a tenant.

        Prices the tenant up front: its *floor* (minimal feasible device
        fraction at max batch, ladder included — what the arbiter must
        always grant) and its *unit cost* (est-cycles of a one-sample
        plan under the full device, the demand weight).  Raises the
        planner's error when the tenant cannot run even with the whole
        device to itself — admission fails honestly.
        """
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        input_shape = tuple(int(d) for d in input_shape)
        canonical = self._specs(params, (self.max_batch,) + input_shape,
                                "float32", pool_window, activation, ladder)
        # Admission check: both the max-batch and the one-sample graphs
        # must plan under the full device (raises the planner's
        # canonical error otherwise) — and both plans warm the share
        # cache for the replan fast path.  The floor stays priced on the
        # unfused graph: fusion-aware planning always falls back to the
        # three-site chain, so the unfused minimum remains the sound
        # feasibility guarantee the arbiter must honor.
        plan_network(canonical, self.budget, fuse=self.fuse,
                     calibration=self.calibration)
        floor = network_min_fraction(canonical, self.budget)
        unit = plan_network(
            self._specs(params, (1,) + input_shape, "float32",
                        pool_window, activation, ladder),
            self.budget, fuse=self.fuse,
            calibration=self.calibration).calibrated_cycles(self.calibration)
        tenant = Tenant(name=name, params=params, input_shape=input_shape,
                        pool_window=tuple(pool_window), activation=activation,
                        ladder=tuple(ladder), measure_quant=measure_quant,
                        floor=floor, unit_cost=unit,
                        telemetry=TenantTelemetry(name=name,
                                                  max_batch=self.max_batch))
        self.arbiter.register(name, floor)
        self.tenants[name] = tenant
        return tenant

    @staticmethod
    def _specs(params, batch_shape, dtype, pool_window, activation, ladder):
        return tuple(cnn_frontend_site_specs(
            params, batch_shape, dtype, pool_window=tuple(pool_window),
            activation=activation, ladder=tuple(ladder)))

    def submit(self, name: str, x, *, at: Optional[float] = None):
        """Queue one sample (H, W, C) — or a (B, H, W, C) stack, queued
        as B independent requests — arriving at clock ``at`` (default:
        now).  Returns the request id (or list of ids)."""
        tenant = self.tenants[name]
        x = jnp.asarray(x)
        if x.ndim == len(tenant.input_shape) + 1:
            return [self.submit(name, xi, at=at) for xi in x]
        if x.shape != tenant.input_shape:
            raise ValueError(
                f"tenant {name!r} expects samples of shape "
                f"{tenant.input_shape}, got {x.shape}")
        arrival = self.clock if at is None else float(at)
        rid = self._next_rid
        self._next_rid += 1
        self._queue.push(Request(rid=rid, tenant=name, x=x, arrival=arrival))
        self.arbiter.observe(name, tenant.unit_cost)
        return rid

    # -- serving ------------------------------------------------------------
    def step(self) -> List[Completion]:
        """One serving round: arbitrate, then drain every bucket.

        Re-grants move tenant budget slices; a moved slice re-plans the
        tenant's graphs on their next batch (the ``replan`` fast path —
        counted in telemetry as a re-plan when the tenant had already
        been granted before).
        """
        if not self._queue:
            return []
        self._apply_shares(self.arbiter.split())
        completions: List[Completion] = []
        for key in self._queue.keys():
            while True:
                batch = self._queue.pop_batch(key, self.max_batch)
                if not batch:
                    break
                completions.extend(self._execute(batch))
        if completions:
            self.clock = max(self.clock,
                             max(c.finished for c in completions))
        return completions

    def _apply_shares(self, shares: Dict[str, TenantShare]) -> None:
        """Adopt one arbitration round's grants.  A moved grant changes
        the tenant's slice budget, which re-plans its graphs on the next
        batch — counted as a re-plan when the tenant had already been
        granted before.  Shared by ``step`` and the SLO scheduler
        (``runtime/scheduler.py``), so both loops account grant moves
        identically."""
        self._shares = shares
        for name, share in shares.items():
            t = self.tenants[name]
            if t.granted and abs(share.fraction - t.granted) > 1e-12:
                t.telemetry.replans += 1
            t.granted = share.fraction

    def drain(self, max_steps: int = 1000) -> List[Completion]:
        out: List[Completion] = []
        for _ in range(max_steps):
            if not self._queue:
                break
            out.extend(self.step())
        return out

    def _execute(self, batch: List[Request]) -> List[Completion]:
        # Tracing contract: the disabled path costs one attribute read
        # and one branch per span site — no argument dicts, no span
        # objects (NOOP_SPAN is the shared singleton).
        with (TRACER.span("serve.execute", "serving",
                          {"tenant": batch[0].tenant,
                           "batch": len(batch)})
              if TRACER.enabled else NOOP_SPAN):
            return self._execute_batch(batch)

    def _execute_batch(self, batch: List[Request]) -> List[Completion]:
        tenant = self.tenants[batch[0].tenant]
        xb = jnp.stack([r.x for r in batch])
        if self.mesh is not None:
            # mesh mode: the tenant holds whole devices — plan against
            # the FULL per-device budget and let the planner decide how
            # (whether) to shard across the granted sub-mesh.
            slice_budget = self.arbiter.budget_for(tenant.name)
            tenant_mesh = self.arbiter.mesh_for(tenant.name)
        else:
            slice_budget = self.budget.scaled(tenant.granted)
            tenant_mesh = None
        skey = (tenant.name, xb.shape, str(xb.dtype))
        specs = self._specs_cache.get(skey)
        if specs is None:
            specs = self._specs(tenant.params, xb.shape, xb.dtype,
                                tenant.pool_window, tenant.activation,
                                tenant.ladder)
            if len(self._specs_cache) >= _SIDE_CACHE_MAX:
                self._specs_cache.pop(next(iter(self._specs_cache)))
            self._specs_cache[skey] = specs
        hits0, misses0 = STATS.plan_hits, STATS.plan_misses
        plan = replan(specs, slice_budget, fuse=self.fuse,
                      calibration=self.calibration, mesh=tenant_mesh)
        tile_overrides = None
        if self.autotune:
            tkey = (specs, slice_budget)
            tile_overrides = self._tile_cache.get(tkey)
            if tile_overrides is None:
                from repro.core.autotune import plan_tile_overrides
                tile_overrides = plan_tile_overrides(plan)
                if len(self._tile_cache) >= _SIDE_CACHE_MAX:
                    self._tile_cache.pop(next(iter(self._tile_cache)))
                self._tile_cache[tkey] = tile_overrides
        quant_report = {} if (tenant.ladder and tenant.measure_quant) else None
        sharded = self._shardable(plan, xb)
        with (TRACER.span("kernel", "kernel",
                          {"tenant": tenant.name,
                           "launches": plan.total_launches,
                           "sharded": sharded})
              if TRACER.enabled else NOOP_SPAN):
            if sharded:
                y = self._run_frontend_sharded(
                    tenant, xb, plan, tile_overrides=tile_overrides)
            else:
                y = apply_cnn_frontend(tenant.params, xb, network=plan,
                                       pool_window=tenant.pool_window,
                                       activation=tenant.activation,
                                       interpret=self.interpret,
                                       ladder=tenant.ladder,
                                       quant_report=quant_report,
                                       tile_overrides=tile_overrides,
                                       fuse=self.fuse)
        start = max(tenant.lane_free, max(r.arrival for r in batch))
        if TRACER.enabled:
            TRACER.instant(
                "batch.queue_wait", "serving",
                {"tenant": tenant.name,
                 "max_wait_cycles":
                     start - min(r.arrival for r in batch)})
        finish = start + plan.calibrated_cycles(self.calibration)
        tenant.lane_free = finish
        latencies = [finish - r.arrival for r in batch]
        quant_err = 0.0
        if quant_report:
            from repro.quant.report import max_rel_error
            quant_err = max_rel_error(quant_report)
        tenant.telemetry.record_batch(
            len(batch), latencies, plan,
            cache_hits=STATS.plan_hits - hits0,
            cache_misses=STATS.plan_misses - misses0,
            quant_err=quant_err)
        return [Completion(rid=r.rid, tenant=r.tenant, result=y[i],
                           arrival=r.arrival, finished=finish,
                           batch_size=len(batch))
                for i, r in enumerate(batch)]

    @staticmethod
    def _shardable(plan, xb) -> bool:
        """True when the plan can run through the shard_map frontend
        path: a mesh plan whose sites are ALL batch-sharded at the mesh
        degree (a uniform layout needs no mid-chain relays inside the
        frontend walk), float precision, and a batch that tiles evenly.
        Mixed/chan/degree-1 layouts fall back to the replicated walk of
        the same plan — identical math, the mesh then only reshapes the
        time model."""
        if plan.mesh is None or plan.mesh.devices <= 1:
            return False
        d = plan.mesh.devices
        sharded = plan.sharded_sites()
        if len(sharded) != len(plan.sites):
            return False
        if any(s.shard_axis != "batch" or s.shard_degree != d
               or s.lowered for s in plan.sites):
            return False
        return xb.shape[0] % d == 0

    def _run_frontend_sharded(self, tenant: Tenant, xb, plan,
                              *, tile_overrides=None):
        """The whole frontend under one shard_map over the tenant's
        device slice: each device runs the per-device plan
        (``plan.device_plan()``) on its batch block; ``out_specs``
        re-tiles the result so the caller sees the replicated contract.
        Bit-identical to the replicated walk for batch sharding (tests
        assert it)."""
        import numpy as np
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        d = plan.mesh.devices
        start, stop = self.arbiter.device_slice(tenant.name)
        devs = jax.devices()[start:stop]
        if len(devs) < d:
            raise ValueError(
                f"tenant {tenant.name!r} was granted devices "
                f"[{start}, {stop}) but only {len(jax.devices())} exist "
                "(set XLA_FLAGS=--xla_force_host_platform_device_count)")
        mesh = Mesh(np.array(devs), (plan.mesh.axis,))
        dplan = plan.device_plan()

        def device_fn(xg):
            return apply_cnn_frontend(tenant.params, xg, network=dplan,
                                      pool_window=tenant.pool_window,
                                      activation=tenant.activation,
                                      interpret=self.interpret,
                                      tile_overrides=tile_overrides)

        fn = shard_map(device_fn, mesh=mesh,
                       in_specs=(P(plan.mesh.axis),),
                       out_specs=P(plan.mesh.axis), check_rep=False)
        return fn(xb)

    # -- observability ------------------------------------------------------
    def shares(self) -> Dict[str, TenantShare]:
        """The latest arbitration round's grants (empty before a step)."""
        return dict(self._shares)

    def pending(self) -> int:
        return len(self._queue)

    def queue_stats(self) -> Dict[str, int]:
        """Lifetime counters of the shape-bucket queue."""
        return self._queue.stats()

    def metrics(self, registry=None):
        """This server's state folded into a ``MetricsRegistry``
        (``repro.obs.metrics``): planner/cache counters, event log,
        tracer stats, arbiter rebalances, and per-tenant telemetry
        including shard degree and comm-cycles share.  Render with
        ``.render()`` (Prometheus text) or ``.snapshot()``."""
        from repro.obs.metrics import system_metrics
        return system_metrics(server=self, registry=registry)

    def telemetry(self) -> Dict[str, dict]:
        """Per-tenant snapshot: latency percentiles (est-cycles),
        batch occupancy, precision mix, re-plans, plan-cache hit rate,
        measured quantization error, and the current grant/floor.
        ``calibration_key`` identifies the cost model the plans and the
        time accounting were priced under (None = analytical)."""
        from repro.core.calibrate_cost import calibration_key
        calkey = calibration_key(self.calibration)
        out = {}
        for name, t in self.tenants.items():
            snap = t.telemetry.snapshot()
            snap["granted_fraction"] = t.granted
            snap["floor_fraction"] = t.floor
            snap["unit_cost_cycles"] = t.unit_cost
            snap["calibration_key"] = calkey
            out[name] = snap
        return out
