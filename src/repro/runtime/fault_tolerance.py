"""Fault-tolerance runtime: watchdog, straggler monitor, elastic re-mesh.

On a real multi-pod deployment these hooks sit in the per-host agent;
here they are fully implemented and unit-tested against simulated
failures (the single-host CPU runtime stands in for a node).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional

import jax

from repro.obs.trace import log_event


# ---------------------------------------------------------------------------
# Watchdog: detects a hung/crashed step and triggers restart-from-ckpt.
# ---------------------------------------------------------------------------
class Watchdog:
    def __init__(self, timeout_s: float, on_timeout: Callable[[], None]):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self._last_beat = time.monotonic()
        self._stop = threading.Event()
        self._fired = False
        self._thread = threading.Thread(target=self._loop, daemon=True)

    def start(self):
        self._thread.start()
        return self

    def beat(self):
        self._last_beat = time.monotonic()

    def stop(self) -> bool:
        """Stop monitoring and join the monitor thread.

        After ``stop()`` returns, no *new* ``on_timeout`` fires: the
        loop re-checks the stop flag right before firing (closing the
        window where the wait timed out just as ``stop`` was called).
        The join is bounded by ``max(timeout_s, 1.0)`` so a wedged
        callback cannot hang the caller; the return value reports
        whether the monitor actually terminated (``False`` means a
        callback was still in flight when the join timed out).  Safe to
        call before ``start()``, more than once, and from inside
        ``on_timeout`` itself (the fire-once pattern) — the monitor
        thread never joins itself.
        """
        self._stop.set()
        if (self._thread.ident is not None and self._thread.is_alive()
                and self._thread is not threading.current_thread()):
            self._thread.join(timeout=max(self.timeout_s, 1.0))
        return not self._thread.is_alive()

    @property
    def fired(self) -> bool:
        return self._fired

    def rearm(self) -> "Watchdog":
        """Clear a latched ``fired`` and restart the beat window.

        ``fired`` otherwise latches forever, so a deployment that
        recovered from one hang could never distinguish a SECOND one
        from the stale flag.  ``RecoveryManager.recover()`` re-arms
        after adopting the replacement server; callers with a live
        monitor thread can re-arm in place, callers whose ``on_timeout``
        stopped the watchdog (the fire-once pattern) need a fresh
        ``Watchdog`` instead — ``rearm`` does not resurrect a joined
        thread."""
        self._fired = False
        self._last_beat = time.monotonic()
        return self

    def _loop(self):
        while not self._stop.wait(min(self.timeout_s / 4, 1.0)):
            if (time.monotonic() - self._last_beat > self.timeout_s
                    and not self._stop.is_set()):
                self._fired = True
                log_event("watchdog.timeout", timeout_s=self.timeout_s)
                self.on_timeout()
                self._last_beat = time.monotonic()


# ---------------------------------------------------------------------------
# Straggler monitor: EWMA step-time outlier detection + mitigation hook.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ewma: float
    ratio: float


class StragglerMonitor:
    """Flags steps slower than ``threshold`` x the EWMA.  The mitigation
    hook is where a production deployment rebalances grad-accumulation
    microbatches away from the slow host or swaps in a hot spare.

    Every flagged step is recorded in ``events`` and logged through
    ``obs.EVENTS`` (``straggler.flagged``), but the mitigation hook is
    *rearm-gated*: after it fires, ``rearm`` consecutive normal steps
    must pass before it can fire again (``rearm=0`` fires on every
    flag) — a sustained slowdown triggers one mitigation, not one per
    step."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.1,
                 warmup: int = 3, rearm: int = 0,
                 on_straggler: Optional[Callable[[StragglerEvent], None]] = None):
        if rearm < 0:
            raise ValueError("rearm must be >= 0")
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.rearm = rearm
        self.on_straggler = on_straggler
        self.ewma: Optional[float] = None
        self.events: List[StragglerEvent] = []
        self.hook_fires = 0
        self._n = 0
        self._suppress = 0   # normal steps still owed before re-firing

    def record(self, step: int, step_time: float) -> Optional[StragglerEvent]:
        self._n += 1
        if self.ewma is None:
            self.ewma = step_time
            return None
        ev = None
        if self._n > self.warmup and step_time > self.threshold * self.ewma:
            ev = StragglerEvent(step, step_time, self.ewma,
                                step_time / self.ewma)
            self.events.append(ev)
            log_event("straggler.flagged", step=step, ratio=ev.ratio,
                      ewma=self.ewma, suppressed=self._suppress > 0)
            if self._suppress == 0:
                if self.on_straggler:
                    self.on_straggler(ev)
                self.hook_fires += 1
                self._suppress = self.rearm
            # don't poison the EWMA with the outlier
            return ev
        if self._suppress > 0:
            self._suppress -= 1
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time
        return ev


# ---------------------------------------------------------------------------
# Elastic re-mesh: pick the best (data, model) mesh for surviving devices.
# Both helpers are expressed over core.shard.degree_ladder — the same
# divisor chain the arbiter's device-loss path descends (the degraded-
# mesh wiring in runtime/arbiter.py and runtime/server.py).
# ---------------------------------------------------------------------------
def choose_mesh_shape(n_devices: int, *, prefer_model: int = 16,
                      min_model: int = 1) -> tuple:
    """Largest (data, model) grid with model | prefer_model, covering as
    many surviving devices as possible (some may idle — correctness
    first, utilization second).  The model-degree candidates are exactly
    ``degree_ladder(prefer_model, survivors=n_devices)`` — a surviving
    model degree must keep the pre-loss model sharding divisible."""
    from repro.core.shard import degree_ladder
    best = (1, 1)
    for model in degree_ladder(prefer_model,
                               survivors=min(prefer_model, n_devices)):
        if model < min_model:
            continue
        data = n_devices // model
        if data * model > best[0] * best[1]:
            best = (data, model)
    return best


def elastic_remesh(n_devices: int, prefer_model: int = 16, *,
                   axis: Optional[str] = None, offset: int = 0):
    """Build a ``jax.sharding.Mesh`` over surviving devices.

    Default (``axis=None``): the training-style 2-D ("data", "model")
    grid over the first devices, shaped by ``choose_mesh_shape``.

    ``axis=`` (serving mode — what ``AdaptiveServer`` executes degraded
    tenants through): a 1-D mesh named ``axis`` over the contiguous
    device slice ``jax.devices()[offset : offset + n_devices]`` — the
    tenant's granted slice on the (possibly shrunk) pool."""
    import numpy as np
    devs = jax.devices()
    if axis is not None:
        pool = devs[offset:offset + n_devices]
        if len(pool) < n_devices:
            raise ValueError(
                f"mesh wants devices [{offset}, {offset + n_devices}) but "
                f"only {len(devs)} exist (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count)")
        return jax.sharding.Mesh(np.array(pool), (axis,))
    data, model = choose_mesh_shape(n_devices, prefer_model=prefer_model)
    grid = np.array(devs[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(grid, ("data", "model"))
