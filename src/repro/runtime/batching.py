"""Shape-bucketed request batching.

Inference requests land in FIFO buckets keyed by (tenant, per-sample
shape, dtype); a batch stacks up to ``max_batch`` same-bucket samples
along a new leading axis so ONE planned execution serves them all.
Bucketing by shape is what keeps the plan cache hot: every batch of the
same (tenant, shape, size) resolves to the same graph key, so repeat
batches cost zero selector work (``core/plan.py`` memoization).

For full-precision plans batching is *exact* — every family's kernels
are batch-independent — and the tests assert batched == per-request.
Quantized plans use per-tensor activation scales, so a batch shares one
scale where per-request execution would pick each its own; the error
stays within the per-site reported bound either way.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class Request:
    """One queued inference request: a single sample for one tenant."""

    rid: int
    tenant: str
    x: Any                  # (H, W, C) sample array
    arrival: float          # server clock, est-cycles units

    @property
    def bucket_key(self) -> Tuple[str, Tuple[int, ...], str]:
        return (self.tenant, tuple(self.x.shape), str(self.x.dtype))


class ShapeBucketQueue:
    """FIFO queue per (tenant, sample-shape, dtype) bucket.

    Buckets drain in creation order and requests within a bucket in
    arrival order — deterministic given the submission sequence.
    """

    def __init__(self):
        self._buckets: Dict[Tuple, Deque[Request]] = {}
        # Lifetime counters (never reset) for metrics exposition.
        self.pushes = 0
        self.pops = 0
        self.popped_requests = 0

    def push(self, req: Request) -> None:
        self.pushes += 1
        self._buckets.setdefault(req.bucket_key, deque()).append(req)

    def keys(self) -> Tuple[Tuple, ...]:
        return tuple(k for k, q in self._buckets.items() if q)

    def pop_batch(self, key: Tuple, max_batch: int) -> List[Request]:
        """Up to ``max_batch`` oldest requests of one bucket (empty list
        when the bucket is drained; drained buckets are dropped)."""
        q = self._buckets.get(key)
        if not q:
            self._buckets.pop(key, None)
            return []
        batch = [q.popleft() for _ in range(min(max_batch, len(q)))]
        if not q:
            self._buckets.pop(key, None)
        self.pops += 1
        self.popped_requests += len(batch)
        return batch

    def stats(self) -> Dict[str, int]:
        """Lifetime counters: requests pushed, batches popped, requests
        popped, plus the current depth and live bucket count."""
        return {"pushes": self.pushes, "pops": self.pops,
                "popped_requests": self.popped_requests,
                "pending": len(self),
                "buckets": len(self.keys())}

    def pending(self, tenant: str) -> int:
        return sum(len(q) for (t, _, _), q in self._buckets.items()
                   if t == tenant)

    def __len__(self) -> int:
        return sum(len(q) for q in self._buckets.values())

    def __bool__(self) -> bool:
        return len(self) > 0
