"""Execution guards: output screening + bounded deadline-aware retry.

The fault injector (``faults.py``) makes failures reproducible; this
module is what turns them into degraded service instead of lost
requests.  A tenant opts in with a ``GuardPolicy``
(``AdaptiveServer.set_guard``); guarded batches then run through
``execute_guarded``:

* **Output screening** — ``jnp.isfinite`` over the batch result.  A
  non-finite output (NaN-poisoned batch, corrupted collective) is
  handled per policy: ``on_nonfinite="reject"`` fails the requests
  immediately (a poisoned answer is worse than no answer);
  ``"retry_f32"`` re-executes the batch with the precision ladder off —
  the quantized rungs are the usual numerical suspects — and screens
  again.
* **Bounded deadline-aware retry** — transient faults (kernel-launch
  exceptions, injected failures) retry with exponential backoff, but the
  whole schedule is truncated against the batch's remaining ``SLOSpec``
  deadline budget (``backoff_schedule``): retry time is charged to the
  request's wall deadline, and work that cannot finish inside it is
  **shed**, not retried hopelessly.
* **Degrade on device loss** — ``DeviceLost`` is structural, not
  transient: the guard calls the ``on_device_loss`` hook (the server
  shrinks the mesh and re-grants) and retries immediately on the
  surviving devices; the degree ladder descends before the precision
  ladder does.

Every outcome is observable: ``retry.attempt`` per retry,
``guard.rejected`` when the guard gives up, and the per-tenant
telemetry columns ``guard_rejected`` / ``guard_shed`` /
``guard_retries``.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, List, Optional, Tuple

import jax.numpy as jnp

from repro.core.plan import PartitionError
from repro.obs.trace import log_event
from repro.runtime.faults import DeviceLost, InjectedFault

NONFINITE_POLICIES = ("reject", "retry_f32")

# Structural (device-loss) retries are bounded separately from the
# backoff schedule: one degrade per surviving rung is enough, and a
# corpse the control plane cannot shrink past must fail, not spin.
MAX_DEVICE_RETRIES = 2


class GuardViolation(RuntimeError):
    """A screened output failed the finiteness check."""


@dataclasses.dataclass(frozen=True)
class GuardPolicy:
    """One tenant's survival policy for guarded execution.

    ``screen_outputs``: run the ``isfinite`` screen on every batch
    result.  ``on_nonfinite``: ``"reject"`` fails the batch,
    ``"retry_f32"`` re-executes with the precision ladder off first.
    ``max_retries`` bounds the transient-fault retry count;
    ``backoff_base_s`` * ``backoff_factor**i`` is retry ``i``'s delay,
    jittered by up to ``backoff_jitter`` (fraction, seeded — delays stay
    monotone non-decreasing)."""

    screen_outputs: bool = True
    on_nonfinite: str = "reject"
    max_retries: int = 2
    backoff_base_s: float = 0.005
    backoff_factor: float = 2.0
    backoff_jitter: float = 0.0

    def __post_init__(self):
        if self.on_nonfinite not in NONFINITE_POLICIES:
            raise ValueError(f"on_nonfinite must be one of "
                             f"{NONFINITE_POLICIES}, got "
                             f"{self.on_nonfinite!r}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_base_s < 0.0:
            raise ValueError("backoff_base_s must be >= 0")
        if self.backoff_factor < 1.0:
            raise ValueError("backoff_factor must be >= 1")
        if not 0.0 <= self.backoff_jitter <= 1.0:
            raise ValueError("backoff_jitter must be in [0, 1]")


def backoff_schedule(policy: GuardPolicy,
                     remaining_s: Optional[float] = None, *,
                     seed: int = 0) -> List[float]:
    """The retry delays a guarded batch may spend, in order.

    Three properties the property tests hold (tests/test_guards.py):
    the schedule is **deterministic** under a fixed seed, delays are
    **monotone non-decreasing**, and the **total never exceeds
    ``remaining_s``** (the request's remaining deadline budget) — the
    schedule is truncated at the first delay that would overdraw it, so
    a hopeless retry is shed instead of attempted."""
    limit = float("inf") if remaining_s is None else max(float(remaining_s),
                                                         0.0)
    rnd = random.Random(seed)
    delays: List[float] = []
    total = prev = 0.0
    for i in range(policy.max_retries):
        d = policy.backoff_base_s * policy.backoff_factor ** i
        if policy.backoff_jitter > 0.0:
            d *= 1.0 + policy.backoff_jitter * rnd.random()
        d = max(d, prev)               # jitter can never break monotonicity
        if total + d > limit:
            break
        delays.append(d)
        total += d
        prev = d
    return delays


def screen_finite(y) -> bool:
    """True when every element of the batch result is finite."""
    return bool(jnp.isfinite(jnp.asarray(y)).all())


@dataclasses.dataclass
class GuardReport:
    """What guarded execution did to one batch: the terminal ``outcome``
    (``ok`` / ``rejected`` / ``shed``), retries spent, whether the
    precision ladder was switched off, and the give-up reason."""

    outcome: str = "ok"
    retries: int = 0
    retried_f32: bool = False
    reason: str = ""


def execute_guarded(attempt: Callable[..., object], policy: GuardPolicy, *,
                    tenant: str = "", remaining_s: Optional[float] = None,
                    wall: Callable[[], float] = time.monotonic,
                    sleep: Callable[[float], None] = time.sleep,
                    on_device_loss: Optional[Callable] = None,
                    seed: int = 0) -> Tuple[Optional[object], GuardReport]:
    """Run ``attempt(retry_f32=...)`` under ``policy``.

    Returns ``(result, report)`` — result is None when the guard gave up
    (``report.outcome`` says whether the batch was *rejected* — faulty
    beyond the retry budget or screened out by policy — or *shed* —
    still failing with no deadline budget left to retry in).  ``sleep``
    and ``wall`` are injectable for tests; retry delays run through the
    real ``sleep`` in serving, so retry time is charged against the
    request's wall-clock deadline."""
    deadline = (None if remaining_s is None
                else wall() + max(float(remaining_s), 0.0))
    delays = backoff_schedule(policy, remaining_s, seed=seed)
    truncated = len(delays) < policy.max_retries
    report = GuardReport()
    retry_f32 = False
    device_retries = 0
    while True:
        try:
            y = attempt(retry_f32=retry_f32)
            if policy.screen_outputs and not screen_finite(y):
                raise GuardViolation("non-finite output")
            return y, report
        except DeviceLost as e:
            # structural, not transient: degrade the mesh, retry free
            if on_device_loss is None or device_retries >= MAX_DEVICE_RETRIES:
                report.outcome, report.reason = "rejected", str(e)
                break
            try:
                on_device_loss(e)
            except Exception as degrade_err:
                report.outcome = "rejected"
                report.reason = f"degradation failed: {degrade_err}"
                break
            device_retries += 1
            report.retries += 1
            log_event("retry.attempt", tenant=tenant,
                      attempt=report.retries, delay_s=0.0,
                      cause="device_lost")
        except (InjectedFault, GuardViolation, PartitionError,
                FloatingPointError) as e:
            nonfinite = isinstance(e, GuardViolation)
            if nonfinite and policy.on_nonfinite == "reject":
                report.outcome, report.reason = "rejected", str(e)
                break
            i = report.retries - device_retries   # backoff delays consumed
            if i >= len(delays):
                # out of retry budget: "shed" when the deadline truncated
                # the schedule, "rejected" when the retry count did
                report.outcome = "shed" if truncated else "rejected"
                report.reason = f"retries exhausted: {e}"
                break
            delay = delays[i]
            if deadline is not None and wall() + delay >= deadline:
                report.outcome = "shed"
                report.reason = f"hopeless within deadline: {e}"
                break
            if nonfinite and policy.on_nonfinite == "retry_f32":
                retry_f32 = True
                report.retried_f32 = True
            report.retries += 1
            log_event("retry.attempt", tenant=tenant,
                      attempt=report.retries, delay_s=delay,
                      cause="nonfinite" if nonfinite else "fault")
            sleep(delay)
    log_event("guard.rejected", tenant=tenant, outcome=report.outcome,
              retries=report.retries, reason=report.reason)
    return None, report
