"""SLO-aware continuous-batching scheduler over ``AdaptiveServer``.

The synchronous loop (``AdaptiveServer.step``) batches in rounds: every
queued bucket drains before any new arrival is considered, deadlines are
invisible, and a burst from one tenant head-of-line-blocks everyone
else.  This scheduler replaces the round with an event-driven dispatch
loop:

* **Continuous batching** — a submitted request joins a *not-yet-
  launched* bucket instead of waiting for the next batching round: the
  dispatch frontier only advances when no lane can launch, so arrivals
  due before a tenant's lane frees ride along in that tenant's next
  batch.
* **SLO admission** — every tenant registers an ``SLOSpec`` (deadline,
  priority, max queue depth).  Admission beyond ``max_queue_depth`` is
  rejected (counted as shed), and queued requests whose deadline has
  already passed are *load-shed* rather than executed — serving a
  hopeless request only makes the next one hopeless too.
* **Deadline-aware dispatch** — launchable buckets are ordered by
  (priority desc, earliest deadline, arrival order); when a priority
  tenant's bucket jumps an earlier-queued lower-priority bucket that is
  a **preemption**: logged through ``obs.EVENTS`` and backed by an
  immediate ``BudgetArbiter.preempt`` grant transfer (the victim is
  squeezed to its floor), instead of waiting rounds of hysteresis for
  the demand EWMA to move.
* **SLO-driven arbitration** — every dispatch folds its deadline
  outcomes into the arbiter's per-tenant miss-rate EWMA
  (``record_outcome``); with ``slo_pressure > 0`` a missing tenant's
  demand weight is amplified at the next ``split()``.

Dual-clock rule (the contract tests assert): ``Request.arrival``, lane
occupancy, and latency percentiles stay in **modeled est-cycles** — the
planner's own cost model, comparable across policies and hosts — while
SLO deadlines and miss detection use a **monotonic wall clock**
(injectable ``wall=``; defaults to ``time.monotonic``).  A request's
wall deadline is stamped when it is *admitted* (deferred ``at=``
arrivals are admitted when the dispatch frontier reaches them), so real
elapsed execution time — not the modeled clock — decides whether it
missed.  ``TenantTelemetry`` therefore carries both clocks:
``p50/p95_cycles`` (modeled) next to ``wall_p50/p95_s`` and
``deadline_miss_rate`` (measured).
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.obs.trace import log_event
from repro.runtime.batching import Request
from repro.runtime.server import AdaptiveServer, Completion


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """One tenant's service-level objective.

    ``deadline_s``: wall-clock budget from admission to completion.
    ``priority``: higher dispatches first and may preempt queued
    lower-priority buckets.  ``max_queue_depth``: admission cap on
    queued-but-unlaunched requests (None = unbounded)."""

    deadline_s: float
    priority: int = 0
    max_queue_depth: Optional[int] = None

    def __post_init__(self):
        if self.deadline_s <= 0.0:
            raise ValueError("deadline_s must be > 0")
        if self.max_queue_depth is not None and self.max_queue_depth < 1:
            raise ValueError("max_queue_depth must be >= 1")


@dataclasses.dataclass
class _Admitted:
    """A queued request with its wall-clock SLO stamps (the est-cycles
    side lives in ``req.arrival``)."""

    req: Request
    admitted_wall: float
    deadline_wall: float


@dataclasses.dataclass
class _Bucket:
    """One not-yet-launched batch-in-progress; ``seq`` is creation
    order — the FIFO baseline preemption is judged against."""

    seq: int
    items: List[_Admitted] = dataclasses.field(default_factory=list)

    def earliest_deadline(self) -> float:
        return min(a.deadline_wall for a in self.items)


class SLOScheduler:
    """Event-driven admission/dispatch over one ``AdaptiveServer``.

    The server keeps its roles — pricing, arbitration mechanics, plan
    cache, kernel execution, est-cycles lane accounting — while this
    loop owns *when* batches launch and *which* requests still deserve
    to.  ``wall=`` injects the monotonic clock (tests pass a fake);
    ``shed_margin_s`` sheds requests whose remaining wall budget is
    below the margin (0.0 = shed only once already expired).
    """

    def __init__(self, server: AdaptiveServer, *,
                 wall: Callable[[], float] = time.monotonic,
                 shed_margin_s: float = 0.0, recovery=None):
        if server.pending():
            raise ValueError("attach the scheduler before submitting "
                             "requests to the server")
        self.server = server
        self.wall = wall
        self.shed_margin_s = float(shed_margin_s)
        # optional RecoveryManager: every healthy launch beats its
        # heartbeat watchdog, so dispatch stalls — not just process
        # death — trip the recovery path
        self.recovery = recovery
        self.slos: Dict[str, SLOSpec] = {}
        self._buckets: Dict[Tuple, _Bucket] = {}
        self._bucket_seq = 0
        # min-heap of deferred arrivals: (at_cycles, order, name, x)
        self._arrivals: List[tuple] = []
        self._order = 0
        self.now = server.clock          # est-cycles dispatch frontier
        self._dirty = True               # re-arbitrate before next launch
        self.launches = 0
        self.sheds = 0
        self.rejections = 0
        self.preemptions = 0
        # rid -> "ok" | "miss" | "shed" | "rejected"
        self.outcomes: Dict[int, str] = {}

    # -- admission ----------------------------------------------------------
    def register(self, name: str, params, input_shape, *, slo: SLOSpec,
                 **kwargs):
        """Register a tenant (delegates pricing/admission to
        ``AdaptiveServer.register``) under an ``SLOSpec``."""
        if not isinstance(slo, SLOSpec):
            raise TypeError(f"slo must be an SLOSpec, got {type(slo)!r}")
        tenant = self.server.register(name, params, input_shape, **kwargs)
        self.slos[name] = slo
        return tenant

    def submit(self, name: str, x, *, at: Optional[float] = None):
        """Queue one sample (or a (B, ...) stack as B requests) arriving
        at est-cycles clock ``at`` (default: now).  The request is
        *admitted* — wall deadline stamped, queue-depth cap checked —
        when the dispatch frontier reaches its arrival, so a deferred
        request's deadline reflects the wall time its turn actually
        comes up.  Returns the request id (or list of ids)."""
        if name not in self.slos:
            raise KeyError(f"tenant {name!r} is not registered with the "
                           f"scheduler")
        tenant = self.server.tenants[name]
        x = jnp.asarray(x)
        if x.ndim == len(tenant.input_shape) + 1:
            return [self.submit(name, xi, at=at) for xi in x]
        if x.shape != tenant.input_shape:
            raise ValueError(
                f"tenant {name!r} expects samples of shape "
                f"{tenant.input_shape}, got {x.shape}")
        arrival = self.now if at is None else max(float(at), self.now)
        rid = self.server._next_rid      # stable across reordering by at=
        self.server._next_rid += 1
        heapq.heappush(self._arrivals,
                       (arrival, self._order, rid, name, x))
        self._order += 1
        return rid

    def _admit_due(self) -> None:
        """Admit every arrival due at the dispatch frontier: stamp its
        wall deadline, enforce the tenant's queue-depth cap, join (or
        open) its not-yet-launched bucket."""
        while self._arrivals and self._arrivals[0][0] <= self.now:
            arrival, _, rid, name, x = heapq.heappop(self._arrivals)
            tenant = self.server.tenants[name]
            slo = self.slos[name]
            if (slo.max_queue_depth is not None
                    and self.queue_depth(name) >= slo.max_queue_depth):
                self.rejections += 1
                self.outcomes[rid] = "rejected"
                tenant.telemetry.record_shed(1)
                log_event("scheduler.reject", tenant=name, rid=rid,
                          depth=slo.max_queue_depth)
                continue
            req = Request(rid=rid, tenant=name, x=x, arrival=arrival)
            w = self.wall()
            adm = _Admitted(req=req, admitted_wall=w,
                            deadline_wall=w + slo.deadline_s)
            bucket = self._buckets.get(req.bucket_key)
            if bucket is None:
                bucket = _Bucket(seq=self._bucket_seq)
                self._bucket_seq += 1
                self._buckets[req.bucket_key] = bucket
            bucket.items.append(adm)
            self.server.arbiter.observe(name, tenant.unit_cost)
            self._dirty = True

    def queue_depth(self, name: str) -> int:
        """Admitted-but-unlaunched requests of one tenant (the number
        the ``max_queue_depth`` cap is enforced against)."""
        return sum(len(b.items) for (t, _, _), b in self._buckets.items()
                   if t == name)

    def pending(self) -> int:
        """Queued + deferred requests still owed a verdict."""
        return (sum(len(b.items) for b in self._buckets.values())
                + len(self._arrivals))

    # -- dispatch -----------------------------------------------------------
    def _shed_hopeless(self) -> None:
        """Drop queued requests that can no longer meet their deadline
        (wall clock past ``deadline_wall - shed_margin_s``).  Every shed
        is a recorded miss; executing it anyway would only push the
        bucket's *other* deadlines past hope too."""
        w = self.wall()
        for key in list(self._buckets):
            bucket = self._buckets[key]
            keep, drop = [], []
            for adm in bucket.items:
                if w + self.shed_margin_s >= adm.deadline_wall:
                    drop.append(adm)
                else:
                    keep.append(adm)
            if not drop:
                continue
            bucket.items = keep
            tenant = self.server.tenants[key[0]]
            tenant.telemetry.record_shed(len(drop))
            self.sheds += len(drop)
            self.server.arbiter.record_outcome(
                key[0], served=len(drop), missed=len(drop))
            self._dirty = True
            for adm in drop:
                self.outcomes[adm.req.rid] = "shed"
                log_event("scheduler.shed", tenant=key[0], rid=adm.req.rid,
                          late_s=w - adm.deadline_wall)
            if not bucket.items:
                del self._buckets[key]

    def _launchable(self) -> List[Tuple]:
        """Bucket keys whose tenant lane is free at the frontier."""
        return [key for key in self._buckets
                if self.server.tenants[key[0]].lane_free <= self.now]

    def _advance(self) -> bool:
        """Nothing launchable: move the est-cycles frontier to the next
        event (a deferred arrival or a lane freeing).  False = no future
        event exists (only unlaunchable work — cannot happen unless the
        loop is misused)."""
        horizons = []
        if self._arrivals:
            horizons.append(self._arrivals[0][0])
        for key in self._buckets:
            horizons.append(self.server.tenants[key[0]].lane_free)
        if not horizons:
            return False
        self.now = max(self.now, min(horizons))
        return True

    def _choose(self, launchable: List[Tuple]) -> Tuple:
        """Dispatch order: priority desc, earliest wall deadline,
        bucket creation order.  Jumping an earlier-queued lower-priority
        bucket is a preemption: logged, counted, and (fractional mode)
        backed by an immediate arbiter grant transfer."""
        def rank(key):
            b = self._buckets[key]
            return (-self.slos[key[0]].priority, b.earliest_deadline(),
                    b.seq)
        chosen = min(launchable, key=rank)
        fifo = min(launchable, key=lambda k: self._buckets[k].seq)
        if fifo == chosen:
            return chosen
        winner, victim = chosen[0], fifo[0]
        if self.slos[winner].priority <= self.slos[victim].priority:
            return chosen                 # EDF reorder, not a preemption
        self.preemptions += 1
        self.server.tenants[winner].telemetry.preemptions += 1
        log_event("scheduler.preempt", winner=winner, victim=victim,
                  winner_priority=self.slos[winner].priority,
                  victim_priority=self.slos[victim].priority)
        if winner != victim and self.server.mesh is None:
            moved = self.server.arbiter.preempt(winner, victim)
            if moved > 0.0:
                self.server._apply_shares(self.server.arbiter.shares())
                self._dirty = True       # let split() re-settle later
        return chosen

    def _launch(self, key: Tuple) -> List[Completion]:
        """Execute up to ``max_batch`` earliest-deadline requests of one
        bucket and judge them on the wall clock.  The batch's tightest
        remaining deadline budget rides along so a guarded execution's
        retries are charged against it (``runtime/guards.py``); a
        guard-failed completion (``ok=False``) counts as a miss for the
        arbiter's SLO pressure."""
        bucket = self._buckets[key]
        bucket.items.sort(key=lambda a: (a.deadline_wall, a.req.rid))
        take = bucket.items[:self.server.max_batch]
        bucket.items = bucket.items[self.server.max_batch:]
        if not bucket.items:
            del self._buckets[key]
        budget_s = min(a.deadline_wall for a in take) - self.wall()
        comps = self.server._execute([a.req for a in take],
                                     deadline_budget_s=max(budget_s, 0.0))
        w = self.wall()
        walls = [w - a.admitted_wall for a in take]
        missed = failed = 0
        for adm, c in zip(take, comps):
            if not c.ok:
                failed += 1
                self.outcomes[adm.req.rid] = "rejected"
            elif w > adm.deadline_wall:
                missed += 1
                self.outcomes[adm.req.rid] = "miss"
            else:
                self.outcomes[adm.req.rid] = "ok"
        name = key[0]
        self.server.tenants[name].telemetry.record_slo_batch(walls, missed)
        self.server.arbiter.record_outcome(name, served=len(take),
                                           missed=missed + failed)
        if missed or failed:
            self._dirty = True
        self.launches += 1
        if self.recovery is not None:
            self.recovery.beat()
        return comps

    def run(self, max_launches: int = 100_000) -> List[Completion]:
        """Drive the loop until every queued and deferred request has a
        verdict (completed, missed, shed, or rejected).  Returns the
        completions in launch order."""
        completions: List[Completion] = []
        while self.pending() and self.launches < max_launches:
            self._admit_due()
            self._shed_hopeless()
            launchable = self._launchable()
            if not launchable:
                if not self._advance():
                    break
                continue
            if self._dirty:
                self.server._apply_shares(self.server.arbiter.split())
                self._dirty = False
            completions.extend(self._launch(self._choose(launchable)))
        if completions:
            self.server.clock = max(self.server.clock, self.now,
                                    max(c.finished for c in completions))
        return completions

    # -- observability / persistence ---------------------------------------
    def metrics(self, registry=None):
        """Server + scheduler state folded into a ``MetricsRegistry``
        (queue-depth gauges, shed/preemption counters, both latency
        clocks).  Render with ``.render()`` (Prometheus text)."""
        from repro.obs.metrics import system_metrics
        return system_metrics(server=self.server, registry=registry,
                              scheduler=self)

    def stats(self) -> dict:
        """Scheduler-level counters (per-tenant SLO outcomes live in
        ``TenantTelemetry``)."""
        return {"launches": self.launches, "sheds": self.sheds,
                "rejections": self.rejections,
                "preemptions": self.preemptions,
                "pending": self.pending(),
                "queue_depths": {name: self.queue_depth(name)
                                 for name in self.slos}}

    def state_dict(self) -> dict:
        """JSON-able SLO state a plan-preserving restart carries: the
        per-tenant specs and the lifetime counters.  Queued requests are
        deliberately NOT snapshotted — in-flight work is lost on a
        crash and the client retries; what must survive is the *plans*
        (see ``runtime/recovery.py``)."""
        return {
            "slos": {name: dataclasses.asdict(spec)
                     for name, spec in self.slos.items()},
            "shed_margin_s": self.shed_margin_s,
            "launches": self.launches, "sheds": self.sheds,
            "rejections": self.rejections,
            "preemptions": self.preemptions,
        }

    def load_state(self, state: dict) -> None:
        """Restore a ``state_dict`` snapshot.  Every snapshotted tenant
        must already be registered with the *server* (the recovery path
        registers tenants there, then re-attaches their SLOs here)."""
        missing = set(state["slos"]) - set(self.server.tenants)
        if missing:
            raise ValueError(f"snapshot covers unregistered tenants: "
                             f"{sorted(missing)}")
        for name, spec in state["slos"].items():
            self.slos[name] = SLOSpec(**spec)
        self.shed_margin_s = float(state.get("shed_margin_s",
                                             self.shed_margin_s))
        self.launches = int(state.get("launches", 0))
        self.sheds = int(state.get("sheds", 0))
        self.rejections = int(state.get("rejections", 0))
        self.preemptions = int(state.get("preemptions", 0))
