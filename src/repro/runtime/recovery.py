"""Plan-preserving failure recovery for the serving runtime.

Wires the previously orphaned fault-tolerance primitives into
``AdaptiveServer``: ``checkpoint/store.py`` persists the serving state,
``fault_tolerance.Watchdog`` detects the death, and this module's
restore path rebuilds a server whose **first post-crash batch re-plans
nothing cold** — the restart storm a naive recovery pays (every tenant's
selector re-running at once) is exactly what a deadline-bound deployment
cannot afford.

What a snapshot preserves (and why):

* tenant params + registration arguments — the checkpointed pytree and
  the ``extra`` manifest; recovery re-registers every tenant in the
  original order (order fixes mesh device slices).
* the **planner memo state** (``core.plan.export_plan_cache``): every
  cached ``NetworkPlan`` with its exact cache key, plus the ``replan``
  fast path's share/fuse memos.  Imported *before* re-registration, so
  even admission re-pricing hits the cache.
* the **arbiter state** (``BudgetArbiter.state_dict``): grants, demand
  and miss-rate EWMAs, un-folded observations.  Restoring grants
  bit-identical is what makes the first batch's slice budget — and
  therefore its plan-cache key — identical to pre-crash.
* the est-cycles clock, the SLO specs and scheduler counters, and the
  **calibration identity** (``calibration_key``) — the table itself is
  NOT serialized; the operator re-supplies it and recovery *validates*
  it against the snapshotted key (a different table would silently
  re-key every cached plan).

What a snapshot deliberately does NOT preserve: queued / in-flight
requests (a crash loses them; clients retry — their wall deadlines
would have expired during the outage anyway), telemetry windows, and
the wall clock (monotonic clocks do not survive a process).

``simulate_worker_death`` models the crash on this single-host runtime:
it clears every in-memory planner memo — the state an actual process
death destroys — so the zero-cold-replan claim is tested against a
genuinely cold process, not a warm cache that happened to survive.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

from repro.core.calibrate_cost import calibration_key
from repro.core.plan import (STATS, clear_plan_cache, export_plan_cache,
                             import_plan_cache)
from repro.core.resources import MeshSpec, ResourceBudget
from repro.checkpoint.store import restore_blind, save
from repro.obs.trace import log_event
from repro.runtime.fault_tolerance import Watchdog
from repro.runtime.scheduler import SLOScheduler
from repro.runtime.server import AdaptiveServer


def _calkey_json(calibration):
    key = calibration_key(calibration)
    return list(key) if key is not None else None


def server_state(server: AdaptiveServer,
                 scheduler: Optional[SLOScheduler] = None) -> Tuple[dict, dict]:
    """(pytree, extra) for ``checkpoint.store.save``: the params tree
    keyed by tenant, and everything else as JSON-able ``extra``."""
    tree = {name: t.params for name, t in server.tenants.items()}
    extra = {
        "server": {
            "budget": dataclasses.asdict(server.budget),
            "policy": server.arbiter.policy,
            "rebalance_threshold": server.arbiter.rebalance_threshold,
            "max_batch": server.max_batch,
            "autotune": server.autotune,
            "interpret": server.interpret,
            "demand_alpha": server.arbiter.demand_alpha,
            "fuse": server.fuse,
            "mesh": (dataclasses.asdict(server.mesh)
                     if server.mesh is not None else None),
            "slo_pressure": server.arbiter.slo_pressure,
            "miss_alpha": server.arbiter.miss_alpha,
            "grant_quantum": server.arbiter.grant_quantum,
        },
        "tenant_order": list(server.tenants),
        "tenants": {
            name: {
                "input_shape": list(t.input_shape),
                "pool_window": list(t.pool_window),
                "activation": t.activation,
                "ladder": list(t.ladder),
                "measure_quant": t.measure_quant,
                "floor": t.floor,
                "unit_cost": t.unit_cost,
            } for name, t in server.tenants.items()
        },
        "arbiter": server.arbiter.state_dict(),
        "guards": {name: dataclasses.asdict(p)
                   for name, p in server._guards.items()},
        "plan_cache": export_plan_cache(),
        "calibration_key": _calkey_json(server.calibration),
        "clock": server.clock,
        "scheduler": scheduler.state_dict() if scheduler else None,
    }
    return tree, extra


def snapshot_server(server: AdaptiveServer, ckpt_dir: str, step: int, *,
                    scheduler: Optional[SLOScheduler] = None,
                    keep: int = 3) -> str:
    """Atomic-commit snapshot of the full serving state."""
    tree, extra = server_state(server, scheduler)
    path = save(ckpt_dir, step, tree, extra=extra, keep=keep)
    log_event("recovery.snapshot", step=step, tenants=len(tree),
              plans=len(extra["plan_cache"]["plans"]))
    return path


def recover_server(ckpt_dir: str, *, step: Optional[int] = None,
                   calibration=None, wall: Optional[Callable] = None,
                   ) -> Tuple[AdaptiveServer, Optional[SLOScheduler]]:
    """Rebuild (server, scheduler-or-None) from the latest committed
    snapshot so the first post-crash batch re-plans nothing cold.

    The restore order is the guarantee: plan-cache import FIRST (so
    re-registration's admission pricing hits the cache), tenants
    re-registered in the original order, then arbiter grants restored
    bit-identical (so the first batch's slice budget keys match).
    ``calibration`` must be the same table the snapshot was taken under
    — validated against the snapshotted ``calibration_key``.
    """
    params, extra = restore_blind(ckpt_dir, step=step)
    snap_key = extra.get("calibration_key")
    live_key = _calkey_json(calibration)
    if snap_key != live_key:
        raise ValueError(
            f"calibration mismatch: snapshot was taken under "
            f"{snap_key}, recovery was handed {live_key} — cached plans "
            f"would re-key cold")
    imported = import_plan_cache(extra["plan_cache"])
    cfg = extra["server"]
    mesh = MeshSpec(**cfg["mesh"]) if cfg["mesh"] is not None else None
    server = AdaptiveServer(
        ResourceBudget(**cfg["budget"]), policy=cfg["policy"],
        rebalance_threshold=cfg["rebalance_threshold"],
        max_batch=cfg["max_batch"], autotune=cfg["autotune"],
        interpret=cfg["interpret"], demand_alpha=cfg["demand_alpha"],
        fuse=cfg["fuse"], calibration=calibration, mesh=mesh,
        slo_pressure=cfg.get("slo_pressure", 0.0),
        miss_alpha=cfg.get("miss_alpha", 0.5),
        grant_quantum=cfg.get("grant_quantum", 0.0))
    for name in extra["tenant_order"]:
        t = extra["tenants"][name]
        tenant = server.register(
            name, params[name], tuple(t["input_shape"]),
            pool_window=tuple(t["pool_window"]),
            activation=t["activation"], ladder=tuple(t["ladder"]),
            measure_quant=t["measure_quant"])
        if abs(tenant.floor - t["floor"]) > 1e-9:
            raise ValueError(
                f"tenant {name!r} floor drifted across restart: "
                f"snapshot {t['floor']:.6f} vs re-priced "
                f"{tenant.floor:.6f}")
    server.arbiter.load_state(extra["arbiter"])
    server._apply_shares(server.arbiter.shares())
    server.clock = float(extra.get("clock", 0.0))
    from repro.runtime.guards import GuardPolicy
    for name, p in extra.get("guards", {}).items():
        server.set_guard(name, GuardPolicy(**p))
    scheduler = None
    if extra.get("scheduler") is not None:
        scheduler = (SLOScheduler(server, wall=wall)
                     if wall is not None else SLOScheduler(server))
        scheduler.load_state(extra["scheduler"])
        scheduler.now = server.clock
    log_event("recovery.restore", tenants=len(extra["tenant_order"]),
              plans_imported=imported,
              cold_plans_during_restore=0)
    return server, scheduler


def simulate_worker_death() -> None:
    """Model a process crash on this single-host runtime: wipe every
    in-memory planner memo (what a real death destroys), so recovery is
    measured against a genuinely cold process."""
    clear_plan_cache()
    log_event("recovery.death", simulated=True)


def cold_replans_since(misses_before: int) -> int:
    """Cold plans since a ``STATS.plan_misses`` reading — the quantity
    the zero-cold-replan guarantee is asserted on."""
    return STATS.plan_misses - misses_before


class RecoveryManager:
    """Watchdog-armed snapshot/restore loop around one server.

    ``beat()`` after every healthy dispatch; a missed heartbeat fires
    ``on_death`` (default: just an event — the harness decides whether
    to restart).  ``snapshot()`` persists, ``recover()`` rebuilds.  The
    manager survives its server: after ``simulate_worker_death`` +
    ``recover()`` it tracks the replacement.
    """

    def __init__(self, server: AdaptiveServer, ckpt_dir: str, *,
                 scheduler: Optional[SLOScheduler] = None,
                 heartbeat_timeout_s: Optional[float] = None,
                 on_death: Optional[Callable[[], None]] = None,
                 keep: int = 3):
        self.server = server
        self.scheduler = scheduler
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._step = 0
        self.watchdog = None
        self._heartbeat_timeout_s = heartbeat_timeout_s

        def _fire():
            log_event("recovery.heartbeat_lost",
                      timeout_s=heartbeat_timeout_s)
            if on_death is not None:
                on_death()
        self._fire = _fire
        if heartbeat_timeout_s is not None:
            self.watchdog = Watchdog(heartbeat_timeout_s, _fire).start()

    def beat(self) -> None:
        if self.watchdog is not None:
            self.watchdog.beat()

    def _rearm_watchdog(self) -> None:
        """Re-arm heartbeat monitoring after an adoption or a degrade:
        a live monitor thread just clears its latched ``fired``
        (``Watchdog.rearm``); a stopped one (the fire-once pattern
        joins its thread inside ``on_timeout``) is replaced — either
        way, a SECOND worker death after one recovery fires again."""
        if self._heartbeat_timeout_s is None:
            return
        wd = self.watchdog
        if wd is not None and wd._thread.is_alive():
            wd.rearm()
            return
        if wd is not None:
            wd.stop()
        self.watchdog = Watchdog(self._heartbeat_timeout_s,
                                 self._fire).start()

    def snapshot(self) -> str:
        self._step += 1
        return snapshot_server(self.server, self.ckpt_dir, self._step,
                               scheduler=self.scheduler, keep=self.keep)

    def recover(self, *, calibration=None,
                wall: Optional[Callable] = None) -> AdaptiveServer:
        """Rebuild from the latest snapshot and adopt the replacement
        (``self.server`` / ``self.scheduler`` point at the new
        instances afterwards).  The heartbeat watchdog is re-armed —
        its ``fired`` latch cleared, its thread restarted if the first
        death stopped it — so a second worker death fires again."""
        self.server, self.scheduler = recover_server(
            self.ckpt_dir, calibration=calibration, wall=wall)
        if self.scheduler is not None:
            self.scheduler.recovery = self
        self._rearm_watchdog()
        return self.server

    def degrade(self, device: Optional[int] = None) -> list:
        """The heartbeat path's lighter-than-restore alternative: treat
        the silence as a lost device, shrink the mesh in place
        (``AdaptiveServer.on_device_loss``), and re-arm the watchdog so
        a SECOND failure still fires.  Returns the affected tenants."""
        affected = self.server.on_device_loss(device)
        self._rearm_watchdog()
        return affected

    def stop(self) -> None:
        if self.watchdog is not None:
            self.watchdog.stop()
