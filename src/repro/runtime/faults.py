"""Deterministic fault injection for the serving runtime.

The paper's resource-driven claim is only credible if the runtime
survives the resources *changing under it* — a mesh device dying, a
kernel launch failing, a collective delivering garbage.  This module is
the chaos half of that claim: a seeded ``FaultInjector`` replays a
declarative fault schedule into well-defined *seams* of the serving
path, so every failure mode the survival machinery (``guards.py``,
``BudgetArbiter.on_device_loss``) must absorb is reproducible
bit-for-bit across runs.

Fault taxonomy (``FAULT_KINDS``) and the seam each fires at:

===================  =========  ==============================================
kind                 seam       effect
===================  =========  ==============================================
``device_loss``      execute    a device index joins ``lost``; any execution
                                whose device slice overlaps it raises
                                ``DeviceLost`` until the control plane shrinks
                                the mesh past it
``kernel_exception`` execute    the batch's kernel launch raises
                                ``InjectedFault``
``budget_shrink``    execute    the server's device budget scales down
                                mid-serving (``AdaptiveServer.on_budget_shrink``)
``nan_output``       output     element ``[0, ...]`` of the batch result
                                becomes NaN (what output screening must catch)
``collective_corrupt``  collective  element ``[0, ...]`` of a sharded
                                execution's gathered result becomes Inf
``latency_spike``    lane       the batch's modeled service cycles multiply
                                by ``param`` (default 4x)
===================  =========  ==============================================

Injection contract (mirrors ``obs.trace.TRACER``): the **disabled path
is bit-transparent** — every seam is one ``INJECTOR.enabled`` attribute
read and one branch; no counters move, no RNG draws, no allocation.
``table_chaos`` asserts a disarmed serving run produces identical
outputs, plans, and cache keys to a never-firing armed run.

Determinism: ``arm(schedule, seed=...)`` resets all per-seam step
counters and seeds one ``random.Random``; a step-triggered spec fires
on the Nth poll of its seam (0-based), a probability-triggered spec
draws from the seeded stream in schedule order — the same schedule and
seed replay the same faults against the same serving trace.

Device-loss simulation: host devices cannot actually die, so the
injector *is* the failure — ``lose()`` marks the index, and
``check_devices`` raises for any execution whose granted slice still
overlaps it.  Convention for the single-host stand-in: lose the
highest device index, so after the arbiter shrinks the pool the
surviving contiguous slices no longer overlap the corpse.
"""
from __future__ import annotations

import dataclasses
import random
from contextlib import contextmanager
from typing import List, Optional, Sequence, Tuple

from repro.obs.trace import log_event

FAULT_KINDS = ("device_loss", "kernel_exception", "collective_corrupt",
               "nan_output", "latency_spike", "budget_shrink")

# kind -> the seam whose poll it answers to
SEAM_OF = {
    "device_loss": "execute",
    "kernel_exception": "execute",
    "budget_shrink": "execute",
    "nan_output": "output",
    "collective_corrupt": "collective",
    "latency_spike": "lane",
}


class InjectedFault(RuntimeError):
    """An injected failure surfacing where the real one would."""


class DeviceLost(InjectedFault):
    """An execution's device slice overlaps a lost device."""

    def __init__(self, message: str, device: Optional[int] = None):
        super().__init__(message)
        self.device = device


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One declarative fault: *what* (``kind``), *when* (``step`` = fire
    on the Nth poll of the kind's seam, 0-based — or ``p`` = seeded
    per-poll probability), *whom* (``tenant``, None = any), and a
    kind-specific ``param`` (device index / latency factor / budget
    fraction).  ``once=True`` retires the spec after its first fire, so
    a guarded retry of the same batch passes."""

    kind: str
    step: Optional[int] = None
    p: float = 0.0
    tenant: Optional[str] = None
    once: bool = True
    param: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"have {FAULT_KINDS}")
        if self.step is None and self.p <= 0.0:
            raise ValueError("a FaultSpec needs step= (deterministic) "
                             "or p= (seeded probability)")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError("p must be in [0, 1]")


class FaultInjector:
    """The process-wide injection switchboard (singleton ``INJECTOR``).

    Disabled by default; ``arm(schedule, seed=)`` enables it for the
    given schedule, ``disarm()`` restores the transparent state.  All
    mutable state — per-seam step counters, the retired-spec mask, the
    lost-device set, the fired log — only ever changes while enabled.
    """

    def __init__(self):
        self.enabled = False
        self._specs: Tuple[FaultSpec, ...] = ()
        self._live: List[bool] = []
        self._counters: dict = {}
        self._rng: Optional[random.Random] = None
        self.lost: set = set()
        self.fired: List[tuple] = []   # (kind, seam, step, tenant)

    def arm(self, schedule: Sequence[FaultSpec], *, seed: int = 0) -> None:
        specs = tuple(schedule)
        for s in specs:
            if not isinstance(s, FaultSpec):
                raise TypeError(f"schedule entries must be FaultSpec, "
                                f"got {type(s)!r}")
        self._specs = specs
        self._live = [True] * len(specs)
        self._counters = {}
        self._rng = random.Random(seed)
        self.lost = set()
        self.fired = []
        self.enabled = bool(specs)

    def disarm(self) -> None:
        self.enabled = False
        self._specs = ()
        self._live = []
        self._counters = {}
        self._rng = None
        self.lost = set()
        self.fired = []

    @contextmanager
    def armed(self, schedule: Sequence[FaultSpec], *, seed: int = 0):
        self.arm(schedule, seed=seed)
        try:
            yield self
        finally:
            self.disarm()

    def counters(self) -> dict:
        """Per-seam poll counts (empty while the injector has never been
        armed — the transparency tests assert exactly that)."""
        return dict(self._counters)

    # -- the seam protocol --------------------------------------------------
    def poll(self, seam: str, tenant: Optional[str] = None
             ) -> List[FaultSpec]:
        """Advance ``seam``'s step counter and return the specs due at
        this poll (matching seam + tenant filter + trigger).  Each fire
        is logged as a ``fault.injected`` event; ``once`` specs retire."""
        if not self.enabled:
            return []
        step = self._counters.get(seam, 0)
        self._counters[seam] = step + 1
        due: List[FaultSpec] = []
        for i, spec in enumerate(self._specs):
            if not self._live[i] or SEAM_OF[spec.kind] != seam:
                continue
            if (spec.tenant is not None and tenant is not None
                    and spec.tenant != tenant):
                continue
            if spec.step is not None:
                hit = spec.step == step
            else:
                hit = self._rng.random() < spec.p
            if not hit:
                continue
            if spec.once:
                self._live[i] = False
            self.fired.append((spec.kind, seam, step, tenant))
            log_event("fault.injected", fault=spec.kind, seam=seam,
                      step=step, tenant=tenant or "", param=spec.param)
            due.append(spec)
        return due

    # -- device-loss simulation ---------------------------------------------
    def lose(self, device: int) -> None:
        """Mark one device index dead (the ``device_loss`` effect)."""
        self.lost.add(int(device))

    def check_devices(self, start: int, stop: int) -> None:
        """Raise ``DeviceLost`` when the [start, stop) device slice an
        execution is about to run on overlaps a lost device — the
        single-host stand-in for the launch failing on the dead chip."""
        if not self.lost:
            return
        hit = sorted(d for d in self.lost if start <= d < stop)
        if hit:
            raise DeviceLost(
                f"device(s) {hit} lost; execution slice [{start}, {stop}) "
                f"still overlaps the corpse — shrink the mesh "
                f"(on_device_loss) before retrying", device=hit[-1])

    # -- output perturbation --------------------------------------------------
    def perturb_output(self, seam: str, y, tenant: Optional[str] = None):
        """``nan_output`` / ``collective_corrupt``: poison element
        ``[0, ...]`` of the result due at this poll of ``seam`` (NaN for
        the output seam, Inf for the collective seam)."""
        for spec in self.poll(seam, tenant):
            val = float("nan") if spec.kind == "nan_output" else float("inf")
            y = y.at[(0,) * y.ndim].set(val)
        return y

    def scale_latency(self, cycles: float,
                      tenant: Optional[str] = None) -> float:
        """``latency_spike``: multiply a batch's modeled service cycles
        by the spec's ``param`` (default 4x)."""
        for spec in self.poll("lane", tenant):
            cycles *= spec.param if spec.param > 0 else 4.0
        return cycles


INJECTOR = FaultInjector()
