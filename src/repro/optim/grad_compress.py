"""Gradient compression for the cross-pod all-reduce.

Two composable schemes, both with error feedback (the residual from
this step's quantization is added into the next step's gradient, so
compression error doesn't bias the trajectory — Seide et al. / EF-SGD):

  * int8 uniform quantization (4x over f32 on the wire)
  * top-k magnitude sparsification (k as a fraction)

``compress/decompress`` are pure jittable functions; ``EFState`` holds
the per-leaf residual and shards exactly like the grads.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any


def init_ef_state(grads) -> EFState:
    return EFState(jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                grads))


# ---------------------------------------------------------------------------
# int8 uniform quantization
# ---------------------------------------------------------------------------
def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# top-k sparsification
# ---------------------------------------------------------------------------
def topk_mask(x: jnp.ndarray, frac: float) -> jnp.ndarray:
    k = max(int(x.size * frac), 1)
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


# ---------------------------------------------------------------------------
# error-feedback wrapper
# ---------------------------------------------------------------------------
def compress_grads(grads, ef: EFState, *, scheme: str = "int8",
                   topk_frac: float = 0.1):
    """Returns (wire_grads, new_ef).  wire_grads is what crosses the pod
    link (int8 payloads or sparsified f32); callers all-reduce it and
    apply.  EF residual = (true - wire) accumulates locally."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if scheme == "int8":
            q, scale = quantize_int8(gf)
            wire = dequantize_int8(q, scale)
        elif scheme == "topk":
            wire = gf * topk_mask(gf, topk_frac)
        elif scheme == "int8_topk":
            m = topk_mask(gf, topk_frac)
            q, scale = quantize_int8(gf * m)
            wire = dequantize_int8(q, scale)
        else:
            raise ValueError(scheme)
        return wire.astype(g.dtype), gf - wire

    out = jax.tree.map(one, grads, ef.residual)
    wire = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    resid = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return wire, EFState(resid)


def wire_bytes(grads, scheme: str = "int8", topk_frac: float = 0.1) -> int:
    """Bytes a scheme puts on the cross-pod link (for the roofline)."""
    total = 0
    for g in jax.tree.leaves(grads):
        if scheme == "int8":
            total += g.size  # 1 byte/elem + negligible scales
        elif scheme == "topk":
            total += int(g.size * topk_frac) * 8  # value+index
        elif scheme == "int8_topk":
            total += int(g.size * topk_frac) * 5
        else:
            total += g.size * 4
    return total
