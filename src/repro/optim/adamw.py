"""AdamW with configurable moment dtype (bf16 moments for the >100B
archs — the large-scale memory policy recorded in DESIGN.md) + global
grad-norm clipping + linear-warmup cosine schedule.

Functional, pytree-shaped like the params: opt state shards exactly as
the params do under pjit (ZeRO-1-equivalent for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jnp.ndarray


def init_opt_state(cfg: AdamWConfig, params) -> OptState:
    md = jnp.dtype(cfg.moment_dtype)
    z = lambda p: jnp.zeros_like(p, dtype=md)
    return OptState(mu=jax.tree.map(z, params), nu=jax.tree.map(z, params),
                    step=jnp.zeros((), jnp.int32))


def lr_at(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        tree), norm


def apply_updates(cfg: AdamWConfig, params, grads, state: OptState
                  ) -> Tuple[Any, OptState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    md = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mhat = m32 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m32.astype(md), v32.astype(md)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(new_mu, new_nu, step), {
        "grad_norm": gnorm, "lr": lr}
