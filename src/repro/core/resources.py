"""TPU resource model — the target-hardware vector the selector adapts to.

The paper adapts convolution IPs to the FPGA resource vector
(DSP slices, LUT/CLB fabric, BRAM).  On TPU v5e the analogous vector is
(MXU passes, VPU ops, VMEM bytes, HBM bytes/bandwidth, ICI bandwidth).
``ResourceBudget`` is the machine-readable "available resources" a
deployment hands to the selector; ``Footprint`` is what one kernel IP
costs against that budget for a concrete shape.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# ---------------------------------------------------------------------------
# TPU v5e hardware constants (per chip).  These are the numbers the roofline
# analysis and the selector cost model share; keep them in one place.
# ---------------------------------------------------------------------------
PEAK_BF16_FLOPS = 197e12          # bf16 MXU peak, FLOP/s
PEAK_INT8_OPS = 394e12            # int8 MXU peak, OP/s (2x bf16)
HBM_BYTES = 16 * 1024**3          # 16 GiB HBM
HBM_BW = 819e9                    # bytes/s
VMEM_BYTES = 128 * 1024 * 1024    # ~128 MiB vector memory
ICI_BW_PER_LINK = 50e9            # bytes/s per ICI link (given)
ICI_LINKS = 4                     # v5e 2D torus: 4 links/chip
VPU_LANES = 8 * 128               # (8, 128) vector registers
VPU_OPS_PER_CYCLE = 4 * VPU_LANES # 4 ALUs per lane pair (approx)
CLOCK_HZ = 940e6                  # v5e core clock
MXU_DIM = 128                     # systolic array is 128x128
LANE = 128                        # last-dim tile
SUBLANE = 8                       # second-to-last-dim tile (fp32)
# Collective pricing unit: bytes one ICI link moves per core cycle —
# what a sharded site's collective traffic is converted to cycles with
# (the FPGA analogy is the inter-board serial links of a multi-FPGA
# deployment; a deployment with slower links overrides it per MeshSpec).
ICI_BYTES_PER_CYCLE = ICI_BW_PER_LINK / CLOCK_HZ


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """A device mesh the planner may spread one plan across.

    The paper sizes one network against ONE fabric; the scale-out story
    (multi-FPGA boards, TPU slices) offers ``devices`` identical fabrics
    joined by links of finite bandwidth.  ``MeshSpec`` is the planner's
    view of that grant: how many devices, the mesh-axis name execution
    shards over, and the link bandwidth collective traffic is priced at
    (``ici_bytes_per_cycle``; cycles here are core cycles, the same unit
    as ``Footprint.est_cycles``).  Hashable — it participates in plan
    cache keys.
    """

    devices: int = 1
    axis: str = "shard"
    ici_bytes_per_cycle: float = ICI_BYTES_PER_CYCLE

    def __post_init__(self):
        if self.devices < 1:
            raise ValueError(f"mesh needs >= 1 device, got {self.devices}")
        if self.ici_bytes_per_cycle <= 0.0:
            raise ValueError("ici_bytes_per_cycle must be positive")

    def ici_cycles(self, n_bytes: float) -> float:
        """Cycles to move ``n_bytes`` across one link."""
        return n_bytes / self.ici_bytes_per_cycle

    def all_gather_cycles(self, n_bytes: float) -> float:
        """Ring all-gather of a tensor of GLOBAL size ``n_bytes``: each
        device receives the (devices-1)/devices of it that it does not
        already hold."""
        d = self.devices
        if d <= 1:
            return 0.0
        return self.ici_cycles(n_bytes * (d - 1) / d)

    def all_reduce_cycles(self, n_bytes: float) -> float:
        """Ring all-reduce (reduce-scatter + all-gather) of a tensor of
        size ``n_bytes``: 2 * (d-1)/d of it crosses each link — the cost
        a channel-split conv pays to sum its partial outputs."""
        d = self.devices
        if d <= 1:
            return 0.0
        return self.ici_cycles(2.0 * n_bytes * (d - 1) / d)

    def halo_cycles(self, n_bytes: float) -> float:
        """Neighbor exchange of ``n_bytes`` of boundary rows — what a
        spatial conv split pays per step (both edges move in parallel
        over distinct links, so one halo's bytes price the exchange)."""
        if self.devices <= 1:
            return 0.0
        return self.ici_cycles(n_bytes)


@dataclasses.dataclass(frozen=True)
class ResourceBudget:
    """Available resources a kernel IP may consume — the paper's
    "available FPGA resources", TPU-native.

    ``mxu_available`` mirrors "DSP availability": a deployment where the
    MXU is saturated by co-resident ops (or absent, e.g. pure-VPU debug
    paths) sets it False, steering the selector to Conv1-style logic-only
    variants.  ``precision_bits`` mirrors the paper's operand-width limits
    (Conv3 is only legal up to 8-bit operands).
    """

    vmem_bytes: int = VMEM_BYTES
    hbm_bytes: int = HBM_BYTES
    mxu_available: bool = True
    mxu_passes_budget: Optional[int] = None   # None = unlimited
    vpu_ops_budget: Optional[int] = None      # None = unlimited
    precision_bits: int = 16                  # max operand width required
    prefer_parallel_streams: bool = False     # paper: "demand high parallelism"

    def scaled(self, fraction: float) -> "ResourceBudget":
        """A fractional slice of this budget (e.g. per co-resident op).

        Every *quantitative* column scales — capacity (vmem/hbm) and the
        optional pass/op ceilings alike; the qualitative knobs
        (mxu_available, precision_bits, prefer_parallel_streams) describe
        the deployment, not an amount, and pass through unchanged.  The
        network planner's budget partitioning depends on the ceilings
        scaling with the slice.
        """
        def _slice(v):
            return None if v is None else int(v * fraction)

        return dataclasses.replace(
            self,
            vmem_bytes=int(self.vmem_bytes * fraction),
            hbm_bytes=int(self.hbm_bytes * fraction),
            mxu_passes_budget=_slice(self.mxu_passes_budget),
            vpu_ops_budget=_slice(self.vpu_ops_budget),
        )


@dataclasses.dataclass(frozen=True)
class Footprint:
    """What one IP costs for one concrete call — paper Table II, machine-readable.

    FPGA column mapping: DSPs -> mxu_passes, LUTs/CLBs -> vpu_ops,
    BRAM -> vmem_bytes, DDR traffic -> hbm_bytes, WNS -> est_cycles
    (the timing-role metric), convs/cycle -> outputs_per_pass.
    """

    vmem_bytes: int
    hbm_bytes: int
    mxu_passes: int
    vpu_ops: int
    est_cycles: float
    outputs_per_pass: int = 1       # Conv3/Conv4 produce 2 convolutions/pass
    max_operand_bits: int = 32      # Conv3 is limited to 8
    launches: int = 1               # pallas_call launches per invocation;
                                    # a fused conv->pool->act member is 1
                                    # where the unfused chain costs 3
    comm_cycles: float = 0.0        # collective traffic a sharded site
                                    # pays (ICI cycles; 0 for the
                                    # single-device footprints families
                                    # price) — folded into est_cycles

    @property
    def compute_cycles(self) -> float:
        """The compute term of the additive ``cost_cycles`` split:
        ``est_cycles`` minus the DMA cycles its ``hbm_bytes`` price in
        and minus its collective ``comm_cycles`` (clamped at zero for
        footprints priced under an older rule).  These are the
        analytical axes the measurement-calibrated cost model
        (``core/calibrate_cost.py``) regresses over."""
        return max(self.est_cycles - hbm_cycles(self.hbm_bytes)
                   - self.comm_cycles, 0.0)

    def calibrated_cycles(self, calibration, member: str) -> float:
        """This footprint's cost under a measurement-derived
        ``CalibrationTable`` (cycle units; ``member`` is the calibration
        key, see ``calibrate_cost.member_key``).  ``calibration=None``
        is the identity: the analytical ``est_cycles``."""
        if calibration is None:
            return self.est_cycles
        return calibration.calibrated_cycles(self, member)

    def fits(self, budget: ResourceBudget) -> bool:
        if self.vmem_bytes > budget.vmem_bytes:
            return False
        if self.hbm_bytes > budget.hbm_bytes:
            return False
        if self.mxu_passes > 0 and not budget.mxu_available:
            return False
        if (budget.mxu_passes_budget is not None
                and self.mxu_passes > budget.mxu_passes_budget):
            return False
        if (budget.vpu_ops_budget is not None
                and self.vpu_ops > budget.vpu_ops_budget):
            return False
        if budget.precision_bits > self.max_operand_bits:
            return False
        return True


def cost_cycles(compute_cycles: float, hbm_bytes: int,
                comm_cycles: float = 0.0) -> float:
    """The shared est-cycles rule every footprint prices with: a kernel
    launch pays its compute AND its DMA traffic AND (for sharded sites)
    its collective traffic.

    The earlier model took ``max(compute, dma)`` (perfect overlap), which
    made HBM round-trips free whenever compute dominated — exactly the
    traffic layer fusion removes.  Accounting DMA bytes additively is the
    conservative serial model (the paper's DDR-traffic column is a cost
    column, not an overlap hint), and it is what lets a fused
    conv->pool->act member's saved intermediate reads+writes show up as
    a counted est-cycles drop (docs/adaptive_ips.md, "Fusion contract").
    ``comm_cycles`` extends the same serial rule to collectives: a
    sharded site pays its halo/psum/all-gather bytes at the mesh's link
    bandwidth (docs/adaptive_ips.md, "Sharding contract").
    """
    return compute_cycles + hbm_cycles(hbm_bytes) + comm_cycles


def mxu_pass_cycles(m: int, k: int, n: int) -> float:
    """Cycles for an (m,k)x(k,n) matmul streamed through the 128x128 MXU."""
    import math
    tiles = (math.ceil(m / MXU_DIM) * math.ceil(k / MXU_DIM)
             * math.ceil(n / MXU_DIM))
    return tiles * MXU_DIM  # one column of results per cycle per tile


def vpu_op_cycles(n_ops: int) -> float:
    """Cycles for ``n_ops`` scalar-equivalent elementwise ops on the VPU."""
    return n_ops / VPU_OPS_PER_CYCLE


def hbm_cycles(n_bytes: int) -> float:
    """Cycles to move ``n_bytes`` HBM<->VMEM at full bandwidth."""
    return n_bytes / HBM_BW * CLOCK_HZ
