"""Resource-driven IP selection — compatibility shims over the engine.

The selection engine (feasibility + the paper's tie-break ranking) now
lives in ``core/plan.py`` as one generic ``select_ip(family, spec,
budget)`` driven by the per-family site adapters registered in
``core/library.py``.  The five historical per-family entry points below
are thin shims that build a ``SiteSpec`` and defer — kept because they
are a pleasant calling convention at a single call site; anything
mapping more than one op should build a ``NetworkPlan``
(``core/plan.py::plan_network``) so the ops share a partitioned budget
instead of each seeing the full one.

All of this is trace-time Python (never inside jit): callers invoke the
returned KernelIP's ``.impl`` directly (see the per-family
``kernels/<family>/ops.py`` wrappers) or record it into a plan rendered
by ``describe_plan``.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.ip import SiteSpec
from repro.core.plan import select_ip
from repro.core.resources import ResourceBudget


def select_conv_ip(x_shape, w_shape, *, dual: bool, dtype=jnp.int8,
                   budget: Optional[ResourceBudget] = None,
                   with_footprint: bool = False):
    spec = SiteSpec.make("conv2d", "conv2d", (x_shape, w_shape), dtype,
                         dual=dual)
    return select_ip("conv2d", spec, budget=budget,
                     with_footprint=with_footprint)


def select_pool_ip(x_shape, *, window=(2, 2), stride=None, mode: str = "max",
                   dtype=jnp.int8,
                   budget: Optional[ResourceBudget] = None,
                   with_footprint: bool = False):
    spec = SiteSpec.make("pool2d", "pool2d", (x_shape,), dtype,
                         window=window, stride=stride, mode=mode)
    return select_ip("pool2d", spec, budget=budget,
                     with_footprint=with_footprint)


def select_activation_ip(x_shape, *, kind: str = "relu", dtype=jnp.float32,
                         budget: Optional[ResourceBudget] = None,
                         with_footprint: bool = False):
    spec = SiteSpec.make("activation", "activation", (x_shape,), dtype,
                         kind=kind)
    return select_ip("activation", spec, budget=budget,
                     with_footprint=with_footprint)


def select_matmul_ip(a_shape, b_shape, *, dual: bool, dtype=jnp.bfloat16,
                     budget: Optional[ResourceBudget] = None,
                     with_footprint: bool = False):
    spec = SiteSpec.make("matmul", "matmul", (a_shape, b_shape), dtype,
                         dual=dual)
    return select_ip("matmul", spec, budget=budget,
                     with_footprint=with_footprint)


def select_attention_ip(q_shape, kv_shape, *,
                        budget: Optional[ResourceBudget] = None,
                        dtype=jnp.bfloat16, with_footprint: bool = False):
    spec = SiteSpec.make("attention", "attention", (q_shape, kv_shape), dtype)
    return select_ip("attention", spec, budget=budget,
                     with_footprint=with_footprint)


def describe_plan(plan) -> str:
    """Render a layer->IP assignment map (used by examples & benches).

    Accepts either an ad-hoc ``{site: (ip, fp)}`` dict or a
    ``NetworkPlan`` (whose ``.describe()`` additionally shows the budget
    fraction each site was granted).
    """
    lines = []
    for site, (ip, fp) in plan.items():
        lines.append(f"{site:<40s} -> {ip.name:<28s} "
                     f"vmem={fp.vmem_bytes/2**20:7.2f}MiB "
                     f"mxu={fp.mxu_passes:<8d} vpu={fp.vpu_ops:.2e} "
                     f"cyc={fp.est_cycles:.3e}")
    return "\n".join(lines)
