"""Resource-driven IP selection — the paper's thesis as code.

Given the op, the concrete shape, and a ResourceBudget (the "available
FPGA resources"), pick the library member that (a) is *feasible* under
the budget — fits VMEM, respects the precision ceiling, does not touch
the MXU if the MXU is spoken for — and (b) minimizes estimated cycles
among the feasible set, with the paper's tie-breaks:

  * prefer_parallel_streams -> prefer outputs_per_pass==2 (Conv3/Conv4);
  * a tight mxu_passes_budget prefers fewer MXU passes (Conv1/Conv3);
  * a tight vpu_ops_budget prefers DSP-style members (Conv2/Conv4).

This module is deliberately small and pure: it is called at trace time
(never inside jit) and returns a KernelIP whose `.impl` the caller then
invokes directly (see the per-family ``kernels/<family>/ops.py``
wrappers) or records into a plan rendered by ``describe_plan``.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax.numpy as jnp

from repro.core.ip import KernelIP
from repro.core.library import ACTIVATION, ATTENTION, CONV2D, MATMUL, POOL2D
from repro.core.resources import Footprint, ResourceBudget


def _dtype_bits(dtype) -> int:
    return jnp.dtype(dtype).itemsize * 8


def _rank(ip: KernelIP, fp: Footprint, budget: ResourceBudget):
    """Ranking key: (primary cost, tie-breaks). Lower is better."""
    parallel_bonus = 0
    if budget.prefer_parallel_streams:
        parallel_bonus = 0 if fp.outputs_per_pass >= 2 else 1
    mxu_pressure = 0.0
    if budget.mxu_passes_budget is not None and budget.mxu_passes_budget > 0:
        mxu_pressure = fp.mxu_passes / budget.mxu_passes_budget
    vpu_pressure = 0.0
    if budget.vpu_ops_budget is not None and budget.vpu_ops_budget > 0:
        vpu_pressure = fp.vpu_ops / budget.vpu_ops_budget
    # Normalize per produced output so dual-stream members aren't
    # penalized for doing two ops' work.
    cycles = fp.est_cycles / max(fp.outputs_per_pass, 1)
    return (parallel_bonus, cycles * (1.0 + mxu_pressure + vpu_pressure),
            fp.vmem_bytes)


def _select(candidates: Sequence[KernelIP], budget: ResourceBudget,
            fp_args: tuple, fp_kwargs: dict, op_bits: int):
    """Returns the winning (KernelIP, Footprint) pair."""
    feasible = []
    for ip in candidates:
        fp = ip.footprint(*fp_args, **fp_kwargs)
        if op_bits > fp.max_operand_bits:
            continue
        if not fp.fits(budget):
            continue
        feasible.append((_rank(ip, fp, budget), ip.name, ip, fp))
    if not feasible:
        raise ValueError(
            "no feasible IP under budget "
            f"{budget} for shape args {fp_args} (operand bits {op_bits}); "
            f"candidates: {[c.name for c in candidates]}")
    feasible.sort(key=lambda t: t[:2])
    return feasible[0][2], feasible[0][3]


# --------------------------------------------------------------------------
# conv2d
# --------------------------------------------------------------------------
def select_conv_ip(x_shape, w_shape, *, dual: bool, dtype=jnp.int8,
                   budget: Optional[ResourceBudget] = None,
                   with_footprint: bool = False):
    budget = budget or ResourceBudget()
    n, h, w_, cin = x_shape
    kh, kw, _, cout = w_shape
    itemsize = jnp.dtype(dtype).itemsize
    want = {True: ("conv2d.ip3_packed", "conv2d.ip4_dual"),
            False: ("conv2d.ip1_vpu", "conv2d.ip2_mxu")}[dual]
    cands = [CONV2D[name] for name in want]
    ip, fp = _select(cands, budget, (n, h, w_, cin, kh, kw, cout),
                     {"itemsize": itemsize}, op_bits=_dtype_bits(dtype))
    return (ip, fp) if with_footprint else ip


# --------------------------------------------------------------------------
# pool2d
# --------------------------------------------------------------------------
def select_pool_ip(x_shape, *, window=(2, 2), stride=None, mode: str = "max",
                   dtype=jnp.int8,
                   budget: Optional[ResourceBudget] = None,
                   with_footprint: bool = False):
    from repro.kernels.pool2d.ref import check_pool_geometry

    budget = budget or ResourceBudget()
    (kh, kw), (sh, sw) = check_pool_geometry(x_shape, window, stride)
    n, h, w_, c = x_shape
    itemsize = jnp.dtype(dtype).itemsize
    cands = [POOL2D["pool2d.pool_vpu"], POOL2D["pool2d.pool_im2col"]]
    ip, fp = _select(cands, budget, (n, h, w_, c, kh, kw, sh, sw),
                     {"itemsize": itemsize, "mode": mode},
                     op_bits=_dtype_bits(dtype))
    return (ip, fp) if with_footprint else ip


# --------------------------------------------------------------------------
# activation
# --------------------------------------------------------------------------
def select_activation_ip(x_shape, *, kind: str = "relu", dtype=jnp.float32,
                         budget: Optional[ResourceBudget] = None,
                         with_footprint: bool = False):
    from repro.kernels.activation.lut_poly import SUPPORTED_KINDS as LUT_KINDS

    budget = budget or ResourceBudget()
    n_elems = int(math.prod(int(d) for d in x_shape))
    itemsize = jnp.dtype(dtype).itemsize
    cands = [ACTIVATION["activation.act_vpu"]]
    if kind in LUT_KINDS:   # capability filter: LUT is constant-off-range
        cands.append(ACTIVATION["activation.act_lut"])
    # Activation IPs re-encode their input (the LUT member quantizes on
    # ingest), so the caller's dtype imposes no operand-width floor; the
    # precision the deployment demands is budget.precision_bits, which
    # Footprint.fits checks against each member's 8/32-bit ceiling.
    ip, fp = _select(cands, budget, (n_elems,),
                     {"itemsize": itemsize, "kind": kind}, op_bits=0)
    return (ip, fp) if with_footprint else ip


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------
def select_matmul_ip(a_shape, b_shape, *, dual: bool, dtype=jnp.bfloat16,
                     budget: Optional[ResourceBudget] = None,
                     with_footprint: bool = False):
    budget = budget or ResourceBudget()
    m, k = a_shape[-2], a_shape[-1]
    n = b_shape[-1]
    itemsize = jnp.dtype(dtype).itemsize
    want = {True: ("matmul.mm_dual_shared", "matmul.mm_dual_full"),
            False: ("matmul.mm_vpu", "matmul.mm_mxu")}[dual]
    cands = [MATMUL[name] for name in want]
    ip, fp = _select(cands, budget, (m, k, n), {"itemsize": itemsize},
                     op_bits=_dtype_bits(dtype))
    return (ip, fp) if with_footprint else ip


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def select_attention_ip(q_shape, kv_shape, *,
                        budget: Optional[ResourceBudget] = None,
                        dtype=jnp.bfloat16, with_footprint: bool = False):
    budget = budget or ResourceBudget()
    b, hq, sq, d = q_shape
    _, hkv, skv, _ = kv_shape
    itemsize = jnp.dtype(dtype).itemsize
    if sq == 1:
        cands = [ATTENTION["attention.attn_decode"]]
        args = (b, hq, hkv, skv, d)
    else:
        cands = [ATTENTION["attention.attn_naive"],
                 ATTENTION["attention.attn_flash"]]
        args = (b, hq, hkv, sq, skv, d)
    ip, fp = _select(cands, budget, args, {"itemsize": itemsize},
                     op_bits=_dtype_bits(dtype))
    return (ip, fp) if with_footprint else ip


def describe_plan(plan) -> str:
    """Render a layer->IP assignment map (used by examples & benches)."""
    lines = []
    for site, (ip, fp) in plan.items():
        lines.append(f"{site:<40s} -> {ip.name:<28s} "
                     f"vmem={fp.vmem_bytes/2**20:7.2f}MiB "
                     f"mxu={fp.mxu_passes:<8d} vpu={fp.vpu_ops:.2e} "
                     f"cyc={fp.est_cycles:.3e}")
    return "\n".join(lines)
