"""The adaptive IP library — paper Table I, machine-readable.

Families: conv2d is the paper's literal object; pool2d and activation
close its stated future work ("expand the library to include pooling
and activation functions"); matmul, attention, and ssm_scan are its
generalization to the assigned LM architectures.  Every member carries
the Table I capability bits and a footprint function pricing it against
the TPU resource vector.  The registration contract is documented in
docs/adaptive_ips.md.
"""
from __future__ import annotations

from repro.core.ip import IPFamily, KernelIP
from repro.kernels.conv2d import ip1_vpu, ip2_mxu, ip3_packed, ip4_dual
from repro.kernels.conv2d.ref import conv2d_ref
from repro.kernels.pool2d import mxu_im2col as pool_im2col_mod
from repro.kernels.pool2d import vpu_window as pool_vpu_mod
from repro.kernels.pool2d.ref import pool2d_ref
from repro.kernels.activation import lut_poly as act_lut_mod
from repro.kernels.activation import vpu_exact as act_exact_mod
from repro.kernels.activation.ref import activation_ref
from repro.kernels.matmul import dual as mm_dual
from repro.kernels.matmul import mxu as mm_mxu_mod
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.attention import decode as attn_decode_mod
from repro.kernels.attention import flash as attn_flash_mod
from repro.kernels.attention.ref import attention_ref

# --------------------------------------------------------------------------
# conv2d family — the paper's four IPs.
# --------------------------------------------------------------------------
CONV2D = IPFamily("conv2d", reference=conv2d_ref)
CONV2D.register(KernelIP(
    name="conv2d.ip1_vpu", family="conv2d", impl=ip1_vpu.conv2d_ip1,
    footprint_fn=ip1_vpu.footprint, uses_mxu=False, max_operand_bits=32,
    outputs_per_pass=1, tags=("paper:Conv1", "logic-only"),
    description="No DSP/MXU; one convolution per pass; high vector logic."))
CONV2D.register(KernelIP(
    name="conv2d.ip2_mxu", family="conv2d", impl=ip2_mxu.conv2d_ip2,
    footprint_fn=ip2_mxu.footprint, uses_mxu=True, max_operand_bits=32,
    outputs_per_pass=1, tags=("paper:Conv2",),
    description="One MXU pass per tile; minimal vector logic."))
CONV2D.register(KernelIP(
    name="conv2d.ip3_packed", family="conv2d", impl=ip3_packed.conv2d_ip3,
    footprint_fn=ip3_packed.footprint, uses_mxu=False, max_operand_bits=8,
    outputs_per_pass=2, supports_dtypes=("int8",),
    tags=("paper:Conv3", "packed", "dual-stream"),
    description="Operand packing: two 8-bit convolutions per multiplier."))
CONV2D.register(KernelIP(
    name="conv2d.ip4_dual", family="conv2d", impl=ip4_dual.conv2d_ip4,
    footprint_fn=ip4_dual.footprint, uses_mxu=True, max_operand_bits=32,
    outputs_per_pass=2, tags=("paper:Conv4", "dual-stream"),
    description="Two parallel convolutions via dual MXU passes; full precision."))

# --------------------------------------------------------------------------
# pool2d family — the paper's future-work coverage: same resource split as
# Conv1/Conv2 (logic-only windowed reduce vs im2col + one MXU pass).
# --------------------------------------------------------------------------
POOL2D = IPFamily("pool2d", reference=pool2d_ref)
POOL2D.register(KernelIP(
    name="pool2d.pool_vpu", family="pool2d", impl=pool_vpu_mod.pool2d_window,
    footprint_fn=pool_vpu_mod.footprint, uses_mxu=False,
    tags=("analogue:Conv1", "windowed-reduce"),
    description="Unrolled strided-slice window reduce; pure VPU, "
                "minimal VMEM."))
POOL2D.register(KernelIP(
    name="pool2d.pool_im2col", family="pool2d",
    impl=pool_im2col_mod.pool2d_im2col,
    footprint_fn=pool_im2col_mod.footprint, uses_mxu=True,
    tags=("analogue:Conv2", "im2col"),
    description="Patch tensor in VMEM; avg collapses to one MXU pass, "
                "max to one vectorized reduce."))

# --------------------------------------------------------------------------
# activation family — exact transcendental vs the paper's fixed-point
# spirit (256-entry LUT over the saturation range, 8-bit operand ceiling).
# --------------------------------------------------------------------------
ACTIVATION = IPFamily("activation", reference=activation_ref)
ACTIVATION.register(KernelIP(
    name="activation.act_vpu", family="activation",
    impl=act_exact_mod.activation_exact,
    footprint_fn=act_exact_mod.footprint, uses_mxu=False,
    tags=("exact",),
    description="Exact float32 transcendental on the VPU; full precision, "
                "high op count for tanh/gelu."))
ACTIVATION.register(KernelIP(
    name="activation.act_lut", family="activation",
    impl=act_lut_mod.activation_lut,
    footprint_fn=act_lut_mod.footprint, uses_mxu=False,
    max_operand_bits=8, supports_dtypes=("int8", "bfloat16", "float32"),
    tags=("fixed-point", "lut"),
    description="256-entry LUT over the saturation range; ~4 VPU ops and "
                "1-byte streaming per element; saturating kinds only."))

# --------------------------------------------------------------------------
# cnn_fused family — conv -> pool -> activation as ONE launch (the paper's
# future-work integration of pooling/activation with the conv IPs).  One
# member per conv IP style; the planner substitutes a fused site for a
# fusable conv/pool/act triple when the combined footprint fits and wins
# (core/plan.py, fuse=True).
# --------------------------------------------------------------------------
from repro.kernels.fused import cnn_block as fused_mod  # noqa: E402


def _fused_ref(x, w, *, window=(2, 2), stride=None, mode="max",
               kind="relu"):
    """Composite oracle: the three family references chained."""
    return activation_ref(
        pool2d_ref(conv2d_ref(x, w), window=window, stride=stride,
                   mode=mode), kind=kind)


CNN_FUSED = IPFamily("cnn_fused", reference=_fused_ref,
                     fuses=("conv2d", "pool2d", "activation"))
CNN_FUSED.register(KernelIP(
    name="cnn_fused.fused_vpu", family="cnn_fused",
    impl=fused_mod.fused_cnn_vpu, footprint_fn=fused_mod.footprint_vpu,
    uses_mxu=False, tags=("fused", "analogue:Conv1"),
    description="Whole CNN block in one launch: Conv1-style VPU MAC, pool "
                "reduce + activation applied to the VMEM-resident tile; "
                "writes only the pooled, activated tensor."))
CNN_FUSED.register(KernelIP(
    name="cnn_fused.fused_mxu", family="cnn_fused",
    impl=fused_mod.fused_cnn_mxu, footprint_fn=fused_mod.footprint_mxu,
    uses_mxu=True, tags=("fused", "analogue:Conv2"),
    description="Whole CNN block in one launch: im2col + one MXU pass, "
                "pool + activation in register; single HBM write."))

# --------------------------------------------------------------------------
# matmul family — the LM-hot-path generalization.
# --------------------------------------------------------------------------
MATMUL = IPFamily("matmul", reference=matmul_ref)
MATMUL.register(KernelIP(
    name="matmul.mm_vpu", family="matmul", impl=mm_mxu_mod.mm_vpu,
    footprint_fn=mm_mxu_mod.footprint_vpu, uses_mxu=False,
    tags=("analogue:Conv1",),
    description="Dot-free broadcast-multiply matmul; VPU only."))
MATMUL.register(KernelIP(
    name="matmul.mm_mxu", family="matmul", impl=mm_mxu_mod.mm_mxu,
    footprint_fn=mm_mxu_mod.footprint_mxu, uses_mxu=True,
    tags=("analogue:Conv2",),
    description="Tiled MXU matmul, f32/int32 VMEM accumulator."))
MATMUL.register(KernelIP(
    name="matmul.mm_dual_shared", family="matmul", impl=mm_dual.mm_dual_shared,
    footprint_fn=lambda m, k, n, **kw: mm_dual.footprint_dual(
        m, k, n, int8=True, **kw),
    uses_mxu=True, max_operand_bits=8, outputs_per_pass=2,
    supports_dtypes=("int8",), tags=("analogue:Conv3", "dual-stream"),
    description="Two int8 streams, one weight fetch, 2x int8 MXU rate."))
MATMUL.register(KernelIP(
    name="matmul.mm_dual_full", family="matmul", impl=mm_dual.mm_dual_full,
    footprint_fn=lambda m, k, n, itemsize=2, **kw: mm_dual.footprint_dual(
        m, k, n, int8=False, itemsize=itemsize, **kw),
    uses_mxu=True, outputs_per_pass=2, tags=("analogue:Conv4", "dual-stream"),
    description="Two full-precision streams sharing one weight fetch."))

# --------------------------------------------------------------------------
# attention family.
# --------------------------------------------------------------------------
# No integer kernels exist for attention — the precision ladder must
# never lower its sites (quantizable=False; see IPFamily docstring).
ATTENTION = IPFamily("attention", reference=attention_ref, quantizable=False)
ATTENTION.register(KernelIP(
    name="attention.attn_naive", family="attention", impl=attention_ref,
    footprint_fn=lambda b, hq, hkv, sq, skv, d, **kw: attn_flash_mod.footprint(
        b, hq, hkv, sq, skv, d, bq=sq, bk=skv, **kw),
    uses_mxu=True, tags=("reference",),
    description="Materialized-scores attention; VMEM O(S^2) — small S only."))
ATTENTION.register(KernelIP(
    name="attention.attn_flash", family="attention",
    impl=attn_flash_mod.flash_attention,
    footprint_fn=attn_flash_mod.footprint, uses_mxu=True,
    tags=("train", "prefill"),
    description="Tiled online-softmax; VMEM O(block), HBM O(S*D)."))
ATTENTION.register(KernelIP(
    name="attention.attn_decode", family="attention",
    impl=attn_decode_mod.flash_decode,
    footprint_fn=attn_decode_mod.footprint, uses_mxu=True,
    tags=("decode",),
    description="Single-token flash-decode over KV blocks; HBM-bound."))

# --------------------------------------------------------------------------
# ssm_scan family — the attention-free recurrence (jamba/rwkv end of the
# spectrum; Conv1-style logic-only contract: zero MXU passes).
# --------------------------------------------------------------------------
from repro.kernels.mamba_scan import scan as mamba_scan_mod  # noqa: E402
from repro.kernels.mamba_scan.ref import selective_scan_ref  # noqa: E402

SSM_SCAN = IPFamily("ssm_scan", reference=selective_scan_ref,
                    quantizable=False)
SSM_SCAN.register(KernelIP(
    name="ssm_scan.selective_vmem", family="ssm_scan",
    impl=mamba_scan_mod.selective_scan,
    footprint_fn=mamba_scan_mod.footprint, uses_mxu=False,
    tags=("analogue:Conv1", "ssm"),
    description="Selective scan with VMEM-resident state: HBM traffic "
                "O(T·(Di+Ds)) vs the scan twin's O(T·Di·Ds)."))

FAMILIES = {f.name: f for f in (CONV2D, POOL2D, ACTIVATION, CNN_FUSED,
                                MATMUL, ATTENTION, SSM_SCAN)}

# --------------------------------------------------------------------------
# Site adapters — what makes each family *plannable*.  An adapter maps a
# declarative SiteSpec (shapes + dtype + knobs) to the candidate members
# and footprint arguments the generic engine (core/plan.py) prices; the
# selection/ranking semantics themselves are family-agnostic.
# --------------------------------------------------------------------------
import math  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core.ip import SiteRequest, SiteSpec  # noqa: E402


def _bits(dtype) -> int:
    return jnp.dtype(dtype).itemsize * 8


def _conv2d_adapter(spec: SiteSpec) -> SiteRequest:
    x_shape, w_shape = spec.shapes
    n, h, w_, cin = x_shape
    kh, kw, _, cout = w_shape
    want = (("conv2d.ip3_packed", "conv2d.ip4_dual")
            if spec.knob("dual", False)
            else ("conv2d.ip1_vpu", "conv2d.ip2_mxu"))
    return SiteRequest(
        candidates=tuple(CONV2D[name] for name in want),
        fp_args=(n, h, w_, cin, kh, kw, cout),
        fp_kwargs=(("itemsize", jnp.dtype(spec.dtype).itemsize),),
        op_bits=_bits(spec.dtype))


def _pool2d_adapter(spec: SiteSpec) -> SiteRequest:
    from repro.kernels.pool2d.ref import check_pool_geometry
    (x_shape,) = spec.shapes
    (kh, kw), (sh, sw) = check_pool_geometry(
        x_shape, spec.knob("window", (2, 2)), spec.knob("stride"))
    n, h, w_, c = x_shape
    return SiteRequest(
        candidates=(POOL2D["pool2d.pool_vpu"], POOL2D["pool2d.pool_im2col"]),
        fp_args=(n, h, w_, c, kh, kw, sh, sw),
        fp_kwargs=(("itemsize", jnp.dtype(spec.dtype).itemsize),
                   ("mode", spec.knob("mode", "max"))),
        op_bits=_bits(spec.dtype))


def _activation_adapter(spec: SiteSpec) -> SiteRequest:
    kind = spec.knob("kind", "relu")
    cands = [ACTIVATION["activation.act_vpu"]]
    if kind in act_lut_mod.SUPPORTED_KINDS:
        # capability filter: the LUT is constant-off-range, so only
        # saturating kinds may offer it
        cands.append(ACTIVATION["activation.act_lut"])
    n_elems = int(math.prod(int(d) for d in spec.shapes[0]))
    # Activation IPs re-encode their input on ingest (the LUT member
    # quantizes), so the caller's dtype imposes no operand-width floor;
    # precision demands arrive via budget.precision_bits instead.
    return SiteRequest(
        candidates=tuple(cands),
        fp_args=(n_elems,),
        fp_kwargs=(("itemsize", jnp.dtype(spec.dtype).itemsize),
                   ("kind", kind)),
        op_bits=0)


def _matmul_adapter(spec: SiteSpec) -> SiteRequest:
    a_shape, b_shape = spec.shapes
    m, k = a_shape[-2], a_shape[-1]
    n = b_shape[-1]
    want = (("matmul.mm_dual_shared", "matmul.mm_dual_full")
            if spec.knob("dual", False)
            else ("matmul.mm_vpu", "matmul.mm_mxu"))
    return SiteRequest(
        candidates=tuple(MATMUL[name] for name in want),
        fp_args=(m, k, n),
        fp_kwargs=(("itemsize", jnp.dtype(spec.dtype).itemsize),),
        op_bits=_bits(spec.dtype))


def _attention_adapter(spec: SiteSpec) -> SiteRequest:
    q_shape, kv_shape = spec.shapes
    b, hq, sq, d = q_shape
    _, hkv, skv, _ = kv_shape
    if sq == 1:
        cands = (ATTENTION["attention.attn_decode"],)
        args = (b, hq, hkv, skv, d)
    else:
        cands = (ATTENTION["attention.attn_naive"],
                 ATTENTION["attention.attn_flash"])
        args = (b, hq, hkv, sq, skv, d)
    return SiteRequest(
        candidates=cands, fp_args=args,
        fp_kwargs=(("itemsize", jnp.dtype(spec.dtype).itemsize),),
        op_bits=_bits(spec.dtype))


def _cnn_fused_adapter(spec: SiteSpec) -> SiteRequest:
    from repro.kernels.pool2d.ref import check_pool_geometry
    x_shape, w_shape = spec.shapes
    n, h, w_, cin = x_shape
    kh, kw, _, cout = w_shape
    conv_out = (n, h - kh + 1, w_ - kw + 1, cout)
    (ph, pw), (sh, sw) = check_pool_geometry(
        conv_out, spec.knob("window", (2, 2)), spec.knob("stride"))
    return SiteRequest(
        candidates=(CNN_FUSED["fused_vpu"], CNN_FUSED["fused_mxu"]),
        fp_args=(n, h, w_, cin, kh, kw, cout, ph, pw, sh, sw),
        fp_kwargs=(("itemsize", jnp.dtype(spec.dtype).itemsize),
                   ("mode", spec.knob("mode", "max")),
                   ("kind", spec.knob("kind", "relu"))),
        op_bits=_bits(spec.dtype))


def _cnn_fuse_sites(run) -> "SiteSpec | None":
    """Map an adjacent (conv, pool, act) SiteSpec triple to the single
    fused-block SiteSpec, or None when the run is not fusable: a
    dual-stream conv, shapes that do not chain conv->pool->act, or a
    pool window the conv output cannot host."""
    conv, pool, act = run
    if conv.knob("dual", False):
        return None
    x_shape, w_shape = conv.shapes
    n, h, w_, cin = x_shape
    kh, kw, _, cout = w_shape
    conv_out = (n, h - kh + 1, w_ - kw + 1, cout)
    if tuple(pool.shapes[0]) != conv_out:
        return None
    try:
        from repro.kernels.pool2d.ref import (check_pool_geometry,
                                              pool2d_out_shape)
        window, stride = check_pool_geometry(
            conv_out, pool.knob("window", (2, 2)), pool.knob("stride"))
        if tuple(act.shapes[0]) != pool2d_out_shape(conv_out, window,
                                                    stride):
            return None
    except ValueError:
        return None
    base = conv.name[:-len(".conv")] if conv.name.endswith(".conv") \
        else conv.name
    ladder = set(conv.ladder) & set(pool.ladder) & set(act.ladder)
    return SiteSpec.make(
        f"{base}.fused", "cnn_fused", (x_shape, w_shape), conv.dtype,
        ladder=tuple(ladder), window=window, stride=stride,
        mode=pool.knob("mode", "max"), kind=act.knob("kind", "relu"))


CONV2D.site_adapter = _conv2d_adapter
POOL2D.site_adapter = _pool2d_adapter
ACTIVATION.site_adapter = _activation_adapter
CNN_FUSED.site_adapter = _cnn_fused_adapter
CNN_FUSED.fuse_sites = _cnn_fuse_sites
MATMUL.site_adapter = _matmul_adapter
ATTENTION.site_adapter = _attention_adapter


def get_family(name: str) -> IPFamily:
    return FAMILIES[name]


def get_ip(qualified: str) -> KernelIP:
    family, _, short = qualified.partition(".")
    return FAMILIES[family][short or qualified]
