"""Mesh sharding rules — how one plan spreads across devices.

The paper sizes a network against ONE fabric.  The scale-out story
(multi-FPGA boards, TPU slices) offers several identical fabrics joined
by links of finite bandwidth, and the honest way to use them is the
same resource-driven bargain the paper strikes on a single chip: a
split shrinks every per-device footprint column, but the collective
traffic it induces is a *cost* — priced in cycles at the mesh's link
bandwidth (``MeshSpec``), never waved away.

This module owns the three ingredients ``plan_network(mesh=...)`` needs:

* **Shard rules** (``shard_site_spec``): for each plannable family, the
  per-device ``SiteSpec`` a split produces — batch-parallel (every
  family that has a batch dim) or channel-parallel (conv splits its
  input channels and psums partial outputs; pool/activation split their
  channel dim communication-free).  ``None`` means "this site does not
  shard this way" (non-divisible dims, dual-stream convs, fused blocks
  on the channel axis — pooling partial sums is wrong math).
* **Layout algebra** (``required_input_layout`` / ``output_layout`` /
  ``boundary_comm_cycles``): what layout a sharded site consumes and
  produces, and what an adjacent pair of sites pays when their layouts
  disagree (an all-gather of the producer's output; slicing replicated
  data is free).
* **The decision pass** (``plan_shard_decisions``): a shortest-path DP
  over the site chain.  Per site the options are degree=1 (replicated),
  a batch split, and a channel split — each priced as its selected
  member's per-device cost plus its collective cycles — and the DP
  threads layout transitions so a mixed chain pays its boundary
  all-gathers where they occur.  The network's input arrives replicated
  and its output must leave replicated (egress gather charged to the
  last site).  A site infeasible at degree=1 but feasible sharded is
  *rescued* by the split — resource-driven adaptation past one device.

Everything here is trace-time Python on specs and budgets; execution of
a sharded plan lives in ``distributed/shard_exec.py``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.ip import SiteSpec
from repro.core.resources import MeshSpec, ResourceBudget
from repro.obs.trace import NOOP_SPAN, TRACER

# A tensor layout as the planner sees it: ("full", 1) replicated on every
# device, ("batch", d) split on the leading dim, ("chan", d) split on the
# trailing (channel) dim.
FULL = ("full", 1)

AXES = ("batch", "chan")


def degree_ladder(degree: int, *,
                  survivors: Optional[int] = None) -> Tuple[int, ...]:
    """The shard-degree degradation ladder of a plan serving at
    ``degree``: every divisor of ``degree``, descending.

    Divisors are the rungs because any batch that tiled evenly at
    ``degree`` still tiles at each of them — descending the ladder
    changes *parallelism*, never feasibility of the shapes already in
    flight.  ``survivors=`` caps the ladder at the devices actually
    left, so ``degree_ladder(d, survivors=s)[0]`` is the widest degree
    a degraded grant of ``s`` devices can still serve.  This is the
    rung order the runtime's device-loss path walks — the degree ladder
    descends *before* the precision ladder does (the shrunk sub-mesh
    still plans each device against the full per-device budget)."""
    if degree < 1:
        raise ValueError("degree must be >= 1")
    rungs = tuple(k for k in range(degree, 0, -1) if degree % k == 0)
    if survivors is not None:
        if survivors < 1:
            raise ValueError("survivors must be >= 1")
        rungs = tuple(k for k in rungs if k <= survivors)
    return rungs


@dataclasses.dataclass(frozen=True)
class SiteSharding:
    """One site's resolved sharding: the axis and degree the DP chose,
    the per-device spec the planner prices under the full per-device
    budget (== the global spec when degree is 1), and the collective
    cycles charged to this site — its own psum/halo traffic plus the
    ingress boundary gather its layout transition costs (the last site
    also carries the egress gather back to replicated)."""

    axis: str                 # "none" | "batch" | "chan"
    degree: int
    spec: SiteSpec            # the spec selection/partitioning runs on
    comm_cycles: float = 0.0

    @property
    def sharded(self) -> bool:
        return self.degree > 1


# ---------------------------------------------------------------------------
# Shapes — the global output of each plannable family (what crosses a
# site boundary, and what a channel-split conv psums).
# ---------------------------------------------------------------------------
def site_output_shape(spec: SiteSpec) -> Tuple[int, ...]:
    """The (global) output shape of one site, from its spec alone."""
    if spec.family == "conv2d":
        (n, h, w, _), (kh, kw, _, cout) = spec.shapes
        return (n, h - kh + 1, w - kw + 1, cout)
    if spec.family == "pool2d":
        from repro.kernels.pool2d.ref import (check_pool_geometry,
                                              pool2d_out_shape)
        (xs,) = spec.shapes
        window, stride = check_pool_geometry(
            xs, spec.knob("window", (2, 2)), spec.knob("stride"))
        return pool2d_out_shape(xs, window, stride)
    if spec.family == "activation":
        return tuple(spec.shapes[0])
    if spec.family == "cnn_fused":
        from repro.kernels.pool2d.ref import (check_pool_geometry,
                                              pool2d_out_shape)
        (n, h, w, _), (kh, kw, _, cout) = spec.shapes
        conv_out = (n, h - kh + 1, w - kw + 1, cout)
        window, stride = check_pool_geometry(
            conv_out, spec.knob("window", (2, 2)), spec.knob("stride"))
        return pool2d_out_shape(conv_out, window, stride)
    if spec.family == "matmul":
        a_shape, b_shape = spec.shapes
        return tuple(a_shape[:-1]) + (b_shape[-1],)
    raise ValueError(f"family {spec.family!r} has no output-shape rule; "
                     "it cannot participate in a sharded chain")


def site_output_bytes(spec: SiteSpec) -> int:
    """Bytes of the site's global output at its native dtype — the
    tensor a boundary all-gather or a channel-split psum moves."""
    shape = site_output_shape(spec)
    return int(math.prod(shape)) * jnp.dtype(spec.dtype).itemsize


def _split_dim(shape: Sequence[int], dim: int, degree: int):
    """``shape`` with ``shape[dim] // degree``, or None if not divisible
    into non-empty blocks."""
    shape = tuple(int(d) for d in shape)
    if degree <= 1:
        return shape
    if shape[dim] % degree != 0 or shape[dim] < degree:
        return None
    out = list(shape)
    out[dim] = shape[dim] // degree
    return tuple(out)


# ---------------------------------------------------------------------------
# Shard rules — the per-device spec each (family, axis) split produces.
# ---------------------------------------------------------------------------
def shard_site_spec(spec: SiteSpec, axis: str,
                    degree: int) -> Optional[SiteSpec]:
    """The per-device ``SiteSpec`` of ``spec`` split ``degree`` ways on
    ``axis``, or ``None`` when the site does not shard that way.

    The name is kept (sharded plans map sites back to their global specs
    positionally; execution looks sites up by name either way).  Rules:

    * ``batch``: every conv/pool/act/fused/matmul site with a divisible
      leading dim — communication-free along the chain (each device owns
      a batch slab end to end).
    * ``chan``: conv splits its *input* channels — each device computes
      a partial sum over the full output, made whole by an all-reduce
      (priced by the caller via ``site_comm_cycles``).  Pool and
      activation split their channel dim with no communication at all.
      Dual-stream convs and fused conv->pool->act blocks refuse: pooling
      or activating a partial sum is not the math the oracle defines.
    """
    if degree <= 1:
        return spec
    if axis not in AXES:
        raise ValueError(f"unknown shard axis {axis!r}; have {AXES}")
    fam = spec.family
    if fam == "conv2d":
        x_shape, w_shape = spec.shapes
        if axis == "batch":
            xs = _split_dim(x_shape, 0, degree)
            if xs is None:
                return None
            return dataclasses.replace(spec, shapes=(xs, tuple(w_shape)))
        # channel: split cin on both operands; partial-sum semantics
        # don't compose with the dual-stream members' packing.
        if spec.knob("dual", False):
            return None
        xs = _split_dim(x_shape, 3, degree)
        ws = _split_dim(w_shape, 2, degree)
        if xs is None or ws is None:
            return None
        return dataclasses.replace(spec, shapes=(xs, ws))
    if fam in ("pool2d", "activation"):
        (x_shape,) = spec.shapes
        dim = 0 if axis == "batch" else len(x_shape) - 1
        xs = _split_dim(x_shape, dim, degree)
        if xs is None:
            return None
        return dataclasses.replace(spec, shapes=(xs,))
    if fam == "cnn_fused":
        if axis != "batch":
            return None     # pool/act of a partial sum is wrong math
        x_shape, w_shape = spec.shapes
        xs = _split_dim(x_shape, 0, degree)
        if xs is None:
            return None
        return dataclasses.replace(spec, shapes=(xs, tuple(w_shape)))
    if fam == "matmul":
        if axis != "batch":
            return None
        a_shape, b_shape = spec.shapes
        a = _split_dim(a_shape, 0, degree)
        if a is None:
            return None
        return dataclasses.replace(spec, shapes=(a, tuple(b_shape)))
    return None             # attention / ssm_scan: no shard rule yet


def required_input_layout(spec: SiteSpec, axis: str,
                          degree: int) -> Tuple[str, int]:
    """The layout a site sharded (axis, degree) consumes."""
    if degree <= 1:
        return FULL
    return (axis, degree)


def output_layout(spec: SiteSpec, axis: str,
                  degree: int) -> Tuple[str, int]:
    """The layout a site sharded (axis, degree) produces.  A channel
    -split conv emerges *replicated*: its all-reduce (priced in
    ``site_comm_cycles``) leaves the full output on every device."""
    if degree <= 1:
        return FULL
    if axis == "chan" and spec.family == "conv2d":
        return FULL
    return (axis, degree)


def site_comm_cycles(spec: SiteSpec, axis: str, degree: int,
                     mesh: MeshSpec) -> float:
    """Collective cycles the split itself induces (boundary transitions
    are priced separately): the channel-split conv's all-reduce of its
    full output; batch and channel splits of pool/act are free."""
    if degree <= 1:
        return 0.0
    if axis == "chan" and spec.family == "conv2d":
        return mesh.all_reduce_cycles(site_output_bytes(spec))
    return 0.0


def boundary_comm_cycles(mesh: MeshSpec, produced: Tuple[str, int],
                         needed: Tuple[str, int], n_bytes: int) -> float:
    """Cycles to re-lay a tensor of global size ``n_bytes`` from the
    layout its producer left it in to the layout its consumer needs.
    Slicing replicated data is free; any sharded-to-different move is
    priced as the all-gather back to replicated (the slice after it is
    free again) — the conservative single-hop model."""
    if produced == needed or produced == FULL:
        return 0.0
    return mesh.all_gather_cycles(n_bytes)


# ---------------------------------------------------------------------------
# The decision pass.
# ---------------------------------------------------------------------------
def plan_shard_decisions(specs: Sequence[SiteSpec], budget: ResourceBudget,
                         mesh: MeshSpec, select=None,
                         calibration=None,
                         events=None) -> Tuple[SiteSharding, ...]:
    """Choose, per site, between replicating and sharding — the mesh
    tentpole's pricing pass (docs/adaptive_ips.md, "Sharding contract").

    A shortest-path DP over the chain: the state after site *i* is the
    layout its chosen option leaves the activation in; an option's cost
    is its selected member's per-device cycles (each device sees the
    FULL per-device ``budget`` — that is what an N-device grant means)
    plus its own collective traffic plus the boundary gather from the
    incoming state's layout.  The input arrives replicated; the output
    is gathered back to replicated (egress charged to the last site).

    Degrees considered are 1 and ``mesh.devices`` — the all-or-nothing
    split matches the arbiter's slice grants; partial degrees would
    strand devices.  A site with no feasible option at all raises the
    degree=1 selection error (sharding *widens* feasibility, it never
    narrows it).  Returns one ``SiteSharding`` per site, comm already
    apportioned; with ``mesh.devices == 1`` every decision is the
    trivial replicated one.

    ``events`` (a list, when given) receives one plan-audit line per
    non-trivial decision: a ``shard:`` line for every split taken and a
    ``shard refusal:`` line — with the per-option prices — for every
    site that had a split available and stayed replicated.
    """
    specs = tuple(specs)
    with (TRACER.span("plan_shard_decisions", "shard",
                      {"sites": len(specs), "devices": mesh.devices})
          if TRACER.enabled else NOOP_SPAN):
        return _plan_shard_decisions(specs, budget, mesh, select,
                                     calibration, events)


def _plan_shard_decisions(specs, budget, mesh, select, calibration,
                          events):
    if select is None:
        from repro.core.plan import _select_site

        def select(s):
            return _select_site(s, budget, calibration)

    if mesh.devices <= 1:
        return tuple(SiteSharding("none", 1, s) for s in specs)

    from repro.core.plan import _select_site, _site_cost
    d = mesh.devices

    def _cost_of(sspec, use_memo):
        sel = select(sspec) if use_memo else _select_site(
            sspec, budget, calibration)
        ip, fp, bits = sel
        return _site_cost(ip, fp, bits, sspec, calibration)

    # Per site: list of (axis, degree, sspec, need_layout, out_layout,
    # site_comm, compute_cost).
    options = []
    for spec in specs:
        opts = []
        base_err = None
        try:
            # degree=1 goes through the caller's memo — plan_network
            # prices the same full-budget selection for its baseline.
            opts.append(("none", 1, spec, FULL, FULL, 0.0,
                         _cost_of(spec, use_memo=True)))
        except ValueError as e:
            base_err = e
        for axis in AXES:
            sspec = shard_site_spec(spec, axis, d)
            if sspec is None:
                continue
            try:
                cost = _cost_of(sspec, use_memo=False)
            except ValueError:
                continue        # this split doesn't fit either; skip it
            opts.append((axis, d, sspec,
                         required_input_layout(spec, axis, d),
                         output_layout(spec, axis, d),
                         site_comm_cycles(spec, axis, d, mesh), cost))
        if not opts:
            raise base_err      # not even the splits rescue this site
        options.append(opts)

    # DP: layout -> (total cost, decisions so far).
    states = {FULL: (0.0, ())}
    for spec, opts in zip(specs, options):
        new_states = {}
        for in_layout, (cost, decs) in states.items():
            for axis, deg, sspec, need, out, scomm, ccost in opts:
                # Boundary bytes: the producer's output == this site's
                # input; the first site's input arrives replicated so
                # its transition is free by the FULL rule.
                prev_bytes = (site_output_bytes(specs[len(decs) - 1])
                              if decs else 0)
                bcomm = boundary_comm_cycles(mesh, in_layout, need,
                                             prev_bytes)
                comm = scomm + bcomm
                total = cost + ccost + comm
                dec = SiteSharding(axis, deg, sspec, comm)
                cur = new_states.get(out)
                if cur is None or total < cur[0]:
                    new_states[out] = (total, decs + (dec,))
        states = new_states

    # Egress: gather the network output back to replicated.
    best = None
    last_bytes = site_output_bytes(specs[-1])
    for out_layout, (cost, decs) in states.items():
        egress = boundary_comm_cycles(mesh, out_layout, FULL, last_bytes)
        total = cost + egress
        if best is None or total < best[0]:
            last = decs[-1]
            decs = decs[:-1] + (dataclasses.replace(
                last, comm_cycles=last.comm_cycles + egress),)
            best = (total, decs)
    if events is not None:
        for spec, opts, dec in zip(specs, options, best[1]):
            if dec.degree > 1:
                events.append(
                    f"shard: {spec.name} split {dec.axis}x{dec.degree} "
                    f"(comm {dec.comm_cycles:.3e} cycles)")
            elif len(opts) > 1:
                # A split was on the table and the DP kept the site
                # replicated — the refusal the audit must explain.
                priced = "; ".join(
                    f"{axis}x{deg} compute {ccost:.3e} + comm "
                    f"{scomm:.3e}"
                    for axis, deg, _, _, _, scomm, ccost in opts
                    if deg > 1)
                repl = next(ccost for axis, deg, *_, ccost in opts
                            if deg == 1)
                events.append(
                    f"shard refusal: {spec.name} stays replicated "
                    f"(compute {repl:.3e}) over {priced}")
    return best[1]


def force_shard_decisions(specs: Sequence[SiteSpec], mesh: MeshSpec,
                          axis: str = "batch") -> Tuple[SiteSharding, ...]:
    """Shard EVERY site on ``axis`` at the mesh's full degree — the
    measurement counterfactual ``benchmarks/run.py::table_mesh`` uses to
    show the planner's refusal is right (force the split the model
    rejected, measure it losing).  Raises when any site has no rule for
    ``axis`` at this degree; comm is priced exactly as the DP would."""
    specs = tuple(specs)
    d = mesh.devices
    if d <= 1:
        return tuple(SiteSharding("none", 1, s) for s in specs)
    out = []
    in_layout = FULL
    for i, spec in enumerate(specs):
        sspec = shard_site_spec(spec, axis, d)
        if sspec is None:
            raise ValueError(
                f"site {spec.name!r} ({spec.family}) cannot shard on "
                f"{axis!r} x{d}")
        need = required_input_layout(spec, axis, d)
        prev_bytes = site_output_bytes(specs[i - 1]) if i else 0
        comm = (site_comm_cycles(spec, axis, d, mesh)
                + boundary_comm_cycles(mesh, in_layout, need, prev_bytes))
        in_layout = output_layout(spec, axis, d)
        out.append(SiteSharding(axis, d, sspec, comm))
    egress = boundary_comm_cycles(mesh, in_layout, FULL,
                                  site_output_bytes(specs[-1]))
    last = out[-1]
    out[-1] = dataclasses.replace(last,
                                  comm_cycles=last.comm_cycles + egress)
    return tuple(out)
